//! SLO serving at fleet scale: the paper's 30-job workload (Table 4) run
//! with DNNScaler and Clipper on the simulated Tesla P40, plus an
//! open-loop bursty-arrival demonstration (§3.3's burst claim).
//!
//! Run with: cargo run --release --example slo_serving

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::job::PAPER_JOBS;
use dnnscaler::coordinator::runner::{JobRunner, RunConfig};
use dnnscaler::gpusim::GpuSim;
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::Table;
use dnnscaler::workload::{ArrivalGenerator, ArrivalPattern, RequestQueue};

fn main() -> Result<()> {
    // ---- Part 1: the 30-job fleet. --------------------------------------
    let runner = JobRunner::new(RunConfig::windows(40, 20));
    let mut t = Table::new(
        "30-job fleet: DNNScaler vs Clipper (simulated P40)",
        &["job", "dnn", "method", "knob", "thr", "clipper", "gain", "p95<=SLO"],
    );
    let (mut gains, mut hits) = (Vec::new(), 0);
    for job in PAPER_JOBS {
        let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 100 + job.id as u64).unwrap();
        let s = runner.run_dnnscaler(job, &mut d1).map_err(|e| anyhow!(e.to_string()))?;
        let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 200 + job.id as u64).unwrap();
        let c = runner.run_clipper(job, &mut d2).map_err(|e| anyhow!(e.to_string()))?;
        let gain = s.throughput / c.throughput;
        gains.push(gain);
        let method = s.method.unwrap();
        if method == job.paper_method {
            hits += 1;
        }
        let knob = if s.steady_mtl > 1 {
            format!("MTL={}", s.steady_mtl)
        } else {
            format!("BS={}", s.steady_bs)
        };
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            method.short().into(),
            knob,
            f1(s.throughput),
            f1(c.throughput),
            f2(gain),
            if s.slo_attainment >= 0.95 { "yes" } else { "~" }.into(),
        ]);
    }
    print!("{}", t.render());
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    let max = gains.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "method agreement {hits}/30 | mean speedup {mean:.2}x | max {max:.2}x (paper: 218% avg, 14x max)\n"
    );

    // ---- Part 2: bursty open-loop serving of one MT job. ---------------
    println!("bursty arrivals against job 1 (inc-v1, MT): queue depth under a 5x burst");
    let job = &PAPER_JOBS[0];
    let mut sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap();
    // Base load ~60 req/s with 4x bursts: mean offered load ~105 req/s
    // against ~200 inf/s of MT capacity, so bursts queue then drain.
    let mut gen = ArrivalGenerator::new(
        ArrivalPattern::Bursty { rate: 60.0, factor: 4.0, period_s: 4.0, burst_s: 1.0 },
        11,
    );
    let mut queue = RequestQueue::new();
    let arrivals = gen.arrivals_until(12.0);
    let mut next_arrival = 0usize;
    let mut now_s = 0.0;
    let mtl = 8u32; // steady point DNNScaler found for job 1
    let mut served = 0u64;
    let mut p95_acc: Vec<f64> = Vec::new();
    while now_s < 12.0 {
        while next_arrival < arrivals.len() && arrivals[next_arrival] <= now_s {
            queue.push(arrivals[next_arrival]);
            next_arrival += 1;
        }
        use dnnscaler::device::Device;
        let s = sim.execute_batch(1, mtl).map_err(|e| anyhow!(e.to_string()))?;
        let round_s = s.latency_ms / 1000.0;
        // Each of the mtl instances drains one request per round.
        let batch = queue.take_batch(mtl as usize);
        for r in &batch {
            let sojourn_ms = (now_s - r.arrival_s) * 1000.0 + s.latency_ms;
            p95_acc.push(sojourn_ms);
            served += 1;
        }
        now_s += round_s;
        if (now_s * 10.0) as u64 % 20 == 0 {
            // coarse progress line every ~2 s of sim time
        }
    }
    p95_acc.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95 = p95_acc[(p95_acc.len() as f64 * 0.95) as usize - 1];
    println!(
        "  served {served} requests in 12 s sim time | peak queue depth {} | p95 sojourn {:.1} ms (SLO {} ms)",
        queue.max_depth, p95, job.slo_ms
    );
    println!(
        "  residual queue {} — MT absorbs the burst {}",
        queue.len(),
        if queue.len() < 50 { "(stable)" } else { "(overloaded)" }
    );
    Ok(())
}
