//! SLO serving at fleet scale: the paper's 30-job workload (Table 4) run
//! with DNNScaler and Clipper on the simulated Tesla P40, plus an
//! open-loop bursty-arrival demonstration (§3.3's burst claim) through
//! the event-driven `ServingSession`.
//!
//! Run with: cargo run --release --example slo_serving

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::job::{JobSpec, PAPER_JOBS};
use dnnscaler::coordinator::session::{JobOutcome, PolicySpec, RunConfig, ServingSession};
use dnnscaler::gpusim::GpuSim;
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::Table;
use dnnscaler::workload::ArrivalPattern;

fn closed(job: &JobSpec, seed: u64, spec: PolicySpec<'static>) -> Result<JobOutcome> {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
    ServingSession::builder()
        .config(RunConfig::windows(40, 20))
        .job(job)
        .device(sim)
        .policy(spec)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))
}

fn main() -> Result<()> {
    // ---- Part 1: the 30-job fleet, closed loop (the paper's setup). ----
    let mut t = Table::new(
        "30-job fleet: DNNScaler vs Clipper (simulated P40)",
        &["job", "dnn", "method", "knob", "thr", "clipper", "gain", "p95<=SLO"],
    );
    let (mut gains, mut hits) = (Vec::new(), 0);
    for job in PAPER_JOBS {
        let s = closed(job, 100 + job.id as u64, PolicySpec::DnnScaler)?;
        let c = closed(job, 200 + job.id as u64, PolicySpec::Clipper)?;
        let gain = s.throughput / c.throughput;
        gains.push(gain);
        let method = s.method.unwrap();
        if method == job.paper_method {
            hits += 1;
        }
        let knob = if s.steady_mtl > 1 {
            format!("MTL={}", s.steady_mtl)
        } else {
            format!("BS={}", s.steady_bs)
        };
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            method.short().into(),
            knob,
            f1(s.throughput),
            f1(c.throughput),
            f2(gain),
            if s.slo_attainment >= 0.95 { "yes" } else { "~" }.into(),
        ]);
    }
    print!("{}", t.render());
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    let max = gains.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "method agreement {hits}/30 | mean speedup {mean:.2}x | max {max:.2}x (paper: 218% avg, 14x max)\n"
    );

    // ---- Part 2: open-loop bursty serving of job 1 (inc-v1, MT). -------
    // Base load 60 req/s with 4x bursts (1 s of every 4 s). The session's
    // virtual-time event loop queues arrivals, forms batches by size or a
    // 5 ms timeout, and charges queueing delay into every latency — so
    // DNNScaler converges to a point with headroom for the bursts instead
    // of the closed-loop knee.
    println!("bursty open-loop serving of job 1 (inc-v1): 60 req/s base, 4x bursts");
    let job = &PAPER_JOBS[0];
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 11).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(30, 20))
        .job(job)
        .device(sim)
        .policy(PolicySpec::DnnScaler)
        .arrivals(ArrivalPattern::bursty(60.0, 4.0, 4.0, 1.0))
        .batch_timeout_ms(5.0)
        .seed(11)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    let served: f64 = out.latencies.iter().map(|(_, w)| w).sum();
    println!(
        "  served {served:.0} requests | steady knob mtl={} (closed-loop knee: 8) | p95 sojourn {:.1} ms (SLO {} ms)",
        out.steady_mtl, out.p95_ms, job.slo_ms
    );
    println!(
        "  queue peak {} | dropped {} | steady SLO attainment {:.1}%",
        out.queue_peak,
        out.drops,
        out.steady_attainment * 100.0
    );
    let burst_windows =
        out.trace.iter().filter(|r| r.queue_peak > 2).count();
    println!(
        "  {} of {} windows saw queue build-up — MT absorbs the bursts {}",
        burst_windows,
        out.trace.len(),
        if out.steady_attainment > 0.8 { "(stable)" } else { "(overloaded)" }
    );
    Ok(())
}
