//! Fleet power-efficiency report (paper Table 6), latency CDFs (paper
//! Fig. 6) for the Multi-Tenancy jobs, and two true multi-job `Fleet`
//! runs on ONE simulated P40 with shared memory and SM contention —
//! closed-loop (lockstep windows) and open-loop (per-member arrival
//! processes through the shared event engine, with SLO deadline shedding
//! and goodput accounting) — the scenarios the paper's one-job-per-GPU
//! evaluation cannot express. Ends with a `Cluster` section: the same
//! bursty offered load across a two-P40 pool under the three shipped
//! placements (round robin pairs the bursty hogs; interference-aware
//! refuses to).
//!
//! Run with: cargo run --release --example fleet_report

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::cluster::{
    BestFit, Cluster, ClusterOutcome, InterferenceAware, Placement, RoundRobin,
};
use dnnscaler::coordinator::job::{paper_job, JobSpec, PAPER_JOBS};
use dnnscaler::coordinator::session::{JobOutcome, PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::{DemandPartition, Fleet, Method};
use dnnscaler::gpusim::{GpuSim, PartitionMode, TESLA_P40};
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::{Table, WeightedCdf};
use dnnscaler::workload::ArrivalPattern;

fn closed(job: &JobSpec, seed: u64, spec: PolicySpec<'static>) -> Result<JobOutcome> {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
    ServingSession::builder()
        .config(RunConfig::windows(40, 20))
        .job(job)
        .device(sim)
        .policy(spec)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))
}

fn main() -> Result<()> {
    let mut t = Table::new(
        "Power & efficiency, MT jobs (Table 6)",
        &["job", "dnn", "P_scaler(W)", "P_clipper(W)", "thr_s", "thr_c", "eff_s", "eff_c", "eff gain"],
    );
    let mut cdf_jobs: Vec<(u32, WeightedCdf, WeightedCdf, f64)> = Vec::new();
    for job in PAPER_JOBS {
        let s = closed(job, 300 + job.id as u64, PolicySpec::DnnScaler)?;
        if s.method != Some(Method::MultiTenancy) {
            continue;
        }
        let c = closed(job, 400 + job.id as u64, PolicySpec::Clipper)?;
        let eff_s = s.throughput / s.power_w;
        let eff_c = c.throughput / c.power_w;
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            f1(s.power_w),
            f1(c.power_w),
            f1(s.throughput),
            f1(c.throughput),
            f2(eff_s),
            f2(eff_c),
            f2(eff_s / eff_c),
        ]);
        if [1u32, 5, 14, 29].contains(&job.id) {
            cdf_jobs.push((
                job.id,
                WeightedCdf::from_samples(&s.latencies),
                WeightedCdf::from_samples(&c.latencies),
                job.slo_ms,
            ));
        }
    }
    print!("{}", t.render());

    println!("\nLatency CDFs for four jobs (Fig. 6): p50/p90/p95/p99 in ms, SLO marked");
    for (id, mut s_cdf, mut c_cdf, slo) in cdf_jobs {
        println!("  job {id} (SLO {slo} ms)");
        for (name, cdf) in [("dnnscaler", &mut s_cdf), ("clipper", &mut c_cdf)] {
            println!(
                "    {name:<10} p50={:>8.2} p90={:>8.2} p95={:>8.2} p99={:>8.2}  frac<=SLO {:.3}",
                cdf.quantile(0.50).unwrap(),
                cdf.quantile(0.90).unwrap(),
                cdf.quantile(0.95).unwrap(),
                cdf.quantile(0.99).unwrap(),
                cdf.fraction_below(slo),
            );
        }
    }

    // ---- Multi-job Fleet: three DNNs sharing one P40. -------------------
    println!("\nFleet: jobs 1 (inc-v1), 3 (inc-v4), 4 (mobv1-05) co-located on one P40");
    let fleet = Fleet::builder()
        .windows(25)
        .rounds_per_window(10)
        .seed(7)
        .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(3).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(4).unwrap(), PolicySpec::DnnScaler)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    let mut t = Table::new(
        "Fleet members (shared memory + SM contention)",
        &["job", "dnn", "method", "knob", "thr", "p95(ms)", "attain%"],
    );
    for m in &fleet.members {
        t.row(&[
            m.job_id.to_string(),
            m.dnn.clone(),
            m.method.map(|x| x.short()).unwrap_or("-").into(),
            format!("bs={} mtl={}", m.steady_bs, m.steady_mtl),
            f1(m.throughput),
            f2(m.p95_ms),
            f1(m.slo_attainment * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!(
        "fleet total {:.1} inf/s | peak mem {:.0}/{:.0} MB | peak SM contention {:.2} | clamps {}",
        fleet.total_throughput,
        fleet.peak_mem_mb,
        fleet.mem_capacity_mb,
        fleet.peak_contention,
        fleet.admission_clamps
    );

    // ---- Open-loop fleet: per-member arrivals, shedding, goodput. -------
    // Job 1 takes bursty traffic under the queue-aware proactive scaler,
    // jobs 3/4 take steady Poisson load; every member sheds requests whose
    // queueing delay alone already blew its SLO.
    println!(
        "\nOpen-loop fleet: per-member arrivals (job 1 bursty 3x, jobs 3/4 steady), --shed on"
    );
    let open = Fleet::builder()
        .windows(30)
        .rounds_per_window(10)
        .seed(11)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::bursty(60.0, 3.0, 4.0, 1.0),
        )
        .queue_capacity(256)
        .shed_deadline(true)
        .job_with_arrivals(
            paper_job(3).unwrap(),
            PolicySpec::DnnScaler,
            ArrivalPattern::poisson(25.0),
        )
        .shed_deadline(true)
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::poisson(40.0),
        )
        .shed_deadline(true)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    let mut t = Table::new(
        "Open-loop fleet members (per-member arrivals + SLO shedding)",
        &[
            "job", "dnn", "policy", "knob", "arr/s", "thr", "goodput", "p95(ms)", "attain%",
            "drop", "shed",
        ],
    );
    for m in &open.members {
        t.row(&[
            m.job_id.to_string(),
            m.dnn.clone(),
            m.controller.clone(),
            format!("bs={} mtl={}", m.steady_bs, m.steady_mtl),
            f1(m.mean_arrival_rate()),
            f1(m.throughput),
            f1(m.goodput),
            f2(m.p95_ms),
            f1(m.slo_attainment * 100.0),
            m.drops.to_string(),
            m.dropped_deadline.to_string(),
        ]);
    }
    print!("{}", t.render());
    let peak_w = open
        .contention_trace
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(w, _)| w)
        .unwrap_or(0);
    println!(
        "fleet goodput {:.1}/{:.1} inf/s | peak SM contention {:.2} (window {peak_w}) | final {:.2} | clamps {}",
        open.total_goodput,
        open.total_throughput,
        open.peak_contention,
        open.contention_trace.last().copied().unwrap_or(0.0),
        open.admission_clamps
    );

    // ---- Spatial partitioning: the same open-loop mix under MPS. --------
    // Each member holds an SM reservation instead of time-sharing; the
    // demand-weighted PartitionPolicy may move share between members at
    // window boundaries. The bursty member can now only slow itself.
    println!("\nSame fleet under MPS spatial partitioning (demand-weighted rebalancing)");
    let mps = Fleet::builder()
        .windows(30)
        .rounds_per_window(10)
        .seed(11)
        .partition_mode(PartitionMode::Mps)
        .partition_policy(DemandPartition::new())
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::bursty(60.0, 3.0, 4.0, 1.0),
        )
        .queue_capacity(256)
        .shed_deadline(true)
        .sm_reservation(0.5)
        .job_with_arrivals(
            paper_job(3).unwrap(),
            PolicySpec::DnnScaler,
            ArrivalPattern::poisson(25.0),
        )
        .shed_deadline(true)
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::poisson(40.0),
        )
        .shed_deadline(true)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    let mut t = Table::new(
        "MPS fleet members (SM grants instead of time-sharing)",
        &["job", "dnn", "policy", "grant w0", "grant wN", "thr", "goodput", "p95(ms)", "shed"],
    );
    let first_grants = &mps.grant_trace[0];
    let last_grants = mps.grant_trace.last().unwrap();
    for (i, m) in mps.members.iter().enumerate() {
        t.row(&[
            m.job_id.to_string(),
            m.dnn.clone(),
            m.controller.clone(),
            f2(first_grants[i]),
            f2(last_grants[i]),
            f1(m.throughput),
            f1(m.goodput),
            f2(m.p95_ms),
            m.dropped_deadline.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "granted SM total per window stays <= 1 (peak {:.2}) | rebalances rejected as clamps: {}",
        mps.peak_contention, mps.admission_clamps
    );

    // ---- Cluster: the scheduling layer above one device. ----------------
    // The same offered load (two bursty inc-v4 hogs + two light smooth
    // jobs; per-job arrival streams are seeded by job index, so every
    // placement faces IDENTICAL traffic) across two whole P40s, compared
    // under the three shipped placements. With two devices and the jobs
    // ordered hog/smooth/hog/smooth, round robin (j mod 2) co-locates
    // the two bursty hogs on device 0; the interference-aware placer
    // refuses to pair them (best-fit packs by memory alone, so it may
    // stack everything wherever it happens to fit tightest).
    println!("\nCluster: two P40s, the same bursty load, three placements compared");
    let run_placed = |placement: Box<dyn Placement>| -> Result<ClusterOutcome> {
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(3).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 4 },
                ArrivalPattern::bursty(24.0, 4.0, 2.0, 0.5),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(30.0),
            )
            .job_with_arrivals(
                paper_job(3).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 4 },
                ArrivalPattern::bursty(24.0, 4.0, 2.0, 0.5),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(30.0),
            )
            .placement(placement)
            .windows(20)
            .rounds_per_window(15)
            .seed(17)
            .build()
            .map_err(|e| anyhow!(e.to_string()))?
            .run()
            .map_err(|e| anyhow!(e.to_string()))
    };
    let mut t = Table::new(
        "Placement comparison (same jobs, same seeds, same offered load)",
        &["placement", "assignment", "total thr", "total goodput", "worst p95(ms)"],
    );
    let placers: Vec<Box<dyn Placement>> = vec![
        Box::new(RoundRobin::new()),
        Box::new(BestFit::new()),
        Box::new(InterferenceAware::new()),
    ];
    for placer in placers {
        let out = run_placed(placer)?;
        let worst_p95 = out
            .devices
            .iter()
            .flat_map(|d| d.fleet.members.iter())
            .map(|m| m.p95_ms)
            .fold(0.0f64, f64::max);
        t.row(&[
            out.placement.clone(),
            format!("{:?}", out.assignment),
            f1(out.total_throughput),
            f1(out.total_goodput),
            f1(worst_p95),
        ]);
    }
    print!("{}", t.render());
    println!(
        "round robin pairs the two bursty inc-v4 hogs on p40#0 (their joint goodput \
         collapses); interference-aware gives each hog its own device"
    );
    Ok(())
}
