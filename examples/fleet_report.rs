//! Fleet power-efficiency report (paper Table 6) and latency CDFs
//! (paper Fig. 6) for the Multi-Tenancy jobs.
//!
//! Run with: cargo run --release --example fleet_report

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::job::PAPER_JOBS;
use dnnscaler::coordinator::runner::{JobRunner, RunConfig};
use dnnscaler::coordinator::Method;
use dnnscaler::gpusim::GpuSim;
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::{Table, WeightedCdf};

fn main() -> Result<()> {
    let runner = JobRunner::new(RunConfig::windows(40, 20));
    let mut t = Table::new(
        "Power & efficiency, MT jobs (Table 6)",
        &["job", "dnn", "P_scaler(W)", "P_clipper(W)", "thr_s", "thr_c", "eff_s", "eff_c", "eff gain"],
    );
    let mut cdf_jobs: Vec<(u32, WeightedCdf, WeightedCdf, f64)> = Vec::new();
    for job in PAPER_JOBS {
        let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 300 + job.id as u64).unwrap();
        let s = runner.run_dnnscaler(job, &mut d1).map_err(|e| anyhow!(e.to_string()))?;
        if s.method != Some(Method::MultiTenancy) {
            continue;
        }
        let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 400 + job.id as u64).unwrap();
        let c = runner.run_clipper(job, &mut d2).map_err(|e| anyhow!(e.to_string()))?;
        let eff_s = s.throughput / s.power_w;
        let eff_c = c.throughput / c.power_w;
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            f1(s.power_w),
            f1(c.power_w),
            f1(s.throughput),
            f1(c.throughput),
            f2(eff_s),
            f2(eff_c),
            f2(eff_s / eff_c),
        ]);
        if [1u32, 5, 14, 29].contains(&job.id) {
            cdf_jobs.push((
                job.id,
                WeightedCdf::from_samples(&s.latencies),
                WeightedCdf::from_samples(&c.latencies),
                job.slo_ms,
            ));
        }
    }
    print!("{}", t.render());

    println!("\nLatency CDFs for four jobs (Fig. 6): p50/p90/p95/p99 in ms, SLO marked");
    for (id, mut s_cdf, mut c_cdf, slo) in cdf_jobs {
        println!("  job {id} (SLO {slo} ms)");
        for (name, cdf) in [("dnnscaler", &mut s_cdf), ("clipper", &mut c_cdf)] {
            println!(
                "    {name:<10} p50={:>8.2} p90={:>8.2} p95={:>8.2} p99={:>8.2}  frac<=SLO {:.3}",
                cdf.quantile(0.50).unwrap(),
                cdf.quantile(0.90).unwrap(),
                cdf.quantile(0.95).unwrap(),
                cdf.quantile(0.99).unwrap(),
                cdf.fraction_below(slo),
            );
        }
    }
    Ok(())
}
