//! Combining Batching and Multi-Tenancy (paper §4.6 / Fig. 12).
//!
//! The paper probes four DNNs: two batching-class networks at a constant
//! BS=8 with MTL swept 1..4, and two mobilenets at a constant MTL=5 with
//! BS swept 1..8. The finding: the mid-size networks (ResV2-152, MobV1-1)
//! can profit from the combination up to a point; the extremes
//! (PNAS-Large, MobV1-025) only pay latency.
//!
//! Part 3 cross-checks the analytic sweep against the serving loop: the
//! same combined point served through `ServingSession` with the
//! static-knob policy must land on the analytic surface.
//!
//! Run with: cargo run --release --example combined_scaling

use dnnscaler::coordinator::job::{JobSpec, SteadyKnob};
use dnnscaler::coordinator::session::{PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::Method;
use dnnscaler::gpusim::{Dataset, GpuSim};
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::Table;

fn main() {
    // Part 1: constant BS=8, sweep MTL (ResV2-152 vs PNAS-Large).
    for dnn in ["resv2-152", "pnas-large"] {
        let sim = GpuSim::for_paper_dnn(dnn, Dataset::ImageNet, 0).unwrap();
        let mut t = Table::new(
            &format!("{dnn}: BS=8 constant, MTL swept (Fig. 12 left)"),
            &["mtl", "throughput", "latency(ms)", "gain vs mtl=1"],
        );
        let base = sim.throughput(8, 1);
        for n in 1..=4u32 {
            t.row(&[
                n.to_string(),
                f1(sim.throughput(8, n)),
                f2(sim.mean_batch_latency_ms(8, n)),
                f2(sim.throughput(8, n) / base),
            ]);
        }
        print!("{}", t.render());
    }

    // Part 2: constant MTL=5, sweep BS (MobV1-1 vs MobV1-025).
    for dnn in ["mobv1-1", "mobv1-025"] {
        let sim = GpuSim::for_paper_dnn(dnn, Dataset::ImageNet, 0).unwrap();
        let mut t = Table::new(
            &format!("{dnn}: MTL=5 constant, BS swept (Fig. 12 right)"),
            &["bs", "throughput", "latency(ms)", "gain vs bs=1"],
        );
        let base = sim.throughput(1, 5);
        for bs in [1u32, 2, 4, 8] {
            t.row(&[
                bs.to_string(),
                f1(sim.throughput(bs, 5)),
                f2(sim.mean_batch_latency_ms(bs, 5)),
                f2(sim.throughput(bs, 5) / base),
            ]);
        }
        print!("{}", t.render());
    }

    // Part 3: serve a combined point through the event-driven API. The
    // static-knob policy holds (8, 2) for the whole run; the measured
    // serving throughput must match the analytic surface (modulo noise).
    println!("static-knob serving cross-check: resv2-152 at (bs=8, mtl=2)");
    let job = JobSpec {
        id: 0,
        dnn: "resv2-152",
        dataset: Dataset::ImageNet,
        slo_ms: 1e9, // no SLO pressure: we want the raw operating point
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(8),
    };
    let sim = GpuSim::for_paper_dnn("resv2-152", Dataset::ImageNet, 0).unwrap();
    let analytic = sim.throughput(8, 2);
    let out = ServingSession::builder()
        .config(RunConfig::windows(10, 20))
        .job(&job)
        .device(sim)
        .policy(PolicySpec::Static { bs: 8, mtl: 2 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    println!(
        "  served {:.1} inf/s vs analytic {:.1} inf/s ({:+.1}% — latency noise)",
        out.throughput,
        analytic,
        (out.throughput / analytic - 1.0) * 100.0
    );

    println!(
        "paper's conclusion reproduced: the mid-size networks gain from the combination \
         up to a knee; the largest (pnas-large) and smallest (mobv1-025) only gain latency."
    );
}
