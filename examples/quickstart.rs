//! Quickstart: the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Loads a real AOT-compiled model (JAX/Pallas -> HLO text -> PJRT CPU),
//! serves batched inference requests through the full DNNScaler stack
//! (Profiler -> Scaler -> event-driven `ServingSession`), and reports
//! throughput/latency. Everything here is the real request path: no
//! simulator, no python.
//!
//! Run with:
//!   make artifacts && cargo run --release --example quickstart

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::job::{JobSpec, SteadyKnob};
use dnnscaler::coordinator::session::{PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::Method;
use dnnscaler::device::real::RealDevice;
use dnnscaler::device::Device;
use dnnscaler::gpusim::Dataset;
use dnnscaler::manifest::Manifest;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let manifest = Manifest::load(&artifacts)?;
    manifest.validate()?;
    println!("manifest: {} artifacts, models {:?}", manifest.entries.len(), manifest.models());

    // --- 1. Raw runtime sanity: execute one batch of every model. -------
    println!("\n[1/3] one real PJRT execution per model:");
    for model in manifest.models() {
        let mut dev = RealDevice::open(&artifacts, &model)?;
        let t0 = std::time::Instant::now();
        let s = dev.execute_batch(1, 1).map_err(|e| anyhow!(e.to_string()))?;
        println!(
            "  {model:<10} bs=1 mtl=1 -> {:7.2} ms (incl. compile+warmup, total {:.0} ms)",
            s.latency_ms,
            t0.elapsed().as_secs_f64() * 1000.0
        );
    }

    // --- 2. Serve a latency-SLO job end to end with DNNScaler. ----------
    let model = "mobv1-025";
    println!("\n[2/3] DNNScaler serving {model} with a 50 ms p95 SLO:");
    let mut dev = RealDevice::open(&artifacts, model)?;
    let max_bs = dev.max_batch_size();
    let job = JobSpec {
        id: 0,
        dnn: "mobv1-025",
        dataset: Dataset::Synthetic,
        slo_ms: 50.0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 15,
        rounds_per_window: 10,
        max_bs,
        max_mtl: 4,
        probe_bs: max_bs,
        probe_mtl: 4,
        ..Default::default()
    };
    let out = ServingSession::builder()
        .config(cfg)
        .job(&job)
        .device(&mut dev)
        .policy(PolicySpec::DnnScaler)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    let profile = out.profile.as_ref().unwrap();
    println!(
        "  profiler: TI_B = {:.1}%  TI_MT = {:.1}%  -> {:?}",
        profile.ti_b, profile.ti_mt, profile.method
    );
    println!(
        "  steady point bs={} mtl={}  throughput {:.1} inf/s  p95 {:.2} ms  SLO attainment {:.1}%",
        out.steady_bs,
        out.steady_mtl,
        out.throughput,
        out.p95_ms,
        out.slo_attainment * 100.0
    );
    for (bs, ms) in dev.pool().compile_report() {
        println!("  compiled artifact bs={bs} once in {ms:.0} ms");
    }

    // --- 3. Trace: how the knob moved. -----------------------------------
    println!("\n[3/3] control trace (window, bs, mtl, p95 ms, throughput):");
    for r in &out.trace {
        println!(
            "  w{:02}  bs={:<3} mtl={}  p95={:8.2}  thr={:8.1}",
            r.window, r.bs, r.mtl, r.p95_ms, r.throughput
        );
    }
    println!(
        "\nquickstart OK — full stack (pallas kernel -> JAX model -> HLO -> PJRT -> coordinator) verified"
    );
    Ok(())
}
