//! Sensitivity analysis (paper §4.5, Figs. 9-10): the SLO changes at
//! runtime and DNNScaler must chase it — batch size for Inception-V4,
//! instance count for Inception-V1, in both directions. Runs through the
//! event-driven `ServingSession` with a `.slo_schedule(..)`.
//!
//! Run with: cargo run --release --example sensitivity

use anyhow::{anyhow, Result};

use dnnscaler::coordinator::job::{JobSpec, SteadyKnob};
use dnnscaler::coordinator::session::{PolicySpec, ServingSession};
use dnnscaler::coordinator::Method;
use dnnscaler::gpusim::{Dataset, GpuSim};

fn run_scenario(
    title: &str,
    dnn: &'static str,
    slo0: f64,
    schedule: Vec<(usize, f64)>,
) -> Result<()> {
    println!("== {title} ==");
    let job = JobSpec {
        id: 0,
        dnn,
        dataset: Dataset::ImageNet,
        slo_ms: slo0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let sim = GpuSim::for_paper_dnn(dnn, Dataset::ImageNet, 99).unwrap();
    let out = ServingSession::builder()
        .windows(40)
        .rounds_per_window(20)
        .slo_schedule(schedule)
        .job(&job)
        .device(sim)
        .policy(PolicySpec::DnnScaler)
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    println!("  method: {:?}", out.method.unwrap());
    let mut last = (0u32, 0u32, 0.0f64);
    for r in &out.trace {
        // Print only windows where something changed, plus every 5th.
        if (r.bs, r.mtl, r.slo_ms) != last || r.window % 5 == 0 {
            println!(
                "  w{:02}  slo={:>6.0}  bs={:<3} mtl={:<2}  p95={:>8.2}  thr={:>8.1}",
                r.window, r.slo_ms, r.bs, r.mtl, r.p95_ms, r.throughput
            );
            last = (r.bs, r.mtl, r.slo_ms);
        }
    }
    println!();
    Ok(())
}

fn main() -> Result<()> {
    // Fig. 9(a): decreasing SLO under Batching (Inception-V4).
    run_scenario("Fig 9(a): inc-v4, SLO 400 -> 150 ms at w20", "inc-v4", 400.0, vec![(20, 150.0)])?;
    // Fig. 9(b): increasing SLO under Batching.
    run_scenario("Fig 9(b): inc-v4, SLO 150 -> 400 ms at w20", "inc-v4", 150.0, vec![(20, 400.0)])?;
    // Fig. 10(a): decreasing SLO under Multi-Tenancy (Inception-V1).
    run_scenario("Fig 10(a): inc-v1, SLO 60 -> 30 ms at w20", "inc-v1", 60.0, vec![(20, 30.0)])?;
    // Fig. 10(b): increasing SLO under Multi-Tenancy.
    run_scenario("Fig 10(b): inc-v1, SLO 25 -> 60 ms at w20", "inc-v1", 25.0, vec![(20, 60.0)])?;
    println!("sensitivity OK — knobs tracked every SLO step");
    Ok(())
}
