# Top-level convenience targets.
#
#   make verify         — tier-1 checks: cargo build --release, examples,
#                         benches (incl. a fleet_scale smoke run),
#                         cargo test -q, a 2-device cluster CLI smoke
#                         run, cargo fmt --check, clippy when installed,
#                         and golden-fixture drift (see scripts/verify.sh)
#   make test-fixtures  — regenerate the golden outcome snapshots under
#                         rust/tests/fixtures/ and fail on drift vs git
#   make bench-json     — run the fleet_scale scaling bench (scheduler
#                         steps/s + fleet requests/s at M=1..256 +
#                         cluster requests/s at D=1..16) and write
#                         BENCH_hotpath.json at the repo root — the
#                         tracked perf trajectory (see docs/perf.md)

.PHONY: verify test-fixtures bench-json
verify:
	bash scripts/verify.sh

bench-json:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "bench-json: no Cargo.toml found" >&2; exit 1; fi; \
	cargo bench --bench fleet_scale --manifest-path "$$manifest" -- --json "$$(pwd)/BENCH_hotpath.json"

test-fixtures:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "test-fixtures: no Cargo.toml found" >&2; exit 1; fi; \
	REGEN_FIXTURES=1 cargo test -q --test golden --manifest-path "$$manifest"
	@if [ -n "$$(git status --porcelain -- rust/tests/fixtures)" ]; then \
		echo "test-fixtures: golden snapshots drifted (or are new) — review and commit:"; \
		git status --porcelain -- rust/tests/fixtures; \
		git --no-pager diff -- rust/tests/fixtures; \
		exit 1; \
	fi
	@echo "test-fixtures: snapshots match the checked-in baseline"
