# Top-level convenience targets.
#
#   make verify    — tier-1 checks: cargo build --release, cargo test -q,
#                    cargo fmt --check (see scripts/verify.sh)

.PHONY: verify
verify:
	bash scripts/verify.sh
