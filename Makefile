# Top-level convenience targets.
#
#   make verify         — tier-1 checks: cargo build --release, examples,
#                         benches (incl. a fleet_scale smoke run),
#                         cargo test -q, a 2-device cluster CLI smoke
#                         run, cargo fmt --check, clippy when installed,
#                         and golden-fixture drift (see scripts/verify.sh)
#   make test-fixtures  — regenerate the golden outcome snapshots under
#                         rust/tests/fixtures/ and fail on drift vs git
#   make bench-json     — run the fleet_scale scaling bench (scheduler
#                         steps/s + fleet requests/s at M=1..256 +
#                         cluster requests/s at D=1..16) and write
#                         BENCH_hotpath.json at the repo root — the
#                         tracked perf trajectory (see docs/perf.md)
#   make fuzz           — differential fuzz campaign: CASES seeded random
#                         scenarios (default 200, SEED 42) through the
#                         production engine vs the naive reference
#                         executor (see docs/testing.md)
#   make fuzz-corpus    — re-bless the committed counterexample corpus
#                         under rust/tests/fuzz_corpus/ and fail on
#                         drift vs git, like test-fixtures

CASES ?= 200
SEED ?= 42

.PHONY: verify test-fixtures bench-json fuzz fuzz-corpus
verify:
	bash scripts/verify.sh

bench-json:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "bench-json: no Cargo.toml found" >&2; exit 1; fi; \
	cargo bench --bench fleet_scale --manifest-path "$$manifest" -- --json "$$(pwd)/BENCH_hotpath.json"

fuzz:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "fuzz: no Cargo.toml found" >&2; exit 1; fi; \
	cargo run --release --manifest-path "$$manifest" -- fuzz --cases $(CASES) --seed $(SEED)

fuzz-corpus:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "fuzz-corpus: no Cargo.toml found" >&2; exit 1; fi; \
	REGEN_FUZZ_CORPUS=1 cargo test -q --test fuzz_corpus --manifest-path "$$manifest"
	@if [ -n "$$(git status --porcelain -- rust/tests/fuzz_corpus)" ]; then \
		echo "fuzz-corpus: corpus cases drifted (or are new) — review and commit:"; \
		git status --porcelain -- rust/tests/fuzz_corpus; \
		git --no-pager diff -- rust/tests/fuzz_corpus; \
		exit 1; \
	fi
	@echo "fuzz-corpus: corpus matches the checked-in baseline"

test-fixtures:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "test-fixtures: no Cargo.toml found" >&2; exit 1; fi; \
	REGEN_FIXTURES=1 cargo test -q --test golden --manifest-path "$$manifest"
	@if [ -n "$$(git status --porcelain -- rust/tests/fixtures)" ]; then \
		echo "test-fixtures: golden snapshots drifted (or are new) — review and commit:"; \
		git status --porcelain -- rust/tests/fixtures; \
		git --no-pager diff -- rust/tests/fixtures; \
		exit 1; \
	fi
	@echo "test-fixtures: snapshots match the checked-in baseline"
