# Top-level convenience targets.
#
#   make verify         — tier-1 checks: cargo build --release, examples,
#                         benches, cargo test -q, cargo fmt --check,
#                         clippy when installed, and golden-fixture drift
#                         (see scripts/verify.sh)
#   make test-fixtures  — regenerate the golden outcome snapshots under
#                         rust/tests/fixtures/ and fail on drift vs git

.PHONY: verify test-fixtures
verify:
	bash scripts/verify.sh

test-fixtures:
	@manifest=""; \
	for c in Cargo.toml rust/Cargo.toml; do \
		[ -f "$$c" ] && manifest="$$c" && break; \
	done; \
	if [ -z "$$manifest" ]; then echo "test-fixtures: no Cargo.toml found" >&2; exit 1; fi; \
	REGEN_FIXTURES=1 cargo test -q --test golden --manifest-path "$$manifest"
	@if [ -n "$$(git status --porcelain -- rust/tests/fixtures)" ]; then \
		echo "test-fixtures: golden snapshots drifted (or are new) — review and commit:"; \
		git status --porcelain -- rust/tests/fixtures; \
		git --no-pager diff -- rust/tests/fixtures; \
		exit 1; \
	fi
	@echo "test-fixtures: snapshots match the checked-in baseline"
