#!/usr/bin/env bash
# Tier-1 verification: build, test, and format-check the rust crate,
# plus the drift guards — examples and benches are compiled too (so a
# library API change that rots an example fails `make verify` instead of
# rotting silently), clippy runs with -D warnings when installed, and
# the golden outcome snapshots are regenerated and diffed against the
# checked-in baseline (make test-fixtures).
#
# Usage: scripts/verify.sh   (or `make verify`)
#
# Runs every step and exits non-zero if any failed, printing a summary of
# what ran, so CHANGES.md can record the explicit baseline of any
# still-failing seed tests.

set -u
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH — tier-1 checks cannot run in this environment." >&2
    echo "verify: install a Rust toolchain (or run in the CI image) and re-run." >&2
    exit 1
fi

# The crate may be rooted at the repo top level or under rust/ depending
# on how the workspace is assembled.
manifest=""
for c in Cargo.toml rust/Cargo.toml; do
    if [ -f "$c" ]; then
        manifest="$c"
        break
    fi
done
if [ -z "$manifest" ]; then
    echo "verify: no Cargo.toml found (looked at ./Cargo.toml and rust/Cargo.toml)." >&2
    exit 1
fi

fail=0
run_step() {
    local name="$1"
    shift
    echo "==> $name: $*"
    if "$@"; then
        echo "==> $name: OK"
    else
        echo "==> $name: FAILED" >&2
        fail=1
    fi
}

run_step "build" cargo build --release --manifest-path "$manifest"
run_step "examples" cargo build --release --examples --manifest-path "$manifest"
run_step "bench-build" cargo bench --no-run --manifest-path "$manifest"
# Smoke-run the scaling bench (M=8, tiny request budget, no file output)
# so fleet_scale — and the BENCH_hotpath.json pipeline behind `make
# bench-json` — can never rot unnoticed.
run_step "bench-smoke" cargo bench --bench fleet_scale --manifest-path "$manifest" -- --smoke
run_step "test" cargo test -q --manifest-path "$manifest"
# Cluster smoke: a tiny heterogeneous 2-physical-device run through the
# CLI, so the cluster subcommand (device specs, placement, per-device
# serving, report rendering) cannot rot unnoticed.
run_step "cluster-smoke" cargo run --release --manifest-path "$manifest" -- \
    cluster --devices p40,p40:mig2 --ids 1,5 --rates 40,20 --windows 4 \
    --placement interference
# Dynamics smoke: churn + migration + autoscaling through the CLI, so
# the warehouse-dynamics path (launch/retire events, periodic
# re-placement, threshold pool scaling, billing report) cannot rot
# unnoticed.
run_step "dynamics-smoke" cargo run --release --manifest-path "$manifest" -- \
    cluster --devices p40,p40,t4 --ids 1,5 --rates 40,20 --windows 8 \
    --churn launch:4@2:r25,retire:4@6 --migrate bestfit:3 --autoscale 1:4
# Parallel smoke: the same small cluster served serial and sharded
# across 4 worker threads must print byte-identical reports — the
# data-parallel determinism contract, checked end to end through the
# CLI (the differential test suite covers it in-process).
parallel_smoke() {
    local serial parallel rc=0
    serial="$(mktemp)" || return 1
    parallel="$(mktemp)" || return 1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,t4,t4:mig2 --ids 1,5,9,12 --rates 40,20,35,25 \
        --windows 4 --threads 1 >"$serial" || rc=1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,t4,t4:mig2 --ids 1,5,9,12 --rates 40,20,35,25 \
        --windows 4 --threads 4 >"$parallel" || rc=1
    if [ "$rc" -eq 0 ]; then
        diff -u "$serial" "$parallel" || rc=1
    fi
    rm -f "$serial" "$parallel"
    return "$rc"
}
run_step "parallel-smoke" parallel_smoke
# Faults smoke: a crash/repair cycle (plus a transient degradation)
# injected through the CLI, served serial and sharded across 4 worker
# threads — the fault report must render and the barrier-serial fault
# decisions must keep the parallel path byte-identical.
faults_smoke() {
    local serial parallel rc=0
    serial="$(mktemp)" || return 1
    parallel="$(mktemp)" || return 1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,p40,t4 --ids 1,5,9 --rates 40,20,25 \
        --windows 8 --faults crash:1@2,degrade:0@1:0.5:3,repair:1@5 \
        --threads 1 >"$serial" || rc=1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,p40,t4 --ids 1,5,9 --rates 40,20,25 \
        --windows 8 --faults crash:1@2,degrade:0@1:0.5:3,repair:1@5 \
        --threads 4 >"$parallel" || rc=1
    if [ "$rc" -eq 0 ]; then
        grep -q "faults:" "$serial" || { echo "faults-smoke: no fault report line" >&2; rc=1; }
        diff -u "$serial" "$parallel" || rc=1
    fi
    rm -f "$serial" "$parallel"
    return "$rc"
}
run_step "faults-smoke" faults_smoke
# SLO smoke: an overloaded mixed-class cluster (gold/silver/best-effort,
# combined batching+multi-tenancy search) through the CLI — the per-class
# report line must render, and class-weighted shedding/admission must
# keep the 4-thread run byte-identical to serial.
slo_smoke() {
    local serial parallel rc=0
    serial="$(mktemp)" || return 1
    parallel="$(mktemp)" || return 1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,t4 --ids 1,5,7 --rates 120,120,120 \
        --windows 6 --shed --method combined --slo-class g,s,b \
        --threads 1 >"$serial" || rc=1
    cargo run --release --manifest-path "$manifest" -- \
        cluster --devices p40,t4 --ids 1,5,7 --rates 120,120,120 \
        --windows 6 --shed --method combined --slo-class g,s,b \
        --threads 4 >"$parallel" || rc=1
    if [ "$rc" -eq 0 ]; then
        grep -q "slo:" "$serial" || { echo "slo-smoke: no per-class report line" >&2; rc=1; }
        diff -u "$serial" "$parallel" || rc=1
    fi
    rm -f "$serial" "$parallel"
    return "$rc"
}
run_step "slo-smoke" slo_smoke
# Differential-fuzz smoke: a bounded, fixed-seed campaign through the
# CLI (production engine vs the naive reference executor, snapshots
# byte-identical, audits clean). The full 200-case campaign runs in the
# test suite; this keeps the `fuzz` subcommand itself from rotting.
run_step "fuzz-smoke" cargo run --release --manifest-path "$manifest" -- \
    fuzz --cases 24 --seed 42
run_step "fmt" cargo fmt --check --manifest-path "$manifest"

# Golden-fixture drift guard: regenerate the outcome snapshots and fail
# if they no longer match the checked-in baseline (make test-fixtures).
run_step "fixtures" make test-fixtures

# Clippy is optional tooling (not in every image); when present, warnings
# are errors so lint drift cannot accumulate unnoticed.
if cargo clippy --version >/dev/null 2>&1; then
    run_step "clippy" cargo clippy --all-targets --manifest-path "$manifest" -- -D warnings
else
    echo "==> clippy: not installed, skipped"
fi

if [ "$fail" -ne 0 ]; then
    echo "verify: at least one step failed — record the baseline in CHANGES.md." >&2
fi
exit "$fail"
