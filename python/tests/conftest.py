"""Make `compile` importable whether pytest runs from python/ or the repo
root (the Makefile uses the former, the top-level CI command the latter)."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
