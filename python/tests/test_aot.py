"""AOT pipeline tests: HLO text emission, manifest schema, freshness."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as zoo


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(jnp.zeros((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_export_model_writes_artifact(tmp_path):
    entry = aot.export_model("mobv1-025", 2, str(tmp_path))
    path = tmp_path / entry["path"]
    assert path.exists()
    text = path.read_text()
    assert "ENTRY" in text
    assert entry["model"] == "mobv1-025"
    assert entry["batch_size"] == 2
    assert entry["input_shape"] == [2, 32, 32, 3]
    assert entry["output_shape"] == [2, zoo.NUM_CLASSES]
    assert entry["param_count"] > 0
    assert entry["flops_per_batch"] > 0
    assert entry["flops_per_inference"] == pytest.approx(entry["flops_per_batch"] / 2)


def test_main_writes_manifest(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--models", "mobv1-025", "--batch-sizes", "1"])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == aot.MANIFEST_VERSION
    assert manifest["num_classes"] == zoo.NUM_CLASSES
    assert len(manifest["entries"]) == 1
    e = manifest["entries"][0]
    assert (tmp_path / e["path"]).exists()


def test_main_rejects_unknown_model(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out-dir", str(tmp_path), "--models", "nope"])


def test_flops_scale_with_batch(tmp_path):
    e1 = aot.export_model("textcnn", 1, str(tmp_path))
    e4 = aot.export_model("textcnn", 4, str(tmp_path))
    # FLOPs per batch must grow with BS, sub-linearly per input: GEMM-tile
    # padding means BS=1 wastes most of the tile, so 4x the inputs costs
    # much less than 4x the FLOPs (this is the batching economics the
    # paper exploits, visible right in the lowered HLO).
    ratio = e4["flops_per_batch"] / e1["flops_per_batch"]
    assert 1.2 < ratio < 6.0


def test_repo_manifest_if_built():
    """If `make artifacts` has run, the checked manifest must be coherent."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    manifest = json.load(open(manifest_path))
    assert manifest["entries"], "manifest has no entries"
    for e in manifest["entries"]:
        assert os.path.exists(os.path.join(root, e["path"])), e["path"]
        assert e["input_shape"][0] == e["batch_size"]
