"""L2 model-zoo contract tests: shapes, determinism, spectrum ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as zoo

FAST_MODELS = ["mobv1-025", "mobv1-1", "incv1", "resv2-50", "textcnn", "deepvs", "deepspeech"]


@pytest.mark.parametrize("name", zoo.list_models())
def test_registry_entry_wellformed(name):
    spec = zoo.ZOO[name]
    assert spec.name == name
    assert spec.family in {"mobile", "incept", "resnet", "textcnn", "videonet", "speechnet"}
    assert len(spec.input_shape) in (2, 3, 4)
    assert spec.paper_analogue


@pytest.mark.parametrize("name", FAST_MODELS)
@pytest.mark.parametrize("bs", [1, 3])
def test_apply_output_contract(name, bs):
    params, apply_fn, x = zoo.build(name, bs)
    y = jax.jit(apply_fn)(params, x)
    assert y.shape == (bs, zoo.NUM_CLASSES)
    assert y.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", ["mobv1-1", "textcnn"])
def test_build_is_deterministic(name):
    p1, apply_fn, _ = zoo.build(name, 1)
    p2, _, _ = zoo.build(name, 1)
    l1 = [x for x in jax.tree_util.tree_leaves(p1) if x is not None]
    l2 = [x for x in jax.tree_util.tree_leaves(p2) if x is not None]
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_consistency():
    """Row i of a batched run equals the single-sample run (no cross-batch leakage)."""
    params, apply_fn, _ = zoo.build("mobv1-1", 4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 32, 32, 3)).astype(np.float32))
    y_batch = jax.jit(apply_fn)(params, x)
    for i in range(4):
        y_one = jax.jit(apply_fn)(params, x[i : i + 1])
        np.testing.assert_allclose(
            np.asarray(y_batch[i]), np.asarray(y_one[0]), rtol=1e-3, atol=1e-3
        )


def test_param_spectrum_ordering():
    """The zoo preserves the paper's size ordering (Table 1): mobile <
    inception-v1-class < inception-v4-class < resnet-152-class."""

    def count(name):
        p, _, _ = zoo.build(name, 1)
        return zoo.param_count(p)

    assert count("mobv1-025") < count("mobv1-1")
    assert count("mobv1-1") < count("incv4")
    assert count("incv1") < count("incv4")
    assert count("resv2-50") < count("resv2-101") < count("resv2-152")
    assert count("incv4") < count("resv2-152")


def test_param_count_handles_none_leaves():
    assert zoo.param_count({"a": jnp.zeros((2, 3)), "b": None}) == 6
    assert zoo.param_count({}) == 0


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        zoo.build("vgg-999", 1)
