"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/activations; every case asserts allclose
between the interpret-mode Pallas path and ref.py. This is the CORE
numeric signal — the same HLO the rust runtime executes comes from these
kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv2d import conv1d, conv2d, depthwise_conv2d
from compile.kernels.matmul import (
    ACTIVATIONS,
    matmul_bias_act,
    mxu_utilization_estimate,
    vmem_bytes,
)

jax.config.update("jax_enable_x64", False)

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(ACTIVATIONS),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    with_bias=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, act, dtype, with_bias, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), dtype)
    w = _rand(rng, (k, n), dtype)
    b = _rand(rng, (n,), dtype) if with_bias else None
    got = matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act_ref(x, w, b, act=act)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-2 if dtype == jnp.bfloat16 else TOL["rtol"], atol=3e-2 if dtype == jnp.bfloat16 else TOL["atol"])


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
)
def test_matmul_tile_size_invariance(m, k, n, bm, bn, bk):
    """Result must not depend on the chosen block decomposition."""
    rng = np.random.default_rng(m * 1000 + k * 100 + n)
    x = _rand(rng, (m, k), jnp.float32)
    w = _rand(rng, (k, n), jnp.float32)
    got = matmul_bias_act(x, w, None, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_bias_act_ref(x, w, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((2, 3))
    w = jnp.zeros((4, 5))
    with pytest.raises(ValueError):
        matmul_bias_act(x, w)
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.zeros((2,)), w)
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.zeros((2, 4)), w, jnp.zeros((3,)))
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.zeros((2, 4)), w, act="swish")


def test_matmul_zero_and_identity():
    x = jnp.eye(16, dtype=jnp.float32)
    w = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
    np.testing.assert_allclose(np.asarray(matmul_bias_act(x, w)), np.asarray(w), **TOL)
    z = jnp.zeros((5, 16))
    np.testing.assert_allclose(np.asarray(matmul_bias_act(z, w)), 0.0, **TOL)


def test_relu_epilogue_clamps():
    x = -jnp.ones((4, 4))
    w = jnp.ones((4, 4))
    out = matmul_bias_act(x, w, None, act="relu")
    assert float(jnp.min(out)) == 0.0


# ---------------------------------------------------------------------------
# conv kernels
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(4, 14),
    c=st.integers(1, 8),
    oc=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(n, hw, c, oc, k, stride, padding, seed):
    if padding == "VALID" and hw < k:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, hw, hw, c), jnp.float32)
    w = _rand(rng, (k, k, c, oc), jnp.float32)
    b = _rand(rng, (oc,), jnp.float32)
    got = conv2d(x, w, b, stride=(stride, stride), padding=padding, act="relu")
    want = ref.conv2d_ref(x, w, b, stride=(stride, stride), padding=padding, act="relu")
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2),
    hw=st.integers(4, 12),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_depthwise_matches_ref(n, hw, c, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, hw, hw, c), jnp.float32)
    w = _rand(rng, (3, 3, c, 1), jnp.float32)
    got = depthwise_conv2d(x, w, None, stride=(stride, stride))
    want = ref.depthwise_conv2d_ref(x, w, None, stride=(stride, stride))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    length=st.integers(5, 32),
    c=st.integers(1, 8),
    oc=st.integers(1, 8),
    k=st.sampled_from([3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv1d_matches_ref(n, length, c, oc, k, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, length, c), jnp.float32)
    w = _rand(rng, (k, c, oc), jnp.float32)
    got = conv1d(x, w, None, stride=stride)
    want = ref.conv1d_ref(x, w, None, stride=stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_conv2d_channel_mismatch_raises():
    with pytest.raises(ValueError):
        conv2d(jnp.zeros((1, 4, 4, 3)), jnp.zeros((3, 3, 5, 2)))
    with pytest.raises(ValueError):
        depthwise_conv2d(jnp.zeros((1, 4, 4, 3)), jnp.zeros((3, 3, 3, 2)))


# ---------------------------------------------------------------------------
# TPU-structure estimators (the §Perf quantities)
# ---------------------------------------------------------------------------


def test_vmem_footprint_fits_core():
    # Default 128x128x128 schedule must fit 16 MiB with double-buffering.
    assert 2 * vmem_bytes() < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    u = mxu_utilization_estimate(130, 128, 128)
    assert 0.0 < u < 1.0
    assert mxu_utilization_estimate(1, 1, 1, 128, 128, 128) < 1e-4


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 512), k=st.integers(1, 512), n=st.integers(1, 512))
def test_mxu_utilization_in_unit_interval(m, k, n):
    u = mxu_utilization_estimate(m, k, n)
    assert 0.0 < u <= 1.0
