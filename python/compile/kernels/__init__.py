"""L1: Pallas kernels for the zoo hot-spot (GEMM tile + conv mappings)."""
from . import conv2d, matmul, ref  # noqa: F401
