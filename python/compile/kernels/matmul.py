"""L1 Pallas kernel: tiled matmul + bias + activation epilogue.

This is the compute hot-spot of every model in the zoo (dense layers and
im2col'd convolutions all funnel through it), mirroring how the paper's
DNNs funnel through cuDNN GEMM kernels on the Tesla P40.

TPU adaptation of the paper's GPU hot path (DESIGN.md §4):
  * the grid is (M/bm, N/bn, K/bk) — the BlockSpecs express the HBM->VMEM
    schedule that a CUDA implementation would express with threadblocks;
  * tiles default to 128x128 to align with the MXU systolic array;
  * bias + activation are fused into the final K-step epilogue so the
    output tile never round-trips to HBM between GEMM and elementwise.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which both the pytest
oracle checks and the rust runtime execute. Real-TPU characteristics are
estimated analytically (see ``vmem_bytes`` / ``mxu_utilization_estimate``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation names supported by the fused epilogue.
ACTIVATIONS = ("none", "relu", "gelu", "tanh")

# MXU-aligned default tile sizes.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _apply_act(x: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {act!r} (supported: {ACTIVATIONS})")


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One (bm, bn) output tile; grid axis 2 walks the K dimension.

    The output block is revisited across K steps and used as the f32
    accumulator; bias + activation run once, fused on the last K step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...], act)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, default: int) -> int:
    """Shrink the default tile for small matrices (power-of-two, >= 8)."""
    if dim >= default:
        return default
    return max(8, 1 << max(3, math.ceil(math.log2(max(dim, 1)))))


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    act: str = "none",
    bm: int = 0,
    bn: int = 0,
    bk: int = 0,
) -> jax.Array:
    """``act(x @ w + b)`` via the tiled Pallas kernel.

    Args:
      x: ``[M, K]`` input (any float dtype; accumulation is f32).
      w: ``[K, N]`` weights.
      b: ``[N]`` bias, or ``None`` for no bias.
      act: one of ``ACTIVATIONS``.
      bm/bn/bk: tile-size overrides (0 = auto: 128 shrunk for small dims).

    Returns:
      ``[M, N]`` in f32.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {x.shape} @ {w.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}")
    if b is None:
        b = jnp.zeros((n,), jnp.float32)
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm = bm or _pick_block(m, DEFAULT_BM)
    bn = bn or _pick_block(n, DEFAULT_BN)
    bk = bk or _pick_block(k, DEFAULT_BK)

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b.astype(jnp.float32), (0, np_ - n)).reshape(1, np_)

    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> int:
    """Static VMEM footprint of one grid step (f32): x, w, bias, out tiles.

    Used by DESIGN.md §8 / EXPERIMENTS.md §Perf to check the schedule fits
    a TPU core's ~16 MiB VMEM with room for double-buffering.
    """
    return 4 * (bm * bk + bk * bn + bn + bm * bn)


def mxu_utilization_estimate(
    m: int, k: int, n: int, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK
) -> float:
    """Fraction of MXU work that is useful (non-padding) for an (m,k,n) GEMM.

    The MXU processes full bm x bn x bk tiles; padding rows/cols are wasted
    lanes. This is the structural utilization bound — the quantity we
    optimize in the §Perf pass (interpret-mode wallclock is *not* a proxy).
    """
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    useful = m * k * n
    issued = mp * kp * np_
    return useful / issued if issued else 0.0
