"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Every kernel in this package has a reference implementation here written
with nothing but jnp/lax primitives; pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between the
Pallas path and these oracles.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {act!r}")


def matmul_bias_act_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    act: str = "none",
) -> jax.Array:
    """Oracle for kernels.matmul.matmul_bias_act."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    return _act(out, act)


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """Oracle for kernels.conv2d.conv2d (direct lax conv, no im2col)."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b.astype(jnp.float32)
    return _act(out, act)


def depthwise_conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """Oracle for kernels.conv2d.depthwise_conv2d."""
    c = x.shape[-1]
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        jnp.transpose(w, (0, 1, 3, 2)).astype(jnp.float32),
        window_strides=stride,
        padding=padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b.astype(jnp.float32)
    return _act(out, act)


def conv1d_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """Oracle for kernels.conv2d.conv1d."""
    out = conv2d_ref(
        x[:, None, :, :],
        w[None, :, :, :],
        b,
        stride=(1, stride),
        padding=padding,
        act=act,
    )
    return out[:, 0, :, :]
