"""Convolutions lowered onto the Pallas GEMM tile (im2col mapping).

On TPU the canonical conv mapping is im2col -> MXU GEMM (the GPU analogue
is implicit-GEMM cuDNN kernels). Patch extraction is plain XLA
(``conv_general_dilated_patches``); the FLOPs-dominant contraction runs
through :func:`kernels.matmul.matmul_bias_act`, so every conv in the model
zoo exercises the L1 kernel.

Depthwise convolutions (Mobilenet family) contract only kh*kw elements per
output — far too skinny to feed a 128x128 systolic array — so they stay on
the XLA grouped-conv path, exactly as they bypass GEMM on real TPUs. The
pointwise 1x1 convs that carry ~90% of a separable block's FLOPs do go
through the Pallas tile.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .matmul import matmul_bias_act


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """NHWC conv via im2col + Pallas GEMM.

    Args:
      x: ``[N, H, W, C]``.
      w: ``[KH, KW, C, OC]``.
      b: ``[OC]`` or None.
      stride: (sh, sw).
      padding: "SAME" or "VALID".
      act: fused epilogue activation.

    Returns:
      ``[N, HO, WO, OC]`` f32.
    """
    n, h, wd, c = x.shape
    kh, kw, c2, oc = w.shape
    if c != c2:
        raise ValueError(f"channel mismatch: x{x.shape} w{w.shape}")

    # Patches arrive as [N, HO, WO, C*KH*KW] with channel-major ordering
    # (feature dim is C x KH x KW, C fastest-varying last per lax docs:
    # spatial dims unrolled with channels innermost along axis -1 ordering
    # [c, kh, kw] -> index c*kh*kw). We reorder w to match.
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    _, ho, wo, feat = patches.shape
    assert feat == c * kh * kw
    # conv_general_dilated_patches orders features as [C, KH, KW].
    w_mat = jnp.transpose(w, (2, 0, 1, 3)).reshape(c * kh * kw, oc)
    out = matmul_bias_act(patches.reshape(n * ho * wo, feat), w_mat, b, act=act)
    return out.reshape(n, ho, wo, oc)


def depthwise_conv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """NHWC depthwise conv (XLA grouped-conv path; see module docstring).

    Args:
      x: ``[N, H, W, C]``.
      w: ``[KH, KW, C, 1]`` (multiplier 1).
    """
    n, h, wd, c = x.shape
    kh, kw, c2, mult = w.shape
    if c != c2 or mult != 1:
        raise ValueError(f"bad depthwise shapes: x{x.shape} w{w.shape}")
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        jnp.transpose(w, (0, 1, 3, 2)).astype(jnp.float32),  # HWIO, I=1
        window_strides=stride,
        padding=padding,
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "gelu":
        out = jax.nn.gelu(out)
    elif act == "tanh":
        out = jnp.tanh(out)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return out


def conv1d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    act: str = "none",
) -> jax.Array:
    """1-D conv (text/speech stacks) as a H=1 2-D conv over the GEMM tile.

    Args:
      x: ``[N, L, C]``.
      w: ``[K, C, OC]``.
    """
    out = conv2d(
        x[:, None, :, :],
        w[None, :, :, :],
        b,
        stride=(1, stride),
        padding=padding,
        act=act,
    )
    return out[:, 0, :, :]
