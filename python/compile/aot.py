"""AOT pipeline: lower each (model, batch size) pair to HLO text artifacts.

This is the only place python touches the serving system: `make artifacts`
runs it once; the rust coordinator then loads `artifacts/*.hlo.txt` through
the PJRT C API and never calls back into python.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` 0.1.6 crate) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. Lowered
with ``return_tuple=True``; the rust side unwraps with ``to_tuple1()``.

Params are closed over as HLO constants (deterministic PRNG seed per model
name), so each artifact is a pure ``f(input) -> logits`` function of one
tensor — the uniform contract rust/src/runtime relies on.

Usage (from the Makefile):
    cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as zoo

# Models exported for the real-execution path (each family represented;
# the full 19-model spectrum lives in gpusim's calibrated profiles).
DEFAULT_MODELS = ["mobv1-025", "mobv1-1", "incv1", "incv4", "resv2-50", "textcnn"]
DEFAULT_BATCH_SIZES = [1, 2, 4, 8]

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is essential: the default printer
    elides weight tensors as ``constant({...})`` and the xla_extension
    0.5.1 text parser silently zero-fills them — the model would load and
    run but emit all-zero logits.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_model(name: str, batch_size: int, out_dir: str) -> dict:
    """Lower one (model, BS) pair; returns its manifest entry."""
    params, apply_fn, example = zoo.build(name, batch_size)

    def fn(x):
        return apply_fn(params, x)

    lowered = jax.jit(fn).lower(example)
    hlo = to_hlo_text(lowered)
    fname = f"{name}_bs{batch_size}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)

    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    out_shape = jax.eval_shape(fn, example)

    spec = zoo.ZOO[name]
    return {
        "model": name,
        "family": spec.family,
        "paper_analogue": spec.paper_analogue,
        "batch_size": batch_size,
        "input_shape": [batch_size, *spec.input_shape],
        "output_shape": list(out_shape.shape),
        "dtype": "f32",
        "param_count": zoo.param_count(params),
        "flops_per_batch": flops,
        "flops_per_inference": flops / batch_size if batch_size else 0.0,
        "path": fname,
    }


def main(argv: List[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=DEFAULT_MODELS)
    ap.add_argument("--batch-sizes", nargs="*", type=int, default=DEFAULT_BATCH_SIZES)
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for name in args.models:
        if name not in zoo.ZOO:
            raise SystemExit(f"unknown model {name!r}; have {zoo.list_models()}")
        for bs in args.batch_sizes:
            entry = export_model(name, bs, args.out_dir)
            entries.append(entry)
            print(
                f"exported {entry['path']:28s} params={entry['param_count']:>9d} "
                f"flops/inf={entry['flops_per_inference']:.3e}"
            )

    manifest = {
        "version": MANIFEST_VERSION,
        "num_classes": zoo.NUM_CLASSES,
        "entries": entries,
    }
    # Manifest written last: it is the Makefile's freshness stamp.
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
