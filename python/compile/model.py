"""L2: the DNN model zoo (JAX, build-time only).

The paper exercises 19 DNNs spanning the compute-vs-data-movement spectrum
(Table 1/3): tiny depthwise-separable nets (Mobilenet) that are copy/launch
bound, mid-size inception stacks, heavy residual nets (ResNetV2-152), plus
an NLP TextCNN, a video-saliency CNN and a speech RNN. We reproduce that
*spectrum* with six parameterized families sized for CPU-PJRT execution
(DESIGN.md §3: the real-execution path proves the stack composes; the
paper's GPU economics live in the rust `gpusim` substrate).

Every FLOPs-dominant contraction funnels through the L1 Pallas GEMM tile
(`kernels.matmul` / `kernels.conv2d`), mirroring how the paper's models
funnel through cuDNN GEMM.

All models are pure functions: ``init(rng) -> params``,
``apply(params, x) -> logits [N, NUM_CLASSES]`` with f32 inputs of shape
``[N, *input_shape]`` — a uniform contract the rust runtime relies on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.conv2d import conv1d, conv2d, depthwise_conv2d
from .kernels.matmul import matmul_bias_act

NUM_CLASSES = 16

# ---------------------------------------------------------------------------
# Param init helpers
# ---------------------------------------------------------------------------


def _conv_init(rng, kh, kw, cin, cout):
    k1, _ = jax.random.split(rng)
    fan_in = kh * kw * cin
    w = jax.random.normal(k1, (kh, kw, cin, cout), jnp.float32) * (2.0 / fan_in) ** 0.5
    b = jnp.zeros((cout,), jnp.float32)
    return {"w": w, "b": b}


def _dense_init(rng, cin, cout):
    k1, _ = jax.random.split(rng)
    w = jax.random.normal(k1, (cin, cout), jnp.float32) * (2.0 / cin) ** 0.5
    b = jnp.zeros((cout,), jnp.float32)
    return {"w": w, "b": b}


def _dw_init(rng, k, c):
    w = jax.random.normal(rng, (k, k, c, 1), jnp.float32) * (2.0 / (k * k)) ** 0.5
    b = jnp.zeros((c,), jnp.float32)
    return {"w": w, "b": b}


def _gap(x):
    """Global average pool NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))


def _dense(p, x, act="none"):
    return matmul_bias_act(x, p["w"], p["b"], act=act)


def _head(rng, cin):
    return _dense_init(rng, cin, NUM_CLASSES)


# ---------------------------------------------------------------------------
# Family: mobile (Mobilenet-V1/V2 analogue — copy-bound, few params)
# ---------------------------------------------------------------------------


def _ch(base: int, width: float) -> int:
    return max(4, int(base * width))


def mobile_init(rng, *, width: float, blocks: int, expand: int = 1):
    keys = jax.random.split(rng, blocks * 3 + 2)
    c0 = _ch(16, width)
    params = {"stem": _conv_init(keys[0], 3, 3, 3, c0)}
    cin = c0
    chans = [_ch(16 * (2 ** min(i // 2, 3)), width) for i in range(blocks)]
    for i, cout in enumerate(chans):
        blk = {}
        mid = cin * expand
        if expand > 1:
            blk["expand"] = _conv_init(keys[3 * i + 1], 1, 1, cin, mid)
        blk["dw"] = _dw_init(keys[3 * i + 2], 3, mid)
        blk["pw"] = _conv_init(keys[3 * i + 3], 1, 1, mid, cout)
        params[f"block{i}"] = blk
        cin = cout
    params["head"] = _head(keys[-1], cin)
    return params


def mobile_apply(params, x, *, width: float, blocks: int, expand: int = 1):
    del width
    h = conv2d(x, params["stem"]["w"], params["stem"]["b"], stride=(2, 2), act="relu")
    for i in range(blocks):
        blk = params[f"block{i}"]
        r = h
        if expand > 1:
            h = conv2d(h, blk["expand"]["w"], blk["expand"]["b"], act="relu")
        stride = (2, 2) if i % 2 == 1 else (1, 1)
        h = depthwise_conv2d(h, blk["dw"]["w"], blk["dw"]["b"], stride=stride, act="relu")
        h = conv2d(h, blk["pw"]["w"], blk["pw"]["b"], act="none")
        if expand > 1 and stride == (1, 1) and r.shape == h.shape:
            h = h + r  # inverted-residual skip (V2)
        h = jnp.maximum(h, 0.0)
    return _dense(params["head"], _gap(h))


# ---------------------------------------------------------------------------
# Family: incept (Inception-V1..V4 / [P]NASNet analogue — mixed profile)
# ---------------------------------------------------------------------------


def _incept_block_init(rng, cin, cout):
    k = jax.random.split(rng, 5)
    c4 = max(4, cout // 4)
    return {
        "b1": _conv_init(k[0], 1, 1, cin, c4),
        "b3r": _conv_init(k[1], 1, 1, cin, c4),
        "b3": _conv_init(k[2], 3, 3, c4, c4),
        "b5r": _conv_init(k[3], 1, 1, cin, c4),
        "b5": _conv_init(k[4], 3, 3, c4, c4 * 2),  # stacked-3x3 "5x5" branch
    }


def incept_init(rng, *, width: float, blocks: int):
    keys = jax.random.split(rng, blocks + 2)
    c0 = _ch(24, width)
    params = {"stem": _conv_init(keys[0], 3, 3, 3, c0)}
    cin = c0
    for i in range(blocks):
        cout = max(16, _ch(24 * (1 + i // 2), width))
        params[f"block{i}"] = _incept_block_init(keys[i + 1], cin, cout)
        c4 = max(4, cout // 4)
        cin = c4 + c4 + 2 * c4  # concat of branches
    params["head"] = _head(keys[-1], cin)
    return params


def incept_apply(params, x, *, width: float, blocks: int):
    del width
    h = conv2d(x, params["stem"]["w"], params["stem"]["b"], stride=(2, 2), act="relu")
    for i in range(blocks):
        blk = params[f"block{i}"]
        b1 = conv2d(h, blk["b1"]["w"], blk["b1"]["b"], act="relu")
        b3 = conv2d(h, blk["b3r"]["w"], blk["b3r"]["b"], act="relu")
        b3 = conv2d(b3, blk["b3"]["w"], blk["b3"]["b"], act="relu")
        b5 = conv2d(h, blk["b5r"]["w"], blk["b5r"]["b"], act="relu")
        b5 = conv2d(b5, blk["b5"]["w"], blk["b5"]["b"], act="relu")
        h = jnp.concatenate([b1, b3, b5], axis=-1)
        if i % 2 == 1:  # spatial reduction every other block
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME"
            )
    return _dense(params["head"], _gap(h))


# ---------------------------------------------------------------------------
# Family: resnet (ResNetV2-50/101/152 analogue — compute-bound, many params)
# ---------------------------------------------------------------------------


def resnet_init(rng, *, width: float, blocks: int):
    keys = jax.random.split(rng, blocks + 2)
    c0 = _ch(32, width)
    params = {"stem": _conv_init(keys[0], 3, 3, 3, c0)}
    cin = c0
    for i in range(blocks):
        cout = _ch(32 * (2 ** min(i // 3, 2)), width)
        k = jax.random.split(keys[i + 1], 4)
        mid = max(8, cout // 2)
        params[f"block{i}"] = {
            "reduce": _conv_init(k[0], 1, 1, cin, mid),
            "conv": _conv_init(k[1], 3, 3, mid, mid),
            "expand": _conv_init(k[2], 1, 1, mid, cout),
            "proj": _conv_init(k[3], 1, 1, cin, cout) if cin != cout else None,
        }
        cin = cout
    params["head"] = _head(keys[-1], cin)
    return params


def resnet_apply(params, x, *, width: float, blocks: int):
    del width
    h = conv2d(x, params["stem"]["w"], params["stem"]["b"], stride=(2, 2), act="relu")
    for i in range(blocks):
        blk = params[f"block{i}"]
        r = h
        y = conv2d(h, blk["reduce"]["w"], blk["reduce"]["b"], act="relu")
        y = conv2d(y, blk["conv"]["w"], blk["conv"]["b"], act="relu")
        y = conv2d(y, blk["expand"]["w"], blk["expand"]["b"], act="none")
        if blk["proj"] is not None:
            r = conv2d(r, blk["proj"]["w"], blk["proj"]["b"], act="none")
        h = jnp.maximum(y + r, 0.0)
    return _dense(params["head"], _gap(h))


# ---------------------------------------------------------------------------
# Family: textcnn (Kim-2014 sentence classifier — TextClassif in the paper)
# ---------------------------------------------------------------------------
# Input is pre-embedded tokens [N, L, E] (f32) so the rust side feeds plain
# float tensors; the embedding lookup is not latency-relevant here.


def textcnn_init(rng, *, seq_len: int, embed: int, filters: int):
    k = jax.random.split(rng, 5)
    return {
        "conv3": {"w": jax.random.normal(k[0], (3, embed, filters)) * 0.1, "b": jnp.zeros((filters,))},
        "conv4": {"w": jax.random.normal(k[1], (4, embed, filters)) * 0.1, "b": jnp.zeros((filters,))},
        "conv5": {"w": jax.random.normal(k[2], (5, embed, filters)) * 0.1, "b": jnp.zeros((filters,))},
        "fc": _dense_init(k[3], filters * 3, filters),
        "head": _head(k[4], filters),
    }


def textcnn_apply(params, x, *, seq_len: int, embed: int, filters: int):
    del seq_len, embed, filters
    feats = []
    for name in ("conv3", "conv4", "conv5"):
        h = conv1d(x, params[name]["w"], params[name]["b"], act="relu")
        feats.append(jnp.max(h, axis=1))  # max-over-time pooling
    h = jnp.concatenate(feats, axis=-1)
    h = _dense(params["fc"], h, act="relu")
    return _dense(params["head"], h)


# ---------------------------------------------------------------------------
# Family: videonet (DeePVS video-saliency analogue — per-frame CNN + fuse)
# ---------------------------------------------------------------------------


def videonet_init(rng, *, frames: int, size: int, width: float):
    k = jax.random.split(rng, 4)
    c0, c1 = _ch(16, width), _ch(32, width)
    return {
        "conv1": _conv_init(k[0], 3, 3, 3, c0),
        "conv2": _conv_init(k[1], 3, 3, c0, c1),
        "temporal": _dense_init(k[2], c1 * frames, c1),
        "head": _head(k[3], c1),
    }


def videonet_apply(params, x, *, frames: int, size: int, width: float):
    del width
    n = x.shape[0]
    h = x.reshape(n * frames, size, size, 3)
    h = conv2d(h, params["conv1"]["w"], params["conv1"]["b"], stride=(2, 2), act="relu")
    h = conv2d(h, params["conv2"]["w"], params["conv2"]["b"], stride=(2, 2), act="relu")
    h = _gap(h).reshape(n, -1)  # [N, frames*c1]
    h = _dense(params["temporal"], h, act="relu")
    return _dense(params["head"], h)


# ---------------------------------------------------------------------------
# Family: speechnet (DeepSpeech2 analogue — conv stack + recurrent scan)
# ---------------------------------------------------------------------------


def speechnet_init(rng, *, steps: int, feat: int, hidden: int):
    k = jax.random.split(rng, 5)
    return {
        "conv1": {"w": jax.random.normal(k[0], (5, feat, hidden)) * 0.05, "b": jnp.zeros((hidden,))},
        "conv2": {"w": jax.random.normal(k[1], (5, hidden, hidden)) * 0.05, "b": jnp.zeros((hidden,))},
        "rnn_x": _dense_init(k[2], hidden, hidden),
        "rnn_h": _dense_init(k[3], hidden, hidden),
        "head": _head(k[4], hidden),
    }


def speechnet_apply(params, x, *, steps: int, feat: int, hidden: int):
    del steps, feat
    h = conv1d(x, params["conv1"]["w"], params["conv1"]["b"], stride=2, act="relu")
    h = conv1d(h, params["conv2"]["w"], params["conv2"]["b"], stride=2, act="relu")
    n, t, c = h.shape

    # Vanilla-RNN scan over time; both projections hit the Pallas GEMM tile.
    def step(carry, xt):
        new = jnp.tanh(
            matmul_bias_act(xt, params["rnn_x"]["w"], params["rnn_x"]["b"])
            + matmul_bias_act(carry, params["rnn_h"]["w"], params["rnn_h"]["b"])
        )
        return new, None

    h0 = jnp.zeros((n, hidden), jnp.float32)
    hT, _ = jax.lax.scan(step, h0, jnp.transpose(h, (1, 0, 2)))
    return _dense(params["head"], hT)


# ---------------------------------------------------------------------------
# Zoo registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A zoo entry: name, family, per-sample input shape and builders."""

    name: str
    family: str
    input_shape: Tuple[int, ...]
    init: Callable
    apply: Callable  # apply(params, x[N,*input_shape]) -> [N, NUM_CLASSES]
    paper_analogue: str
    seed: int = 0


def _spec(name, family, input_shape, init_fn, apply_fn, analogue, **cfg) -> ModelSpec:
    return ModelSpec(
        name=name,
        family=family,
        input_shape=input_shape,
        init=functools.partial(init_fn, **cfg),
        apply=functools.partial(apply_fn, **cfg),
        paper_analogue=analogue,
    )


IMG = (32, 32, 3)

ZOO: Dict[str, ModelSpec] = {
    s.name: s
    for s in [
        _spec("mobv1-025", "mobile", IMG, mobile_init, mobile_apply,
              "Mobilenet-V1-0.25", width=0.25, blocks=4),
        _spec("mobv1-05", "mobile", IMG, mobile_init, mobile_apply,
              "Mobilenet-V1-0.5", width=0.5, blocks=4),
        _spec("mobv1-1", "mobile", IMG, mobile_init, mobile_apply,
              "Mobilenet-V1-1.0", width=1.0, blocks=4),
        _spec("mobv2-1", "mobile", IMG, mobile_init, mobile_apply,
              "Mobilenet-V2-1.0", width=1.0, blocks=4, expand=4),
        _spec("mobv2-14", "mobile", IMG, mobile_init, mobile_apply,
              "Mobilenet-V2-1.4", width=1.4, blocks=4, expand=4),
        _spec("incv1", "incept", IMG, incept_init, incept_apply,
              "Inception-V1", width=0.5, blocks=2),
        _spec("incv2", "incept", IMG, incept_init, incept_apply,
              "Inception-V2", width=0.75, blocks=3),
        _spec("incv3", "incept", IMG, incept_init, incept_apply,
              "Inception-V3", width=1.0, blocks=4),
        _spec("incv4", "incept", IMG, incept_init, incept_apply,
              "Inception-V4", width=1.5, blocks=6),
        _spec("nas-mob", "incept", IMG, incept_init, incept_apply,
              "NASNET-Mobile", width=0.5, blocks=3),
        _spec("nas-large", "incept", IMG, incept_init, incept_apply,
              "NASNET-Large", width=2.0, blocks=6),
        _spec("pnas-mob", "incept", IMG, incept_init, incept_apply,
              "PNASNET-Mobile", width=0.6, blocks=3),
        _spec("pnas-large", "incept", IMG, incept_init, incept_apply,
              "PNASNET-Large", width=2.2, blocks=6),
        _spec("resv2-50", "resnet", IMG, resnet_init, resnet_apply,
              "ResNet-V2-50", width=1.0, blocks=4),
        _spec("resv2-101", "resnet", IMG, resnet_init, resnet_apply,
              "ResNet-V2-101", width=1.0, blocks=8),
        _spec("resv2-152", "resnet", IMG, resnet_init, resnet_apply,
              "ResNet-V2-152", width=1.0, blocks=12),
        _spec("textcnn", "textcnn", (64, 32), textcnn_init, textcnn_apply,
              "TextClassif (Kim 2014)", seq_len=64, embed=32, filters=64),
        _spec("deepvs", "videonet", (4, 16, 16, 3), videonet_init, videonet_apply,
              "DeePVS", frames=4, size=16, width=1.0),
        _spec("deepspeech", "speechnet", (64, 32), speechnet_init, speechnet_apply,
              "DeepSpeech2", steps=64, feat=32, hidden=64),
    ]
}


def param_count(params) -> int:
    """Total trainable parameters in a param tree (None leaves allowed)."""
    leaves = [p for p in jax.tree_util.tree_leaves(params) if p is not None]
    return int(sum(p.size for p in leaves))


def build(name: str, batch_size: int):
    """Instantiate a zoo model: returns (params, apply_fn, example_input).

    ``apply_fn(params, x)`` is the function that gets AOT-lowered; aot.py
    closes the params over as HLO constants so the rust side only feeds the
    input tensor.
    """
    spec = ZOO[name]
    # hash() is salted per-process; use a stable digest for reproducibility.
    seed = sum(ord(c) * 31**i for i, c in enumerate(spec.name)) % (2**31)
    rng = jax.random.PRNGKey(seed + spec.seed)
    params = spec.init(rng)
    example = jnp.zeros((batch_size, *spec.input_shape), jnp.float32)
    return params, spec.apply, example


def list_models() -> List[str]:
    return sorted(ZOO)
