//! Hot-path micro-benchmarks (the §Perf deliverable, DESIGN.md §8).
//!
//! Custom harness (offline build — no criterion): each case is run with
//! adaptive iteration counts and reports ns/op plus derived rates. The
//! serving-relevant targets:
//!
//! * simulator batch execution — drives every figure regeneration, must
//!   sustain >= 1M simulated batches/s;
//! * controller decisions (batch scaler, MT scaler, clipper) — must be
//!   sub-microsecond so L3 is never the bottleneck;
//! * matrix completion — one-shot per job, budget ~ms;
//! * windowed p95 — per control window;
//! * real PJRT execution — the end-to-end request path.
//!
//! Run: cargo bench --bench hotpath   (optionally: -- sim ctrl mc window real)

use std::time::Instant;

use dnnscaler::coordinator::clipper::Clipper;
use dnnscaler::coordinator::latency::LatencyWindow;
use dnnscaler::coordinator::matcomp::LatencyLibrary;
use dnnscaler::coordinator::scaler_batching::BatchScaler;
use dnnscaler::coordinator::scaler_mt::MtScaler;
use dnnscaler::coordinator::session::{PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::Controller;
use dnnscaler::device::Device;
use dnnscaler::gpusim::{Dataset, GpuSim};
use dnnscaler::linalg::{svd, Mat};
use dnnscaler::workload::ArrivalPattern;

/// Time `f` adaptively; returns ns/op.
fn bench<F: FnMut()>(name: &str, mut f: F) -> f64 {
    // Warmup + calibration.
    let t0 = Instant::now();
    let mut calib = 0u64;
    while t0.elapsed().as_millis() < 20 {
        f();
        calib += 1;
    }
    let iters = (calib * 10).clamp(10, 5_000_000);
    let t1 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t1.elapsed().as_nanos() as f64 / iters as f64;
    let rate = 1e9 / ns;
    println!("{name:<44} {ns:>12.1} ns/op   {rate:>14.0} op/s   ({iters} iters)");
    ns
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sel: Vec<&str> = args.iter().map(|s| s.as_str()).filter(|s| !s.starts_with('-')).collect();
    let run = |name: &str| sel.is_empty() || sel.contains(&name);
    println!("{:<44} {:>15} {:>20}", "benchmark", "latency", "throughput");
    println!("{}", "-".repeat(90));

    if run("sim") {
        let mut sim = GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 1).unwrap();
        let ns = bench("gpusim: execute_batch(4, 1)", || {
            let _ = std::hint::black_box(sim.execute_batch(4, 1).unwrap());
        });
        assert!(ns < 1_000.0, "simulator step must stay under 1 us");
        let sim2 = GpuSim::for_paper_dnn("resv2-152", Dataset::ImageNet, 1).unwrap();
        bench("gpusim: analytic throughput surface (128,10)", || {
            std::hint::black_box(sim2.throughput(128, 10));
        });
        bench("gpusim: power model", || {
            std::hint::black_box(sim2.power_w(32, 4));
        });
    }

    if run("ctrl") {
        let mut bs = BatchScaler::new();
        bench("controller: BatchScaler.observe_window", || {
            std::hint::black_box(bs.observe_window(90.0, 100.0));
        });
        let mut mt = MtScaler::unseeded(5, 10);
        bench("controller: MtScaler.observe_window", || {
            std::hint::black_box(mt.observe_window(90.0, 100.0));
        });
        let mut cl = Clipper::new();
        bench("controller: Clipper.observe_window", || {
            std::hint::black_box(cl.observe_window(90.0, 100.0));
        });
    }

    if run("mc") {
        let lib = LatencyLibrary::from_paper_profiles("inc-v1", 10);
        bench("matcomp: complete 18x10 from 2 obs", || {
            std::hint::black_box(lib.complete(&[(1, 10.0), (8, 40.0)]));
        });
        let mut x = 1u64;
        let mut next = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((x >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        };
        let data: Vec<f64> = (0..18 * 10).map(|_| next()).collect();
        let m = Mat::from_rows(18, 10, &data);
        bench("linalg: jacobi SVD 18x10", || {
            std::hint::black_box(svd(&m));
        });
    }

    if run("window") {
        // Feed varying samples — a constant-valued window hits sort/select
        // degenerate fast paths and benchmarks nothing real.
        let mut w = LatencyWindow::new(20);
        let mut x = 0u64;
        for i in 0..20 {
            w.record(i as f64);
        }
        bench("latency window: record + p95 (n=20)", || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            w.record((x >> 40) as f64);
            std::hint::black_box(w.p95());
        });
        let mut w200 = LatencyWindow::new(200);
        for i in 0..200 {
            w200.record(i as f64);
        }
        bench("latency window: record + p95 (n=200)", || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            w200.record((x >> 40) as f64);
            std::hint::black_box(w200.p95());
        });
    }

    if run("e2e") {
        // End-to-end simulated job run (the figure-regeneration unit),
        // closed loop through the event-driven session.
        let job = dnnscaler::coordinator::job::paper_job(1).unwrap();
        let t0 = Instant::now();
        let mut sims = 0;
        while t0.elapsed().as_millis() < 300 {
            let d = GpuSim::for_paper_dnn(job.dnn, job.dataset, sims).unwrap();
            let out = ServingSession::builder()
                .config(RunConfig::windows(20, 20))
                .job(job)
                .device(d)
                .policy(PolicySpec::DnnScaler)
                .build()
                .unwrap()
                .run()
                .unwrap();
            std::hint::black_box(out);
            sims += 1;
        }
        let ms = t0.elapsed().as_secs_f64() * 1000.0 / sims as f64;
        println!(
            "{:<44} {:>10.2} ms/job  {:>14.1} jobs/s   ({} iters)",
            "e2e: full DNNScaler job (20x20 windows)",
            ms,
            1000.0 / ms,
            sims
        );

        // Open-loop variant: the virtual-time event loop (queue + batch
        // formation) must not become the serving bottleneck.
        let t0 = Instant::now();
        let mut runs = 0;
        while t0.elapsed().as_millis() < 300 {
            let d = GpuSim::for_paper_dnn(job.dnn, job.dataset, runs).unwrap();
            let out = ServingSession::builder()
                .config(RunConfig::windows(20, 20))
                .job(job)
                .device(d)
                .policy(PolicySpec::DnnScaler)
                .arrivals(ArrivalPattern::bursty(60.0, 3.0, 4.0, 1.0))
                .seed(runs)
                .build()
                .unwrap()
                .run()
                .unwrap();
            std::hint::black_box(out);
            runs += 1;
        }
        let ms = t0.elapsed().as_secs_f64() * 1000.0 / runs as f64;
        println!(
            "{:<44} {:>10.2} ms/job  {:>14.1} jobs/s   ({} iters)",
            "e2e: open-loop bursty session (20x20)",
            ms,
            1000.0 / ms,
            runs
        );
    }

    if run("trace") {
        // Replay the shipped Azure-Functions-style arrival log (the
        // burst-interference raw material) through the open-loop engine:
        // the event loop must replay recorded production shapes at far
        // above real time.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../data/azure_functions_sample.txt");
        match ArrivalPattern::from_trace_file(&path) {
            Ok(pattern) => {
                let n = match &pattern {
                    ArrivalPattern::Trace(ts) => ts.len(),
                    ArrivalPattern::Streamed(src) => src.len(),
                    _ => 0,
                };
                let job = dnnscaler::coordinator::job::paper_job(1).unwrap();
                let t0 = Instant::now();
                let mut runs = 0u64;
                while t0.elapsed().as_millis() < 300 {
                    let d = GpuSim::for_paper_dnn(job.dnn, job.dataset, runs).unwrap();
                    let out = ServingSession::builder()
                        .config(RunConfig::windows(60, 20))
                        .job(job)
                        .device(d)
                        .policy(PolicySpec::Static { bs: 1, mtl: 4 })
                        .arrivals(pattern.clone())
                        .seed(runs)
                        .build()
                        .unwrap()
                        .run()
                        .unwrap();
                    assert_eq!(out.arrived as usize, n, "replay must admit the whole trace");
                    std::hint::black_box(out);
                    runs += 1;
                }
                let ms = t0.elapsed().as_secs_f64() * 1000.0 / runs as f64;
                println!(
                    "{:<44} {:>10.2} ms/replay {:>12.0} req/s   ({} iters)",
                    format!("trace: azure sample ({n} arrivals, 60 s)"),
                    ms,
                    n as f64 * 1000.0 / ms,
                    runs
                );
            }
            Err(e) => println!("trace: skipped ({e})"),
        }
    }

    #[cfg(not(feature = "xla"))]
    if run("real") {
        println!("real PJRT: skipped (built without the `xla` feature)");
    }

    #[cfg(feature = "xla")]
    if run("real") {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let mut dev =
                dnnscaler::device::real::RealDevice::open(&dir, "mobv1-025").unwrap();
            // Warm the compile caches.
            let _ = dev.execute_batch(1, 1).unwrap();
            let _ = dev.execute_batch(8, 1).unwrap();
            for bs in [1u32, 8] {
                let t0 = Instant::now();
                let mut n = 0u64;
                while t0.elapsed().as_millis() < 400 {
                    std::hint::black_box(dev.execute_batch(bs, 1).unwrap());
                    n += 1;
                }
                let ms = t0.elapsed().as_secs_f64() * 1000.0 / n as f64;
                println!(
                    "{:<44} {:>10.3} ms/batch {:>12.0} inf/s   ({} iters)",
                    format!("real PJRT: mobv1-025 execute bs={bs}"),
                    ms,
                    bs as f64 * 1000.0 / ms,
                    n
                );
            }
        } else {
            println!("real PJRT: skipped (run `make artifacts`)");
        }
    }

    println!("\nhotpath done");
}
