//! Bench harness regenerating every TABLE of the paper (DESIGN.md §6):
//! Table 1 (DNN sizes), Table 4 (30-job methods + steady knobs), Table 5
//! (Profiler TI rows), Table 6 (power & efficiency).
//!
//! Run all:      cargo bench --bench tables
//! Run one:      cargo bench --bench tables -- table5

use std::io::Write as _;

use dnnscaler::coordinator::job::{paper_job, JobSpec, SteadyKnob, PAPER_JOBS};
use dnnscaler::coordinator::session::{JobOutcome, PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::{Method, Profiler};
use dnnscaler::gpusim::{paper_profile, Dataset, GpuSim};
use dnnscaler::manifest::Manifest;
use dnnscaler::metrics::report::{csv_writer, f1, f2};
use dnnscaler::metrics::Table;

/// Run one job through the event-driven session with the given policy.
fn run_with(job: &JobSpec, seed: u64, spec: PolicySpec<'static>) -> JobOutcome {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
    ServingSession::builder()
        .config(RunConfig::windows(40, 20))
        .job(job)
        .device(sim)
        .policy(spec)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let filter: Vec<&str> =
        args.iter().map(|s| s.as_str()).filter(|s| s.starts_with("table")).collect();
    let run = |name: &str| filter.is_empty() || filter.contains(&name);

    std::fs::create_dir_all("reports").ok();
    if run("table1") {
        table1();
    }
    if run("table4") {
        table4();
    }
    if run("table5") {
        table5();
    }
    if run("table6") {
        table6();
    }
    println!("\ntables done — raw rows in reports/");
}

/// Table 1: parameters & computational complexity. The paper measures the
/// TF-Slim graphs; we report (a) the calibrated simulator profiles and
/// (b) the real AOT zoo's measured params/FLOPs from the manifest.
fn table1() {
    let mut t = Table::new(
        "Table 1: DNN size spectrum (simulator profiles)",
        &["dnn", "paper params", "weights MB (sim)", "compute ms/inf (sim)"],
    );
    let paper_params = [
        ("inc-v1", "6.6 M"),
        ("inc-v4", "42.7 M"),
        ("mobv1-1", "4.2 M"),
        ("resv2-152", "60.2 M"),
    ];
    for (dnn, pp) in paper_params {
        let p = paper_profile(dnn).unwrap();
        t.row(&[dnn.into(), pp.into(), f1(p.weight_mb), f2(p.t_fl_ms * p.bsat)]);
    }
    print!("{}", t.render());

    if let Ok(m) = Manifest::load("artifacts") {
        let mut t = Table::new(
            "Table 1 (real zoo): measured params & FLOPs from the manifest",
            &["model", "analogue", "params", "MFLOP/inference (bs=1)"],
        );
        let mut w = csv_writer("reports/table1.csv", "model,params,mflop_per_inf").unwrap();
        for model in m.models() {
            let e = m.get(&model, 1).or_else(|| m.best_fit(&model, 1)).unwrap();
            writeln!(w, "{model},{},{:.3}", e.param_count, e.flops_per_inference / 1e6).unwrap();
            t.row(&[
                model.clone(),
                e.paper_analogue.clone(),
                e.param_count.to_string(),
                f2(e.flops_per_inference / 1e6),
            ]);
        }
        print!("{}", t.render());
    }
    println!();
}

/// Table 4: the 30 jobs — our method + steady knob vs the paper's.
fn table4() {
    let mut w = csv_writer(
        "reports/table4.csv",
        "job,dnn,dataset,slo_ms,method,paper_method,steady,paper_steady",
    )
    .unwrap();
    let mut t = Table::new(
        "Table 4: jobs, chosen method, steady knob (ours vs paper)",
        &["job", "dnn", "dataset", "SLO", "method", "paper", "steady", "paper steady"],
    );
    let mut hits = 0;
    for job in PAPER_JOBS {
        let s = run_with(job, 100 + job.id as u64, PolicySpec::DnnScaler);
        let m = s.method.unwrap();
        if m == job.paper_method {
            hits += 1;
        }
        let steady = match m {
            Method::Batching => format!("BS={}", s.steady_bs),
            Method::MultiTenancy => format!("MTL={}", s.steady_mtl),
        };
        let paper_steady = match job.paper_steady {
            SteadyKnob::Bs(b) => format!("BS={b}"),
            SteadyKnob::Mtl(n) => format!("MTL={n}"),
        };
        writeln!(
            w,
            "{},{},{},{},{},{},{},{}",
            job.id,
            job.dnn,
            job.dataset.name(),
            job.slo_ms,
            m.short(),
            job.paper_method.short(),
            steady,
            paper_steady
        )
        .unwrap();
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            job.dataset.name().into(),
            format!("{}", job.slo_ms),
            m.short().into(),
            job.paper_method.short().into(),
            steady,
            paper_steady,
        ]);
    }
    print!("{}", t.render());
    println!("method agreement with the paper: {hits}/30\n");
}

/// Table 5: Profiler probe rows (TI_B vs TI_MT) for the paper's
/// representative jobs, with the paper's numbers inline.
fn table5() {
    // (job, paper base, paper MTL=8, paper TI_MT, paper BS=32, paper TI_B)
    let rows: &[(u32, f64, f64, f64, f64, f64)] = &[
        (1, 118.66, 237.28, 99.96, 125.67, 5.91),
        (2, 104.46, 169.85, 62.59, 125.33, 19.97),
        (3, 36.81, 39.61, 7.63, 116.41, 216.28),
        (9, 48.49, 148.28, 205.81, 125.44, 158.70),
        (10, 103.62, 137.43, 32.63, 126.55, 22.13),
        (11, 62.75, 78.63, 25.32, 125.99, 100.79),
        (15, 102.82, 169.31, 64.67, 235.05, 128.61),
        (19, 241.14, 1050.58, 335.67, 267.84, 11.07),
        (26, 492.00, 2163.80, 339.80, 7145.89, 1352.43),
        (29, 15.46, 41.27, 166.89, 19.82, 28.16),
    ];
    let profiler = Profiler::default();
    let mut w = csv_writer(
        "reports/table5.csv",
        "job,base,mt8,ti_mt,bs32,ti_b,paper_ti_mt,paper_ti_b,winner,paper_winner",
    )
    .unwrap();
    let mut t = Table::new(
        "Table 5: Profiler probes — ours (paper) per cell",
        &["job", "base thr", "MTL=8 thr", "TI_MT %", "BS=32 thr", "TI_B %", "winner(paper)"],
    );
    let mut agree = 0;
    for &(id, pb, pmt, ptimt, pbs, ptib) in rows {
        let job = paper_job(id).unwrap();
        let mut sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 42).unwrap();
        let out = profiler.run(&mut sim).unwrap();
        let winner = out.method.short();
        let paper_winner = if ptimt > ptib { "MT" } else { "B" };
        if winner == paper_winner {
            agree += 1;
        }
        writeln!(
            w,
            "{id},{:.2},{:.2},{:.2},{:.2},{:.2},{ptimt},{ptib},{winner},{paper_winner}",
            out.thr_base, out.thr_mt, out.ti_mt, out.thr_batch, out.ti_b
        )
        .unwrap();
        t.row(&[
            id.to_string(),
            format!("{:.0} ({:.0})", out.thr_base, pb),
            format!("{:.0} ({:.0})", out.thr_mt, pmt),
            format!("{:.0} ({:.0})", out.ti_mt, ptimt),
            format!("{:.0} ({:.0})", out.thr_batch, pbs),
            format!("{:.0} ({:.0})", out.ti_b, ptib),
            format!("{winner}({paper_winner})"),
        ]);
    }
    print!("{}", t.render());
    println!("winner agreement with Table 5: {agree}/{}\n", rows.len());
}

/// Table 6: power & power efficiency for the Multi-Tenancy jobs.
fn table6() {
    // Paper's Table 6 reference values: (job, P_scaler, P_clipper,
    // thr_scaler, thr_clipper, eff_scaler, eff_clipper).
    let paper: &[(u32, f64, f64, f64, f64, f64, f64)] = &[
        (1, 87.70, 55.04, 241.62, 32.88, 2.75, 0.60),
        (2, 89.82, 57.98, 172.26, 54.81, 1.92, 0.95),
        (4, 74.96, 54.61, 1254.10, 116.08, 16.73, 2.13),
        (5, 63.04, 51.78, 1888.50, 121.57, 29.96, 2.35),
        (6, 90.58, 59.96, 415.70, 84.59, 4.59, 1.41),
        (8, 71.57, 55.74, 127.60, 44.02, 1.78, 0.79),
        (9, 73.33, 57.88, 150.60, 60.54, 2.05, 1.05),
        (10, 118.06, 64.17, 138.84, 50.63, 1.18, 0.79),
        (14, 87.74, 57.32, 239.30, 71.89, 2.73, 1.25),
        (18, 109.84, 65.80, 634.90, 144.58, 5.78, 2.20),
        (19, 75.94, 54.34, 1118.60, 151.41, 14.73, 2.79),
        (20, 63.30, 52.41, 1839.80, 200.78, 29.07, 3.83),
        (21, 90.63, 65.25, 414.50, 155.09, 4.57, 2.38),
        (29, 122.44, 86.39, 40.93, 22.51, 0.33, 0.26),
        (30, 132.19, 88.98, 40.72, 24.72, 0.31, 0.28),
    ];
    let mut w = csv_writer(
        "reports/table6.csv",
        "job,power_scaler,power_clipper,thr_scaler,thr_clipper,eff_scaler,eff_clipper,eff_gain",
    )
    .unwrap();
    let mut t = Table::new(
        "Table 6: power (W) & efficiency (inf/s/W) — ours (paper) per cell",
        &["job", "P scaler", "P clipper", "eff scaler", "eff clipper", "eff gain"],
    );
    let mut power_up = 0;
    let mut eff_up = 0;
    for &(id, pps, ppc, _pts, _ptc, pes, pec) in paper {
        let job = paper_job(id).unwrap();
        let s = run_with(job, 300 + id as u64, PolicySpec::DnnScaler);
        let c = run_with(job, 400 + id as u64, PolicySpec::Clipper);
        let (es, ec) = (s.throughput / s.power_w, c.throughput / c.power_w);
        if s.power_w > c.power_w {
            power_up += 1;
        }
        if es > ec {
            eff_up += 1;
        }
        writeln!(
            w,
            "{id},{:.2},{:.2},{:.2},{:.2},{:.3},{:.3},{:.3}",
            s.power_w, c.power_w, s.throughput, c.throughput, es, ec, es / ec
        )
        .unwrap();
        t.row(&[
            id.to_string(),
            format!("{:.0} ({:.0})", s.power_w, pps),
            format!("{:.0} ({:.0})", c.power_w, ppc),
            format!("{:.2} ({:.2})", es, pes),
            format!("{:.2} ({:.2})", ec, pec),
            f2(es / ec),
        ]);
    }
    print!("{}", t.render());
    println!(
        "shape check (paper): DNNScaler draws more power on {power_up}/15 jobs but wins efficiency on {eff_up}/15\n"
    );
}
