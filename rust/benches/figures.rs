//! Bench harness regenerating every FIGURE of the paper's evaluation
//! (DESIGN.md §6). Each `figN` prints the figure's series as a table and
//! writes the raw data to `reports/figN*.csv`.
//!
//! Run all:      cargo bench --bench figures
//! Run one:      cargo bench --bench figures -- fig5
//!
//! Shapes, not absolutes, are the acceptance criterion (DESIGN.md §7) —
//! the harness prints the paper's reference numbers next to ours where
//! the paper gives them.

use std::io::Write as _;

use dnnscaler::coordinator::job::{paper_job, JobSpec, SteadyKnob, PAPER_JOBS};
use dnnscaler::coordinator::scaler_mt::MtScaler;
use dnnscaler::coordinator::session::{JobOutcome, PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::Method;
use dnnscaler::gpusim::{Dataset, GpuSim};
use dnnscaler::metrics::report::{csv_writer, f1, f2};
use dnnscaler::metrics::{Table, WeightedCdf};

/// Run one job through the event-driven session with the given policy.
fn run_with(job: &JobSpec, cfg: RunConfig, seed: u64, spec: PolicySpec<'static>) -> JobOutcome {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
    ServingSession::builder()
        .config(cfg)
        .job(job)
        .device(sim)
        .policy(spec)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let filter: Vec<&str> =
        args.iter().map(|s| s.as_str()).filter(|s| s.starts_with("fig")).collect();
    let run = |name: &str| filter.is_empty() || filter.contains(&name);

    std::fs::create_dir_all("reports").ok();
    if run("fig1") {
        fig1();
    }
    if run("fig2") {
        fig2();
    }
    if run("fig5") {
        fig5();
    }
    if run("fig6") {
        fig6();
    }
    if run("fig7") {
        fig7();
    }
    if run("fig8") {
        fig8();
    }
    if run("fig9") {
        fig9();
    }
    if run("fig10") {
        fig10();
    }
    if run("fig11") {
        fig11();
    }
    if run("fig12") {
        fig12();
    }
    println!("\nfigures done — raw series in reports/");
}

/// Fig. 1: throughput & latency vs BS (a, c) and vs MTL (b, d) for the
/// four preliminary DNNs.
fn fig1() {
    let dnns = ["inc-v1", "inc-v4", "mobv1-1", "resv2-152"];
    let mut w = csv_writer("reports/fig1.csv", "dnn,knob,value,throughput,latency_ms").unwrap();
    let mut t = Table::new(
        "Fig 1(a,c): Batching sweep (throughput inf/s | latency ms)",
        &["bs", "inc-v1", "inc-v4", "mobv1-1", "resv2-152"],
    );
    for bs in [1u32, 2, 4, 8, 16, 32, 64, 128] {
        let mut row = vec![bs.to_string()];
        for d in dnns {
            let sim = GpuSim::for_paper_dnn(d, Dataset::ImageNet, 0).unwrap();
            let thr = sim.throughput(bs, 1);
            let lat = sim.mean_batch_latency_ms(bs, 1);
            writeln!(w, "{d},bs,{bs},{thr:.2},{lat:.2}").unwrap();
            row.push(format!("{:.0} | {:.0}", thr, lat));
        }
        t.row(&row);
    }
    print!("{}", t.render());

    let mut t = Table::new(
        "Fig 1(b,d): Multi-Tenancy sweep (throughput inf/s | latency ms)",
        &["mtl", "inc-v1", "inc-v4", "mobv1-1", "resv2-152"],
    );
    for n in 1..=8u32 {
        let mut row = vec![n.to_string()];
        for d in dnns {
            let sim = GpuSim::for_paper_dnn(d, Dataset::ImageNet, 0).unwrap();
            let thr = sim.throughput(1, n);
            let lat = sim.mean_batch_latency_ms(1, n);
            writeln!(w, "{d},mtl,{n},{thr:.2},{lat:.2}").unwrap();
            row.push(format!("{:.0} | {:.0}", thr, lat));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    println!(
        "shape check: batching gain 1->128: inc-v4 {:.1}x resv2-152 {:.1}x (paper: large), \
         inc-v1 {:.2}x mobv1-1 {:.2}x (paper: negligible)",
        gain("inc-v4", true),
        gain("resv2-152", true),
        gain("inc-v1", true),
        gain("mobv1-1", true)
    );
    println!(
        "             MT gain 1->8: inc-v1 {:.1}x mobv1-1 {:.1}x (paper: large), \
         inc-v4 {:.2}x resv2-152 {:.2}x (paper: negligible)\n",
        gain("inc-v1", false),
        gain("mobv1-1", false),
        gain("inc-v4", false),
        gain("resv2-152", false)
    );
}

fn gain(dnn: &str, batching: bool) -> f64 {
    let sim = GpuSim::for_paper_dnn(dnn, Dataset::ImageNet, 0).unwrap();
    if batching {
        sim.throughput(128, 1) / sim.throughput(1, 1)
    } else {
        sim.throughput(1, 8) / sim.throughput(1, 1)
    }
}

/// Fig. 2: SM utilization vs co-located instances for MobV1-1 and Inc-V4.
fn fig2() {
    let mut w = csv_writer("reports/fig2.csv", "dnn,mtl,sm_util").unwrap();
    let mut t =
        Table::new("Fig 2: SM utilization vs co-location", &["mtl", "mobv1-1", "inc-v4"]);
    for n in 1..=4u32 {
        let mut row = vec![n.to_string()];
        for d in ["mobv1-1", "inc-v4"] {
            let sim = GpuSim::for_paper_dnn(d, Dataset::ImageNet, 0).unwrap();
            let u = sim.sm_utilization(1, n);
            writeln!(w, "{d},{n},{u:.3}").unwrap();
            row.push(format!("{:.0}%", u * 100.0));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    println!("shape check (paper): mobilenet climbs steeply with instances; inc-v4 starts high and flattens\n");
}

/// Fig. 5: DNNScaler vs Clipper throughput on all 30 jobs.
fn fig5() {
    let mut w = csv_writer(
        "reports/fig5.csv",
        "job,dnn,method,paper_method,dnnscaler_thr,clipper_thr,speedup",
    )
    .unwrap();
    let mut t = Table::new(
        "Fig 5: throughput, DNNScaler vs Clipper (30 jobs)",
        &["job", "dnn", "method(paper)", "dnnscaler", "clipper", "speedup"],
    );
    let mut gains = Vec::new();
    let mut hits = 0;
    for job in PAPER_JOBS {
        let cfg = RunConfig::windows(40, 20);
        let s = run_with(job, cfg.clone(), 100 + job.id as u64, PolicySpec::DnnScaler);
        let c = run_with(job, cfg, 200 + job.id as u64, PolicySpec::Clipper);
        let gain = s.throughput / c.throughput;
        gains.push(gain);
        let m = s.method.unwrap();
        if m == job.paper_method {
            hits += 1;
        }
        writeln!(
            w,
            "{},{},{},{},{:.2},{:.2},{:.3}",
            job.id,
            job.dnn,
            m.short(),
            job.paper_method.short(),
            s.throughput,
            c.throughput,
            gain
        )
        .unwrap();
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            format!("{}({})", m.short(), job.paper_method.short()),
            f1(s.throughput),
            f1(c.throughput),
            f2(gain),
        ]);
    }
    print!("{}", t.render());
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    let max = gains.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "method agreement {hits}/30 | mean gain {:.2}x (paper avg 218%) | max {:.1}x (paper 14x)\n",
        mean, max
    );
}

/// Fig. 6: latency CDFs for four jobs under both systems.
fn fig6() {
    let mut w = csv_writer("reports/fig6.csv", "job,system,quantile,latency_ms").unwrap();
    for id in [1u32, 5, 14, 29] {
        let job = paper_job(id).unwrap();
        let cfg = RunConfig::windows(40, 20);
        let s = run_with(job, cfg.clone(), 300 + id as u64, PolicySpec::DnnScaler);
        let c = run_with(job, cfg, 400 + id as u64, PolicySpec::Clipper);
        println!("Fig 6, job {id} ({}, SLO {} ms):", job.dnn, job.slo_ms);
        for (sys, out) in [("dnnscaler", &s), ("clipper", &c)] {
            let mut cdf = WeightedCdf::from_samples(&out.latencies);
            for q in [0.5, 0.9, 0.95, 0.99] {
                writeln!(w, "{id},{sys},{q},{:.3}", cdf.quantile(q).unwrap()).unwrap();
            }
            println!(
                "  {sys:<10} p50 {:>8.2}  p95 {:>8.2}  p99 {:>8.2}  frac<=SLO {:.3}",
                cdf.quantile(0.5).unwrap(),
                cdf.quantile(0.95).unwrap(),
                cdf.quantile(0.99).unwrap(),
                cdf.fraction_below(job.slo_ms)
            );
        }
    }
    println!("shape check (paper): ~95% of requests at or below the SLO line for the steady system\n");
}

/// Fig. 7: batch-size convergence trace, DNNScaler vs Clipper (2 jobs).
fn fig7() {
    let mut w = csv_writer("reports/fig7.csv", "job,system,window,bs,p95_ms").unwrap();
    for id in [3u32, 12] {
        let job = paper_job(id).unwrap();
        let cfg = RunConfig::windows(25, 20);
        let s = run_with(job, cfg.clone(), 500 + id as u64, PolicySpec::DnnScaler);
        let c = run_with(job, cfg, 600 + id as u64, PolicySpec::Clipper);
        println!("Fig 7, job {id} ({}): BS trace (window: dnnscaler/clipper)", job.dnn);
        let mut s_settle = None;
        let mut c_settle = None;
        for i in 0..s.trace.len() {
            writeln!(w, "{id},dnnscaler,{i},{},{:.2}", s.trace[i].bs, s.trace[i].p95_ms).unwrap();
            writeln!(w, "{id},clipper,{i},{},{:.2}", c.trace[i].bs, c.trace[i].p95_ms).unwrap();
            if s_settle.is_none() && s.trace[i].bs == s.steady_bs {
                s_settle = Some(i);
            }
            if c_settle.is_none() && c.trace[i].bs == c.steady_bs {
                c_settle = Some(i);
            }
            if i < 14 {
                println!("  w{i:02}: {:>4} / {:>4}", s.trace[i].bs, c.trace[i].bs);
            }
        }
        println!(
            "  settled: dnnscaler w{:?} (bs={}), clipper w{:?} (bs={}) — binary search reaches the knee first",
            s_settle, s.steady_bs, c_settle, c.steady_bs
        );
    }
    println!();
}

/// Fig. 8: Multi-Tenancy traces — matrix-completion seed then AIMD trim.
fn fig8() {
    let mut w = csv_writer("reports/fig8.csv", "job,window,mtl,p95_ms,slo_ms").unwrap();
    for id in [2u32, 14] {
        let job = paper_job(id).unwrap();
        let s = run_with(job, RunConfig::windows(25, 20), 100 + id as u64, PolicySpec::DnnScaler);
        println!(
            "Fig 8, job {id} ({}, SLO {} ms): MTL trace (seeded by matrix completion at w0)",
            job.dnn, job.slo_ms
        );
        for r in s.trace.iter().take(14) {
            writeln!(w, "{id},{},{},{:.2},{}", r.window, r.mtl, r.p95_ms, r.slo_ms).unwrap();
            println!("  w{:02}: bs={:<2} mtl={:<2} p95={:>8.2}", r.window, r.bs, r.mtl, r.p95_ms);
        }
        println!("  steady mtl={} (paper: {:?})", s.steady_mtl, job.paper_steady);
    }
    println!("shape check (paper): job-2-like seeds high then trims; job-14-like rides at MTL=10\n");
}

/// Figs. 9 & 10 share the SLO-step machinery.
fn sensitivity(fig: &str, dnn: &'static str, slo0: f64, slo1: f64) {
    let job = JobSpec {
        id: 0,
        dnn,
        dataset: Dataset::ImageNet,
        slo_ms: slo0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 40,
        rounds_per_window: 20,
        slo_schedule: vec![(20, slo1)],
        ..Default::default()
    };
    let out = run_with(&job, cfg, 900, PolicySpec::DnnScaler);
    let mut w =
        csv_writer(&format!("reports/{fig}.csv"), "window,slo_ms,bs,mtl,p95_ms,throughput")
            .unwrap();
    for r in &out.trace {
        writeln!(
            w,
            "{},{},{},{},{:.2},{:.2}",
            r.window, r.slo_ms, r.bs, r.mtl, r.p95_ms, r.throughput
        )
        .unwrap();
    }
    let before = &out.trace[19];
    let after = out.trace.last().unwrap();
    println!(
        "{fig}: {dnn} SLO {slo0} -> {slo1} ms | knob before (bs={} mtl={}) after (bs={} mtl={}) | p95 after {:.1} <= {:.0}: {}",
        before.bs,
        before.mtl,
        after.bs,
        after.mtl,
        after.p95_ms,
        slo1,
        after.p95_ms <= slo1
    );
}

fn fig9() {
    sensitivity("fig9a", "inc-v4", 400.0, 150.0);
    sensitivity("fig9b", "inc-v4", 150.0, 400.0);
    println!();
}

fn fig10() {
    sensitivity("fig10a", "inc-v1", 60.0, 30.0);
    sensitivity("fig10b", "inc-v1", 25.0, 60.0);
    println!();
}

/// Fig. 11: Batching vs (forced) Multi-Tenancy on six batching jobs.
fn fig11() {
    let mut w = csv_writer("reports/fig11.csv", "job,batching_thr,mt_thr").unwrap();
    let mut t = Table::new(
        "Fig 11: Batching (DNNScaler's pick) vs forced Multi-Tenancy",
        &["job", "dnn", "batching thr", "MT thr", "batching wins"],
    );
    for id in [3u32, 7, 12, 16, 22, 28] {
        let job = paper_job(id).unwrap();
        let cfg = RunConfig::windows(30, 20);
        let s = run_with(job, cfg.clone(), 1100 + id as u64, PolicySpec::DnnScaler);
        // Force the MT scaler on the same job.
        let m = run_with(
            job,
            cfg,
            1200 + id as u64,
            PolicySpec::custom(MtScaler::unseeded(1, 10)),
        );
        writeln!(w, "{id},{:.2},{:.2}", s.throughput, m.throughput).unwrap();
        t.row(&[
            id.to_string(),
            job.dnn.into(),
            f1(s.throughput),
            f1(m.throughput),
            (s.throughput > m.throughput).to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("shape check (paper): Batching wins on every one of these jobs\n");
}

/// Fig. 12: combining Batching and Multi-Tenancy.
fn fig12() {
    let mut w = csv_writer("reports/fig12.csv", "dnn,bs,mtl,throughput,latency_ms").unwrap();
    let mut t = Table::new(
        "Fig 12 (left): BS=8 constant, MTL swept — throughput (gain vs MTL=1)",
        &["mtl", "resv2-152", "pnas-large"],
    );
    for n in 1..=4u32 {
        let mut row = vec![n.to_string()];
        for d in ["resv2-152", "pnas-large"] {
            let sim = GpuSim::for_paper_dnn(d, Dataset::ImageNet, 0).unwrap();
            let thr = sim.throughput(8, n);
            writeln!(w, "{d},8,{n},{thr:.2},{:.2}", sim.mean_batch_latency_ms(8, n)).unwrap();
            row.push(format!("{:.0} ({:.2}x)", thr, thr / sim.throughput(8, 1)));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    let mut t = Table::new(
        "Fig 12 (right): MTL=5 constant, BS swept — throughput (gain vs BS=1)",
        &["bs", "mobv1-1", "mobv1-025"],
    );
    for bs in [1u32, 2, 4, 8] {
        let mut row = vec![bs.to_string()];
        for d in ["mobv1-1", "mobv1-025"] {
            let sim = GpuSim::for_paper_dnn(d, Dataset::ImageNet, 0).unwrap();
            let thr = sim.throughput(bs, 5);
            writeln!(w, "{d},{bs},5,{thr:.2},{:.2}", sim.mean_batch_latency_ms(bs, 5)).unwrap();
            row.push(format!("{:.0} ({:.2}x)", thr, thr / sim.throughput(1, 5)));
        }
        t.row(&row);
    }
    print!("{}", t.render());
    println!(
        "shape check (paper): resv2-152 gains at MTL=2 then flattens; pnas-large loses; \
         mobv1-1 gains from batching on top of MT; mobv1-025 does not\n"
    );
}
