//! fleet_scale: the serving engine's scaling benchmark (PR 4).
//!
//! Measures the two things the zero-allocation + event-calendar refactor
//! is supposed to buy, at fleet sizes M in {1, 8, 64, 256}:
//!
//! * **scheduler steps/s** — the next-event pick, both through the
//!   retained O(M) `LinearScan` baseline and the O(log M)
//!   `EventCalendar` (the acceptance criterion: >= 5x steps/s at
//!   M = 256). Both run the identical synthetic pop/advance/re-push
//!   schedule, so the ratio isolates the scheduler;
//! * **end-to-end fleet serving** — a real open-loop `Fleet` run per M
//!   (overloaded bounded queues, full batches), reporting engine
//!   steps/s (batch rounds) and requests/s of wall time, with >= 1M
//!   simulated requests per fleet size at the default budget.
//!
//! Also times the request-queue hot pair (`push` + `take_batch_into`)
//! so a regression in the ring buffer itself is visible in isolation,
//! and (PR 5) a `cluster_scale` case: end-to-end requests/s of a
//! multi-device `Cluster`, which prices the cross-device event loop.
//! PR 6 adds a `churn_scale` case: the same cluster run through the
//! dynamic window loop (job churn + threshold autoscaling), pricing
//! warehouse dynamics against the static path. PR 7 grows
//! `cluster_scale` to D in {16, 256, 4096} whole devices (2 members
//! each) swept over worker-thread counts {1, 2, 4, 8}, reporting
//! requests/s and requests/s-per-core — the data-parallel sharding's
//! scaling curve (output is byte-identical at every thread count, so
//! only wall clock moves). PR 9 adds a `fault_churn` case: the churned
//! cluster with a crash/repair cycle and a transient degradation
//! injected, pricing the fault barrier and failover machinery. PR 10
//! adds an `slo_overload` case: mixed-class fleets (gold/silver/
//! best-effort) under the combined batching+multi-tenancy search,
//! emitting the per-class goodput split.
//!
//! Run:  cargo bench --bench fleet_scale             (report only)
//!       cargo bench --bench fleet_scale -- --json   (also write
//!                                                    BENCH_hotpath.json
//!                                                    at the repo root)
//!       cargo bench --bench fleet_scale -- --smoke  (CI smoke: M = 8,
//!                                                    tiny budget, no
//!                                                    file output)
//!
//! `make bench-json` wraps the `--json` form; the checked-in
//! BENCH_hotpath.json is the tracked perf trajectory (see docs/perf.md).

use std::collections::BTreeMap;
use std::time::Instant;

use dnnscaler::coordinator::calendar::{EventCalendar, LinearScan, NextEventQueue};
use dnnscaler::coordinator::cluster::{Cluster, RoundRobin};
use dnnscaler::coordinator::dynamics::{ChurnSchedule, ThresholdAutoscaler};
use dnnscaler::coordinator::FaultSchedule;
use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::PolicySpec;
use dnnscaler::coordinator::slo::{SloClass, SloReport};
use dnnscaler::gpusim::{GpuSpec, TESLA_P40};
use dnnscaler::json::Json;
use dnnscaler::workload::{ArrivalPattern, RequestQueue};
use dnnscaler::Fleet;

/// Synthetic scheduler workload: pop the earliest member, advance its
/// clock pseudo-randomly, re-push — the exact op sequence one fleet
/// serving round costs the scheduler. Returns steps/s.
fn sched_steps_per_s(q: &mut dyn NextEventQueue, m: usize, steps: u64) -> f64 {
    let mut t: Vec<f64> = (0..m).map(|i| (i % 7) as f64 * 1e-3).collect();
    q.clear();
    for (i, &ti) in t.iter().enumerate() {
        q.push(i, ti);
    }
    let mut x = 0x9E3779B97F4A7C15u64;
    let t0 = Instant::now();
    for _ in 0..steps {
        let k = q.pop().expect("scheduler never empties");
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        t[k] += 5e-4 + (x >> 40) as f64 * 1e-9;
        q.push(k, t[k]);
    }
    let per_s = steps as f64 / t0.elapsed().as_secs_f64();
    // Drain so repeated calls start clean.
    q.clear();
    per_s
}

struct FleetRun {
    members: usize,
    windows: usize,
    rounds_per_window: usize,
    requests_served: f64,
    steps: u64,
    wall_s: f64,
}

/// Shared scaling-bench workload: the smallest model (so big runs stay
/// fast) on a synthetic 16 TiB-memory GPU — memory admission is not the
/// subject under test here, and hundreds of members cannot fit a real
/// 24 GB card. Used identically by the fleet and cluster cases so the
/// two stay comparable.
fn bench_workload() -> (dnnscaler::JobSpec, GpuSpec) {
    let mut job = *paper_job(1).expect("paper job 1");
    job.dnn = "mobv1-025";
    (job, GpuSpec { mem_mb: 16.0 * 1024.0 * 1024.0, ..TESLA_P40 })
}

/// Rounds per window so `members` members at 8 requests/round over 8
/// windows serve roughly `request_target` requests (batches kept full
/// by overload).
fn rounds_for_target(members: u64, windows: u64, request_target: u64) -> usize {
    (request_target.div_ceil(members * windows * 8)).max(1) as usize
}

/// One overloaded open-loop fleet run at `m` members sized to serve
/// roughly `request_target` requests (full 8-request batches).
fn run_fleet(m: usize, request_target: u64) -> FleetRun {
    let (job, gpu) = bench_workload();
    let windows = 8usize;
    let rounds_per_window = rounds_for_target(m as u64, windows as u64, request_target);

    let mut b = Fleet::builder().gpu(gpu).windows(windows).rounds_per_window(rounds_per_window);
    for _ in 0..m {
        b = b
            .job_with_arrivals(
                &job,
                PolicySpec::Static { bs: 8, mtl: 1 },
                // ~10x per-member service capacity: batches stay full
                // (the round count fixes the request count) without the
                // run degenerating into pure arrival synthesis.
                ArrivalPattern::uniform(2_000.0),
            )
            .queue_capacity(1024);
    }
    let fleet = b.build().expect("fleet config");
    let t0 = Instant::now();
    let out = fleet.run().expect("fleet run");
    let wall_s = t0.elapsed().as_secs_f64();
    let requests_served: f64 =
        out.members.iter().map(|j| j.latencies.iter().map(|(_, w)| *w).sum::<f64>()).sum();
    FleetRun {
        members: m,
        windows,
        rounds_per_window,
        requests_served,
        steps: m as u64 * windows as u64 * rounds_per_window as u64,
        wall_s,
    }
}

struct ClusterRun {
    devices: usize,
    jobs: usize,
    threads: usize,
    requests_served: f64,
    wall_s: f64,
}

/// One overloaded open-loop cluster run at `d` whole devices (2 jobs
/// per device, round-robin placement) sized to serve roughly
/// `request_target` requests in total — the multi-device analogue of
/// [`run_fleet`], measuring what the D-device event loop costs at
/// `threads` shard workers (1 = the serial reference engine).
fn run_cluster(d: usize, request_target: u64, threads: usize) -> ClusterRun {
    let (job, gpu) = bench_workload();
    let jobs = 2 * d;
    let windows = 8usize;
    let rounds_per_window = rounds_for_target(jobs as u64, windows as u64, request_target);

    let mut b = Cluster::builder()
        .windows(windows)
        .rounds_per_window(rounds_per_window)
        .threads(threads)
        .placement(RoundRobin::new());
    for _ in 0..d {
        b = b.device(gpu.clone());
    }
    for _ in 0..jobs {
        b = b
            .job_with_arrivals(
                &job,
                PolicySpec::Static { bs: 8, mtl: 1 },
                ArrivalPattern::uniform(2_000.0),
            )
            .queue_capacity(1024);
    }
    let cluster = b.build().expect("cluster config");
    let t0 = Instant::now();
    let out = cluster.run().expect("cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    let requests_served: f64 = out
        .devices
        .iter()
        .flat_map(|dev| dev.fleet.members.iter())
        .map(|j| j.latencies.iter().map(|(_, w)| *w).sum::<f64>())
        .sum();
    ClusterRun { devices: d, jobs, threads, requests_served, wall_s }
}

/// One overloaded open-loop cluster run at `d` devices UNDER CHURN
/// (PR 6): two resident jobs per device plus two mid-run launches and
/// one retirement, with the threshold autoscaler free to resize the
/// pool. Prices what the dynamic window loop (membership rebuild,
/// migration checks, pool billing) costs relative to `run_cluster`.
fn run_churn(d: usize, request_target: u64) -> ClusterRun {
    let (job, gpu) = bench_workload();
    let jobs = 2 * d;
    let windows = 8usize;
    let rounds_per_window = rounds_for_target(jobs as u64, windows as u64, request_target);

    let mut launched = job;
    launched.id = 1000;
    let churn = ChurnSchedule::new()
        .launch(
            2,
            &launched,
            PolicySpec::Static { bs: 8, mtl: 1 },
            ArrivalPattern::uniform(2_000.0),
        )
        .launch(
            3,
            &launched,
            PolicySpec::Static { bs: 8, mtl: 1 },
            ArrivalPattern::uniform(2_000.0),
        )
        .retire(6, 1000);

    let mut b = Cluster::builder()
        .windows(windows)
        .rounds_per_window(rounds_per_window)
        .placement(RoundRobin::new())
        .churn(churn)
        .autoscaler(ThresholdAutoscaler::new(1, d + 1));
    for _ in 0..d {
        b = b.device(gpu.clone());
    }
    for _ in 0..jobs {
        b = b
            .job_with_arrivals(
                &job,
                PolicySpec::Static { bs: 8, mtl: 1 },
                ArrivalPattern::uniform(2_000.0),
            )
            .queue_capacity(1024);
    }
    let cluster = b.build().expect("churn cluster config");
    let t0 = Instant::now();
    let out = cluster.run().expect("churn cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    let dy = out.dynamics.as_ref().expect("dynamic run reports telemetry");
    assert!(dy.launches + dy.failed_launches == 2 && dy.retires <= 1);
    let requests_served: f64 = out
        .devices
        .iter()
        .flat_map(|dev| dev.fleet.members.iter())
        .map(|j| j.latencies.iter().map(|(_, w)| *w).sum::<f64>())
        .sum();
    ClusterRun { devices: d, jobs, threads: 1, requests_served, wall_s }
}

/// One overloaded open-loop cluster run at `d` devices under FAULTS
/// (PR 9): the `run_churn` membership pressure (one mid-run launch)
/// plus a crash/repair cycle on the last device and a transient
/// degradation of the first — pricing the fault barrier, the evacuation
/// placement, and the pending-retry queue on top of the dynamic loop.
fn run_faults(d: usize, request_target: u64) -> ClusterRun {
    let (job, gpu) = bench_workload();
    let jobs = 2 * d;
    let windows = 8usize;
    let rounds_per_window = rounds_for_target(jobs as u64, windows as u64, request_target);

    let mut launched = job;
    launched.id = 1000;
    let churn = ChurnSchedule::new().launch(
        2,
        &launched,
        PolicySpec::Static { bs: 8, mtl: 1 },
        ArrivalPattern::uniform(2_000.0),
    );
    let faults = FaultSchedule::new()
        .degrade(0, 1, 0.5, 2)
        .crash(d - 1, 3)
        .repair(d - 1, 6);

    let mut b = Cluster::builder()
        .windows(windows)
        .rounds_per_window(rounds_per_window)
        .placement(RoundRobin::new())
        .churn(churn)
        .faults(faults);
    for _ in 0..d {
        b = b.device(gpu.clone());
    }
    for _ in 0..jobs {
        b = b
            .job_with_arrivals(
                &job,
                PolicySpec::Static { bs: 8, mtl: 1 },
                ArrivalPattern::uniform(2_000.0),
            )
            .queue_capacity(1024);
    }
    let cluster = b.build().expect("fault cluster config");
    let t0 = Instant::now();
    let out = cluster.run().expect("fault cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    let dy = out.dynamics.as_ref().expect("dynamic run reports telemetry");
    let fo = dy.faults.as_ref().expect("faulty run reports fault telemetry");
    assert!(fo.crashes == 1 && fo.repairs == 1 && fo.degrades == 1);
    let requests_served: f64 = out
        .devices
        .iter()
        .flat_map(|dev| dev.fleet.members.iter())
        .map(|j| j.latencies.iter().map(|(_, w)| *w).sum::<f64>())
        .sum();
    ClusterRun { devices: d, jobs, threads: 1, requests_served, wall_s }
}

struct SloRun {
    members: usize,
    wall_s: f64,
    report: SloReport,
}

/// One overloaded mixed-class fleet run (PR 10): `m` members cycling
/// gold/silver/best-effort with deadline shedding on and the combined
/// batching + multi-tenancy search driving the knobs — pricing the
/// class-weighted shed/admission arithmetic and producing the per-class
/// goodput split that BENCH_hotpath.json tracks.
fn run_slo(m: usize, request_target: u64) -> SloRun {
    let (job, gpu) = bench_workload();
    let windows = 8usize;
    let rounds_per_window = rounds_for_target(m as u64, windows as u64, request_target);
    let classes: Vec<SloClass> = (0..m).map(|i| SloClass::ALL[i % 3]).collect();

    let mut b = Fleet::builder().gpu(gpu).windows(windows).rounds_per_window(rounds_per_window);
    for _ in 0..m {
        b = b
            .job_with_arrivals(&job, PolicySpec::Combined, ArrivalPattern::uniform(2_000.0))
            .queue_capacity(1024)
            .shed_deadline(true);
    }
    let fleet = b.slo_classes(&classes).build().expect("slo fleet config");
    let t0 = Instant::now();
    let out = fleet.run().expect("slo fleet run");
    let wall_s = t0.elapsed().as_secs_f64();
    let report = out.slo.expect("classed run reports per-class stats");
    SloRun { members: m, wall_s, report }
}

/// Steady-state queue hot pair: push + take_batch_into over a warmed
/// ring (zero allocations). Returns ops/s (one op = 8 pushes + 1 drain).
fn queue_ops_per_s(iters: u64) -> f64 {
    let mut q = RequestQueue::bounded(64);
    let mut scratch = Vec::with_capacity(8);
    for i in 0..64 {
        let _ = q.push(i as f64);
    }
    let t0 = Instant::now();
    for i in 0..iters {
        q.take_batch_into(8, &mut scratch);
        for k in 0..8u64 {
            let _ = q.push((i * 8 + k) as f64 * 1e-6);
        }
        std::hint::black_box(&scratch);
    }
    iters as f64 / t0.elapsed().as_secs_f64()
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_out: Option<String> = args.iter().position(|a| a == "--json").map(|i| {
        args.get(i + 1).filter(|p| !p.starts_with('-')).cloned().unwrap_or_else(|| {
            // Default: BENCH_hotpath.json at the repo root. The crate
            // manifest may live at rust/ or at the root itself; pick the
            // directory that holds ROADMAP.md.
            let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
            let root = if manifest.join("ROADMAP.md").exists() {
                manifest.to_path_buf()
            } else {
                manifest.join("..")
            };
            root.join("BENCH_hotpath.json").to_string_lossy().into_owned()
        })
    });

    let member_counts: &[usize] = if smoke { &[8] } else { &[1, 8, 64, 256] };
    let sched_steps: u64 = if smoke { 20_000 } else { 2_000_000 };
    let request_target: u64 = if smoke { 20_000 } else { 1_000_000 };

    println!(
        "{:<10} {:>16} {:>16} {:>9} {:>14} {:>14} {:>10}",
        "members",
        "linear steps/s",
        "calendar steps/s",
        "speedup",
        "fleet steps/s",
        "requests/s",
        "requests"
    );
    println!("{}", "-".repeat(96));

    let mut per_m: Vec<Json> = Vec::new();
    for &m in member_counts {
        let mut lin = LinearScan::with_capacity(m);
        let mut cal = EventCalendar::with_capacity(m);
        let linear = sched_steps_per_s(&mut lin, m, sched_steps);
        let calendar = sched_steps_per_s(&mut cal, m, sched_steps);
        let speedup = calendar / linear;
        let fleet = run_fleet(m, request_target);
        let fleet_steps_per_s = fleet.steps as f64 / fleet.wall_s;
        let requests_per_s = fleet.requests_served / fleet.wall_s;
        println!(
            "{:<10} {:>16.0} {:>16.0} {:>8.1}x {:>14.0} {:>14.0} {:>10.0}",
            m, linear, calendar, speedup, fleet_steps_per_s, requests_per_s, fleet.requests_served
        );
        let mut o = BTreeMap::new();
        o.insert("members".into(), num(m as f64));
        o.insert("sched_linear_steps_per_s".into(), num(linear));
        o.insert("sched_calendar_steps_per_s".into(), num(calendar));
        o.insert("sched_speedup".into(), num(speedup));
        o.insert("fleet_windows".into(), num(fleet.windows as f64));
        o.insert("fleet_rounds_per_window".into(), num(fleet.rounds_per_window as f64));
        o.insert("fleet_steps".into(), num(fleet.steps as f64));
        o.insert("fleet_wall_s".into(), num(fleet.wall_s));
        o.insert("fleet_steps_per_s".into(), num(fleet_steps_per_s));
        o.insert("fleet_requests_served".into(), num(fleet.requests_served));
        o.insert("fleet_requests_per_s".into(), num(requests_per_s));
        per_m.push(Json::Obj(o));
        assert!(fleet.requests_served > 0.0, "fleet served nothing at M={m}");
        if smoke {
            // The smoke run exists so CI notices when the bench rots;
            // keep its own sanity check strict but cheap.
            assert!(
                fleet.requests_served as u64 >= request_target / 2,
                "smoke fleet under-served: {}",
                fleet.requests_served
            );
        }
    }

    // Cluster scaling: requests/s at D devices (2 members per device,
    // round-robin placement, same overloaded per-member workload),
    // swept over worker-thread counts — the data-parallel scaling
    // curve. requests/s-per-core divides by the thread count, so a
    // perfectly scaling shard keeps the per-core number flat.
    let device_counts: &[usize] = if smoke { &[2] } else { &[16, 256, 4096] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let cluster_target: u64 = if smoke { 20_000 } else { 1_000_000 };
    println!(
        "\n{:<10} {:>6} {:>8} {:>14} {:>14} {:>16} {:>10}",
        "devices", "jobs", "threads", "wall_s", "requests/s", "req/s/core", "requests"
    );
    println!("{}", "-".repeat(88));
    let mut per_d: Vec<Json> = Vec::new();
    for &d in device_counts {
        for &t in thread_counts {
            let run = run_cluster(d, cluster_target, t);
            let requests_per_s = run.requests_served / run.wall_s;
            let per_core = requests_per_s / run.threads as f64;
            println!(
                "{:<10} {:>6} {:>8} {:>14.3} {:>14.0} {:>16.0} {:>10.0}",
                run.devices, run.jobs, run.threads, run.wall_s, requests_per_s, per_core,
                run.requests_served
            );
            assert!(run.requests_served > 0.0, "cluster served nothing at D={d} T={t}");
            let mut o = BTreeMap::new();
            o.insert("devices".into(), num(run.devices as f64));
            o.insert("jobs".into(), num(run.jobs as f64));
            o.insert("threads".into(), num(run.threads as f64));
            o.insert("wall_s".into(), num(run.wall_s));
            o.insert("requests_served".into(), num(run.requests_served));
            o.insert("requests_per_s".into(), num(requests_per_s));
            o.insert("requests_per_s_per_core".into(), num(per_core));
            per_d.push(Json::Obj(o));
        }
    }

    // Churn scaling: the same cluster workload through the dynamic
    // window loop (launches, a retirement, threshold autoscaling) —
    // what warehouse dynamics cost on top of the static path. Kept at
    // its PR 6 sizes so the tracked trajectory stays comparable.
    let churn_counts: &[usize] = if smoke { &[2] } else { &[1, 4, 16] };
    println!(
        "\n{:<10} {:>6} {:>14} {:>14} {:>10}   (under churn + autoscale)",
        "devices", "jobs", "wall_s", "requests/s", "requests"
    );
    println!("{}", "-".repeat(90));
    let mut per_c: Vec<Json> = Vec::new();
    for &d in churn_counts {
        let run = run_churn(d, cluster_target);
        let requests_per_s = run.requests_served / run.wall_s;
        println!(
            "{:<10} {:>6} {:>14.3} {:>14.0} {:>10.0}",
            run.devices, run.jobs, run.wall_s, requests_per_s, run.requests_served
        );
        assert!(run.requests_served > 0.0, "churn cluster served nothing at D={d}");
        let mut o = BTreeMap::new();
        o.insert("devices".into(), num(run.devices as f64));
        o.insert("jobs".into(), num(run.jobs as f64));
        o.insert("wall_s".into(), num(run.wall_s));
        o.insert("requests_served".into(), num(run.requests_served));
        o.insert("requests_per_s".into(), num(requests_per_s));
        per_c.push(Json::Obj(o));
    }

    // Fault scaling: the churned cluster with a crash/repair cycle and
    // a transient degradation injected — what detection, evacuation,
    // and pending retries cost on top of plain warehouse dynamics.
    let fault_counts: &[usize] = if smoke { &[2] } else { &[1, 4, 16] };
    println!(
        "\n{:<10} {:>6} {:>14} {:>14} {:>10}   (under churn + faults)",
        "devices", "jobs", "wall_s", "requests/s", "requests"
    );
    println!("{}", "-".repeat(90));
    let mut per_f: Vec<Json> = Vec::new();
    for &d in fault_counts {
        let run = run_faults(d, cluster_target);
        let requests_per_s = run.requests_served / run.wall_s;
        println!(
            "{:<10} {:>6} {:>14.3} {:>14.0} {:>10.0}",
            run.devices, run.jobs, run.wall_s, requests_per_s, run.requests_served
        );
        assert!(run.requests_served > 0.0, "fault cluster served nothing at D={d}");
        let mut o = BTreeMap::new();
        o.insert("devices".into(), num(run.devices as f64));
        o.insert("jobs".into(), num(run.jobs as f64));
        o.insert("wall_s".into(), num(run.wall_s));
        o.insert("requests_served".into(), num(run.requests_served));
        o.insert("requests_per_s".into(), num(requests_per_s));
        per_f.push(Json::Obj(o));
    }

    // SLO overload: mixed-class fleets under the combined search — the
    // per-class goodput split under class-weighted shedding, tracked so
    // a regression in the SLO arithmetic (or its cost) is visible.
    let slo_counts: &[usize] = if smoke { &[3] } else { &[3, 12, 48] };
    println!(
        "\n{:<10} {:>14} {:>14} {:>14} {:>14}   (mixed classes, combined)",
        "members", "wall_s", "gold inf/s", "silver inf/s", "b-eff inf/s"
    );
    println!("{}", "-".repeat(92));
    let mut per_s: Vec<Json> = Vec::new();
    for &m in slo_counts {
        let run = run_slo(m, cluster_target);
        let g = run.report.class(SloClass::Gold);
        let s = run.report.class(SloClass::Silver);
        let be = run.report.class(SloClass::BestEffort);
        println!(
            "{:<10} {:>14.3} {:>14.1} {:>14.1} {:>14.1}",
            run.members, run.wall_s, g.goodput, s.goodput, be.goodput
        );
        assert!(
            g.goodput + s.goodput + be.goodput > 0.0,
            "slo fleet served nothing at M={m}"
        );
        let mut o = BTreeMap::new();
        o.insert("members".into(), num(run.members as f64));
        o.insert("wall_s".into(), num(run.wall_s));
        o.insert("gold_goodput".into(), num(g.goodput));
        o.insert("silver_goodput".into(), num(s.goodput));
        o.insert("best_effort_goodput".into(), num(be.goodput));
        o.insert("gold_shed".into(), num(g.shed as f64));
        o.insert("silver_shed".into(), num(s.shed as f64));
        o.insert("best_effort_shed".into(), num(be.shed as f64));
        per_s.push(Json::Obj(o));
    }

    let queue_ops = queue_ops_per_s(if smoke { 50_000 } else { 2_000_000 });
    println!("\nqueue: push x8 + take_batch_into(8)  {queue_ops:>14.0} ops/s");

    if smoke {
        println!("\nfleet_scale smoke OK");
        return;
    }

    if let Some(path) = json_out {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("fleet_scale".into()));
        root.insert("request_target".into(), num(request_target as f64));
        root.insert("sched_steps".into(), num(sched_steps as f64));
        root.insert("queue_hot_pair_ops_per_s".into(), num(queue_ops));
        root.insert("per_member_count".into(), Json::Arr(per_m));
        root.insert("cluster_scale".into(), Json::Arr(per_d));
        root.insert("churn_scale".into(), Json::Arr(per_c));
        root.insert("fault_churn".into(), Json::Arr(per_f));
        root.insert("slo_overload".into(), Json::Arr(per_s));
        let text = dnnscaler::json::write(&Json::Obj(root));
        std::fs::write(&path, text + "\n").expect("write BENCH_hotpath.json");
        println!("\nwrote {path}");
    }

    println!("\nfleet_scale done");
}
