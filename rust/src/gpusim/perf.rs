//! The mechanistic latency/throughput model (see module docs in `mod.rs`).
//!
//! Per-batch latency for one instance, with `n` instances co-located and
//! batch size `b` each (`ds` = dataset prep multiplier):
//!
//! ```text
//! d(b)     = min(1, r1 * (1 + (b-1)/bsat))              instance SM residency
//! c(b)     = t_fl * max(b, bsat) * ds_c                 compute roofline
//! gpu(b,n) = (t_gpu_fixed + c(b) * max(1, n*d(b))) * (1 + kappa*(n-1))
//! cpu(b)   = b * t_prep * ds * (1 + prep_growth * b)    per-input prep/copy
//! T(b,n)   = cpu(b) + gpu(b,n)
//! ```
//!
//! Throughput = `n*b / T(b,n)`. The shapes this produces are exactly the
//! paper's Fig. 1: prep-bound DNNs have flat throughput in `b` (batching
//! useless) but scale with `n` until `n*r1 > 1`; compute-roofline DNNs
//! with large `bsat` get near-linear batching gains but time-share under
//! co-location (`max(1, n*d)` kicks in immediately because `d(1)=r1~1`).

use super::profiles::{dataset_multiplier, Dataset, DnnProfile};

/// An operating point of the serving system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperatingPoint {
    pub batch_size: u32,
    pub mtl: u32,
}

/// Latency decomposition of one batch at an operating point (all ms).
#[derive(Debug, Clone, Copy)]
pub struct PerfBreakdown {
    /// CPU prep + H2D copy time.
    pub cpu_ms: f64,
    /// GPU-side time including co-location sharing and interference.
    pub gpu_ms: f64,
    /// End-to-end per-batch latency (`cpu + gpu`).
    pub total_ms: f64,
    /// This instance's SM residency at the batch size, 0..1.
    pub residency: f64,
    /// Aggregate SM demand `n * d(b)` (may exceed 1 = time-sharing).
    pub sm_demand: f64,
}

/// Instance SM residency at batch size `b`.
pub fn residency(p: &DnnProfile, b: u32) -> f64 {
    let b = b as f64;
    (p.r1 * (1.0 + (b - 1.0) / p.bsat)).min(1.0)
}

/// Compute-roofline time (ms) of one batch executed alone.
pub fn compute_ms(p: &DnnProfile, ds: Dataset, b: u32) -> f64 {
    let seq_mult = match ds {
        // Sequence datasets scale compute with input length too.
        Dataset::ImdbReviews | Dataset::Dhf1k => dataset_multiplier(ds),
        _ => 1.0,
    };
    p.t_fl_ms * (b as f64).max(p.bsat) * seq_mult
}

/// Full per-batch latency breakdown at `(b, n)` on the whole GPU.
pub fn batch_latency_ms(p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> PerfBreakdown {
    batch_latency_ms_granted(p, ds, b, n, 1.0)
}

/// Per-batch latency breakdown at `(b, n)` inside a spatial SM partition
/// of fraction `grant` (MPS fractional provisioning / a MIG slice
/// bundle). The member's `n` instances live entirely inside its grant:
///
/// ```text
/// gpu(b, n, g) = (t_gpu_fixed + c(b) * max(1, n*d(b)/g)) * (1 + kappa*(n-1))
/// ```
///
/// Squeezing demand `n*d(b)` into `g` of the SMs covers both spatial
/// effects at once: an instance wider than its partition (`d > g`) slows
/// by `d/g`, and instances time-share *within* the partition once their
/// combined demand exceeds it — but never with their neighbours, which
/// is exactly what distinguishes MPS/MIG from time-sharing. CPU prep and
/// H2D copy are host-side and unaffected by the SM grant. `grant = 1`
/// reproduces [`batch_latency_ms`] bit for bit (division by 1.0 is
/// exact), which is what lets `TimeShare` fleets stay byte-identical.
pub fn batch_latency_ms_granted(
    p: &DnnProfile,
    ds: Dataset,
    b: u32,
    n: u32,
    grant: f64,
) -> PerfBreakdown {
    assert!(b >= 1 && n >= 1, "operating point must be >= (1,1)");
    assert!(
        grant.is_finite() && grant > 0.0 && grant <= 1.0,
        "SM grant must be in (0, 1], got {grant}"
    );
    let bf = b as f64;
    let nf = n as f64;
    let mult = dataset_multiplier(ds);

    // Superlinear prep growth saturates around BS=32 (host-side resize
    // queues stop degrading once full): without the cap, mobilenet
    // throughput would *fall* 2x by BS=128, where the paper's Fig. 1
    // shows a flat curve.
    let cpu_ms = bf * p.t_prep_ms * mult * (1.0 + p.prep_growth * bf.min(32.0));
    let d = residency(p, b);
    let sm_demand = nf * d;
    let sharing = (sm_demand / grant).max(1.0);
    let interference = 1.0 + p.kappa * (nf - 1.0);
    let gpu_ms = (p.t_gpu_fixed_ms + compute_ms(p, ds, b) * sharing) * interference;

    PerfBreakdown { cpu_ms, gpu_ms, total_ms: cpu_ms + gpu_ms, residency: d, sm_demand }
}

/// Steady-state throughput (inferences/s) at `(b, n)`.
pub fn throughput(p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> f64 {
    let t = batch_latency_ms(p, ds, b, n).total_ms;
    (n as f64) * (b as f64) / (t / 1000.0)
}

/// nvidia-smi-style SM utilization: busy fraction weighted by residency.
///
/// One instance keeps the GPU "busy" for its gpu-time share of the batch
/// interval; co-located instances stack until the device saturates
/// (Fig. 2 of the paper: Mobilenet climbs ~linearly with instances,
/// Inception-V4 starts high and flattens).
pub fn sm_utilization(p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> f64 {
    sm_utilization_granted(p, ds, b, n, 1.0)
}

/// SM utilization of a member confined to an SM partition of fraction
/// `grant`: the member can never occupy (or report) more than its own
/// share of the device. `grant = 1` reproduces [`sm_utilization`].
pub fn sm_utilization_granted(p: &DnnProfile, ds: Dataset, b: u32, n: u32, grant: f64) -> f64 {
    let bd = batch_latency_ms_granted(p, ds, b, n, grant);
    let own_gpu_ms = p.t_gpu_fixed_ms + compute_ms(p, ds, b);
    let busy = ((n as f64) * own_gpu_ms / bd.total_ms).min(1.0);
    let occupancy = bd.sm_demand.min(grant);
    // Busy-time fraction dominates what nvidia-smi reports; occupancy
    // softens it for very sparse instances.
    (busy * (0.35 + 0.65 * occupancy)).min(grant)
}

/// GPU memory demand (MB) at `(b, n)`.
pub fn mem_demand_mb(p: &DnnProfile, b: u32, n: u32) -> f64 {
    (n as f64) * (p.mem_mb + p.act_mb * (b as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::paper_profile;

    fn close_pct(got: f64, want: f64, pct: f64) -> bool {
        (got - want).abs() / want <= pct / 100.0
    }

    /// The Table 5 calibration anchors must hold within a tolerance band.
    /// We check ordering exactly and magnitudes within 40% — the paper's
    /// own numbers carry run-to-run noise, and DESIGN.md §7 binds us to
    /// shapes, not absolutes.
    #[test]
    fn table5_anchor_bands() {
        let cases: &[(&str, Dataset, f64, f64, f64)] = &[
            // (dnn, ds, base thr, thr at MTL=8, thr at BS=32)
            ("inc-v1", Dataset::ImageNet, 118.66, 237.28, 125.67),
            ("inc-v2", Dataset::ImageNet, 104.46, 169.85, 125.33),
            ("inc-v4", Dataset::ImageNet, 36.81, 39.61, 116.41),
            ("pnas-mob", Dataset::ImageNet, 48.49, 148.28, 125.44),
            ("resv2-50", Dataset::ImageNet, 103.62, 137.43, 126.55),
            ("resv2-101", Dataset::ImageNet, 62.75, 78.63, 125.99),
            ("mobv1-05", Dataset::Caltech256, 241.14, 1050.58, 267.84),
            ("textclassif", Dataset::Sentiment140, 492.0, 2163.8, 7145.89),
            ("deepvs", Dataset::Ledov, 15.46, 41.27, 19.82),
        ];
        for &(name, ds, base, mt8, bs32) in cases {
            let p = paper_profile(name).unwrap();
            let got_base = throughput(&p, ds, 1, 1);
            let got_mt8 = throughput(&p, ds, 1, 8);
            let got_bs32 = throughput(&p, ds, 32, 1);
            assert!(close_pct(got_base, base, 40.0), "{name} base: got {got_base:.1} want {base}");
            assert!(close_pct(got_mt8, mt8, 40.0), "{name} mt8: got {got_mt8:.1} want {mt8}");
            assert!(close_pct(got_bs32, bs32, 40.0), "{name} bs32: got {got_bs32:.1} want {bs32}");
            // The decisive comparison (Eq. 5) must match the paper exactly.
            let ti_mt = (mt8 - base) / base;
            let ti_b = (bs32 - base) / base;
            let got_ti_mt = (got_mt8 - got_base) / got_base;
            let got_ti_b = (got_bs32 - got_base) / got_base;
            assert_eq!(
                ti_mt > ti_b,
                got_ti_mt > got_ti_b,
                "{name}: method decision flipped (paper TI_MT={ti_mt:.2} TI_B={ti_b:.2}, \
                 got TI_MT={got_ti_mt:.2} TI_B={got_ti_b:.2})"
            );
        }
    }

    #[test]
    fn fig1_shapes() {
        // Batching helps inc-v4/resv2-152 a lot, inc-v1/mobv1-1 barely.
        for (name, min_gain) in [("inc-v4", 3.0), ("resv2-152", 3.0)] {
            let p = paper_profile(name).unwrap();
            let gain = throughput(&p, Dataset::ImageNet, 128, 1)
                / throughput(&p, Dataset::ImageNet, 1, 1);
            assert!(gain > min_gain, "{name} batching gain {gain:.2} < {min_gain}");
        }
        for name in ["inc-v1", "mobv1-1"] {
            let p = paper_profile(name).unwrap();
            let gain = throughput(&p, Dataset::ImageNet, 128, 1)
                / throughput(&p, Dataset::ImageNet, 1, 1);
            assert!(gain < 1.6, "{name} batching gain {gain:.2} should be small");
        }
        // Multi-tenancy mirror image.
        for name in ["inc-v1", "mobv1-1"] {
            let p = paper_profile(name).unwrap();
            let gain = throughput(&p, Dataset::ImageNet, 1, 8)
                / throughput(&p, Dataset::ImageNet, 1, 1);
            assert!(gain > 1.5, "{name} MT gain {gain:.2} too small");
        }
        for name in ["inc-v4", "nas-large", "pnas-large"] {
            let p = paper_profile(name).unwrap();
            let gain = throughput(&p, Dataset::ImageNet, 1, 8)
                / throughput(&p, Dataset::ImageNet, 1, 1);
            assert!(gain < 1.35, "{name} MT gain {gain:.2} should be negligible");
        }
    }

    #[test]
    fn fig2_sm_utilization_shapes() {
        let mob = paper_profile("mobv1-1").unwrap();
        let inc4 = paper_profile("inc-v4").unwrap();
        let mob_u1 = sm_utilization(&mob, Dataset::ImageNet, 1, 1);
        let mob_u4 = sm_utilization(&mob, Dataset::ImageNet, 1, 4);
        let inc_u1 = sm_utilization(&inc4, Dataset::ImageNet, 1, 1);
        let inc_u4 = sm_utilization(&inc4, Dataset::ImageNet, 1, 4);
        assert!(mob_u1 < 0.3, "one mobilenet instance must leave the GPU mostly idle");
        assert!(mob_u4 > 2.0 * mob_u1, "co-location must raise mobilenet utilization");
        assert!(inc_u1 > 0.5, "one inc-v4 instance occupies most of the GPU");
        assert!(inc_u4 <= 1.0 && inc_u4 > inc_u1 * 0.9, "inc-v4 utilization saturates");
    }

    #[test]
    fn residency_monotone_and_capped() {
        let p = paper_profile("resv2-152").unwrap();
        let mut prev = 0.0;
        for b in [1u32, 2, 4, 8, 16, 32, 64, 128] {
            let d = residency(&p, b);
            assert!(d >= prev && d <= 1.0);
            prev = d;
        }
        assert!((residency(&p, 1) - p.r1).abs() < 1e-12);
    }

    #[test]
    fn latency_monotone_in_both_knobs() {
        for p in crate::gpusim::profiles::PAPER_DNNS {
            let mut prev = 0.0;
            for b in 1..=64u32 {
                let t = batch_latency_ms(p, Dataset::ImageNet, b, 1).total_ms;
                assert!(t > prev, "{}: latency not monotone in bs", p.name);
                prev = t;
            }
            let mut prev = 0.0;
            for n in 1..=10u32 {
                let t = batch_latency_ms(p, Dataset::ImageNet, 1, n).total_ms;
                assert!(t >= prev, "{}: latency not monotone in mtl", p.name);
                prev = t;
            }
        }
    }

    #[test]
    fn full_grant_reproduces_whole_gpu_model_bitwise() {
        // TimeShare byte-identity rests on this: a grant of 1.0 must be
        // the SAME computation as the ungranted model, not merely close.
        for p in crate::gpusim::profiles::PAPER_DNNS {
            for (b, n) in [(1u32, 1u32), (4, 2), (32, 1), (1, 8), (16, 4)] {
                let base = batch_latency_ms(p, Dataset::ImageNet, b, n);
                let granted = batch_latency_ms_granted(p, Dataset::ImageNet, b, n, 1.0);
                assert_eq!(base.total_ms, granted.total_ms, "{} ({b},{n})", p.name);
                assert_eq!(base.gpu_ms, granted.gpu_ms, "{} ({b},{n})", p.name);
                assert_eq!(
                    sm_utilization(p, Dataset::ImageNet, b, n),
                    sm_utilization_granted(p, Dataset::ImageNet, b, n, 1.0),
                    "{} ({b},{n})",
                    p.name
                );
            }
        }
    }

    #[test]
    fn smaller_grants_never_speed_a_member_up() {
        let p = paper_profile("mobv1-05").unwrap();
        let mut prev = 0.0;
        for grant in [1.0, 0.75, 0.5, 0.25, 0.125] {
            let t = batch_latency_ms_granted(&p, Dataset::ImageNet, 1, 4, grant).total_ms;
            assert!(t >= prev, "latency must be monotone in shrinking grant: {t} < {prev}");
            prev = t;
        }
        // A member whose demand fits its grant is NOT slowed at all:
        // mobv1-025 at (1,1) demands r1 = 0.08 < 0.25.
        let tiny = paper_profile("mobv1-025").unwrap();
        let solo = batch_latency_ms(&tiny, Dataset::ImageNet, 1, 1).total_ms;
        let quarter = batch_latency_ms_granted(&tiny, Dataset::ImageNet, 1, 1, 0.25).total_ms;
        assert_eq!(solo, quarter, "under-demanded partition must not slow the member");
    }

    #[test]
    fn granted_utilization_stays_inside_the_partition() {
        let p = paper_profile("inc-v4").unwrap();
        for grant in [0.25, 0.5, 1.0] {
            for n in 1..=4u32 {
                let u = sm_utilization_granted(&p, Dataset::ImageNet, 1, n, grant);
                assert!(u <= grant + 1e-12, "util {u} escapes grant {grant}");
                assert!(u >= 0.0);
            }
        }
    }

    #[test]
    fn mem_demand_linear() {
        let p = paper_profile("inc-v4").unwrap();
        let m1 = mem_demand_mb(&p, 1, 1);
        let m2 = mem_demand_mb(&p, 1, 2);
        assert!((m2 - 2.0 * m1).abs() < 1e-9);
        assert!(mem_demand_mb(&p, 64, 1) > m1);
    }
}
