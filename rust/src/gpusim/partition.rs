//! Spatial SM partitioning: MPS fractional grants and MIG-style slices.
//!
//! The paper's Multi-Tenancy knob co-locates instances that *time-share*
//! the GPU — the fleet models that with a single latency-inflation factor
//! derived from combined SM utilization. Production multi-tenancy
//! (D-STACK, the multi-tenant inference surveys) instead partitions the
//! device *spatially*: CUDA MPS grants each client an arbitrary fraction
//! of the SMs, and MIG carves the device into discrete isolated slices.
//! The two regimes behave qualitatively differently — a spatially
//! partitioned member cannot inflate its neighbour's latency, it can only
//! run slower inside its own share.
//!
//! This module is the device-side vocabulary for that model:
//!
//! * [`PartitionMode`] — how a fleet divides the SMs (`TimeShare` keeps
//!   the legacy inflation-factor coupling byte for byte; `Mps` grants
//!   arbitrary fractions; `MigSlices` quantizes grants to `1/slices`
//!   multiples, rounding *down* — conservative, never over-granting);
//! * [`plan_grants`] — turn per-member reservations (some may be left
//!   unset and default to an equal split of the remainder) into validated
//!   capacity grants, with typed [`PartitionError`]s for over-subscription
//!   and invalid reservations;
//! * [`SmPool`] — the admission-side ledger: grants are taken from and
//!   released back to a capacity-1.0 pool, which refuses to over-grant
//!   under any interleaving (property-tested in `tests/partitioning.rs`).
//!
//! The perf model consumes a grant through
//! [`batch_latency_ms_granted`](super::perf::batch_latency_ms_granted):
//! a member with grant `g` runs as if on a GPU `g` as large (compute
//! inflates by `max(1, n*d(b)/g)`), with `g = 1` reproducing the
//! whole-GPU model exactly.

use std::fmt;

/// Smallest SM fraction a member may hold (guards against degenerate
/// near-zero grants that would make latencies explode to infinity).
pub const MIN_GRANT: f64 = 1.0 / 64.0;

/// How a fleet divides the GPU's SMs between members.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionMode {
    /// Legacy behaviour: members' combined SM utilization sets one
    /// time-sharing inflation factor applied to every member's latency.
    #[default]
    TimeShare,
    /// MPS-style fractional SM provisioning: each member holds an
    /// arbitrary fraction of the SMs; members never inflate each other.
    Mps,
    /// MIG-style discrete slices: reservations are quantized *down* to
    /// multiples of `1/slices` (conservative — the quantized grant never
    /// exceeds the reservation, so the pool cannot over-subscribe).
    MigSlices { slices: u32 },
}

/// The A100's 7-slice layout, the conventional MIG granularity.
pub const DEFAULT_MIG_SLICES: u32 = 7;

impl PartitionMode {
    /// True for the spatial modes (`Mps`, `MigSlices`).
    pub fn is_spatial(&self) -> bool {
        !matches!(self, PartitionMode::TimeShare)
    }

    /// Parse a CLI spelling: `timeshare`, `mps`, `mig` (7 slices), or
    /// `mig:N`.
    pub fn parse(s: &str) -> Option<PartitionMode> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "timeshare" | "time-share" | "ts" => Some(PartitionMode::TimeShare),
            "mps" => Some(PartitionMode::Mps),
            "mig" => Some(PartitionMode::MigSlices { slices: DEFAULT_MIG_SLICES }),
            _ => {
                let n = s.strip_prefix("mig:")?;
                n.parse().ok().map(|slices| PartitionMode::MigSlices { slices })
            }
        }
    }
}

impl fmt::Display for PartitionMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PartitionMode::TimeShare => write!(f, "timeshare"),
            PartitionMode::Mps => write!(f, "mps"),
            PartitionMode::MigSlices { slices } => write!(f, "mig:{slices}"),
        }
    }
}

/// Why a partition plan was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionError {
    /// `MigSlices { slices: 0 }` describes no device at all.
    ZeroSlices,
    /// A reservation must be a finite fraction in `[MIN_GRANT, 1]`.
    BadReservation { index: usize, value: f64 },
    /// A MIG reservation below one slice quantizes to nothing.
    BelowSliceFloor { index: usize, value: f64, slices: u32 },
    /// Explicit reservations alone exceed the device (sum > 1).
    Oversubscribed { total: f64 },
    /// Every SM is explicitly reserved but some members have no
    /// reservation — they would be granted nothing.
    NoShareLeft { unreserved: usize },
    /// A member's model footprint does not fit the memory ceiling of its
    /// MIG slice bundle (MIG partitions memory along with the SMs; see
    /// [`plan_mem_ceilings`]).
    MemoryExceeded { index: usize, demand_mb: f64, ceiling_mb: f64 },
}

impl fmt::Display for PartitionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PartitionError::ZeroSlices => write!(f, "MIG slice count must be >= 1"),
            PartitionError::BadReservation { index, value } => {
                write!(
                    f,
                    "member {index}: SM reservation must be in [{MIN_GRANT}, 1], got {value}"
                )
            }
            PartitionError::BelowSliceFloor { index, value, slices } => write!(
                f,
                "member {index}: reservation {value} is below one MIG slice (1/{slices})"
            ),
            PartitionError::Oversubscribed { total } => {
                write!(f, "SM reservations sum to {total} > 1.0 (over-subscribed)")
            }
            PartitionError::NoShareLeft { unreserved } => write!(
                f,
                "explicit reservations consume the whole GPU but {unreserved} member(s) \
                 have no reservation left to share"
            ),
            PartitionError::MemoryExceeded { index, demand_mb, ceiling_mb } => write!(
                f,
                "member {index}: model footprint {demand_mb:.0} MB exceeds its MIG slice \
                 memory ceiling {ceiling_mb:.0} MB"
            ),
        }
    }
}

impl std::error::Error for PartitionError {}

/// Quantize a fraction down to a whole number of `1/slices` slices.
/// Conservative by construction: the result never exceeds `f` by more
/// than the 1e-9 nudge, which only exists so a value that *is* a slice
/// multiple (up to float error, e.g. `(1.0/7.0) * 7`) keeps its intended
/// slice count instead of losing one to a unit-in-last-place wobble.
pub fn quantize_to_slices(f: f64, slices: u32) -> f64 {
    let slices = slices.max(1) as f64;
    (f * slices + 1e-9).floor() / slices
}

/// Turn per-member reservations into validated capacity grants.
///
/// * `TimeShare` — every member notionally holds the whole device
///   (grants of 1.0); the time-sharing factor does the coupling.
/// * `Mps` — explicit reservations are granted verbatim; members without
///   one split the unreserved remainder equally.
/// * `MigSlices` — as `Mps`, then every grant is quantized down to whole
///   slices ([`quantize_to_slices`]); a reservation below one slice is a
///   typed error rather than a silent zero-grant.
///
/// Invariant (property-tested): on success the grants sum to at most
/// 1.0 + 1e-9 and every grant is positive.
pub fn plan_grants(
    mode: PartitionMode,
    reservations: &[Option<f64>],
) -> Result<Vec<f64>, PartitionError> {
    let n = reservations.len();
    if let PartitionMode::MigSlices { slices: 0 } = mode {
        return Err(PartitionError::ZeroSlices);
    }
    if matches!(mode, PartitionMode::TimeShare) {
        return Ok(vec![1.0; n]);
    }
    let mut explicit = 0.0f64;
    let mut unreserved = 0usize;
    for (index, r) in reservations.iter().enumerate() {
        match r {
            Some(v) if !v.is_finite() || *v < MIN_GRANT || *v > 1.0 => {
                return Err(PartitionError::BadReservation { index, value: *v });
            }
            Some(v) => explicit += *v,
            None => unreserved += 1,
        }
    }
    if explicit > 1.0 + 1e-9 {
        return Err(PartitionError::Oversubscribed { total: explicit });
    }
    let remainder = (1.0 - explicit).max(0.0);
    if unreserved > 0 && remainder / unreserved as f64 < MIN_GRANT {
        return Err(PartitionError::NoShareLeft { unreserved });
    }
    let default_share = if unreserved > 0 {
        remainder / unreserved as f64
    } else {
        0.0
    };
    let mut grants: Vec<f64> =
        reservations.iter().map(|r| r.unwrap_or(default_share)).collect();
    if let PartitionMode::MigSlices { slices } = mode {
        for (index, g) in grants.iter_mut().enumerate() {
            let q = quantize_to_slices(*g, slices);
            if q <= 0.0 {
                return Err(PartitionError::BelowSliceFloor {
                    index,
                    value: *g,
                    slices,
                });
            }
            *g = q;
        }
    }
    Ok(grants)
}

/// Per-member GPU-memory ceilings (MB) implied by a set of SM grants.
///
/// MIG is the only mode that partitions memory: each slice bundle owns
/// the same fraction of device memory as of the SMs, so a member granted
/// `k/slices` of the SMs may touch at most `k/slices` of the memory.
/// `Mps` (and `TimeShare`) leave memory a whole-device resource — CUDA
/// MPS shares the memory space, so every member's ceiling is the full
/// device and only the fleet's combined-demand admission applies.
pub fn plan_mem_ceilings(mode: PartitionMode, grants: &[f64], mem_mb: f64) -> Vec<f64> {
    match mode {
        PartitionMode::MigSlices { .. } => grants.iter().map(|g| g * mem_mb).collect(),
        _ => vec![mem_mb; grants.len()],
    }
}

/// Check per-member memory demands against the ceilings of their slice
/// bundles ([`plan_mem_ceilings`]). The first member whose demand
/// exceeds its ceiling is reported as a typed
/// [`PartitionError::MemoryExceeded`]; modes that do not partition
/// memory always pass.
pub fn check_mem_ceilings(
    mode: PartitionMode,
    grants: &[f64],
    mem_mb: f64,
    demands_mb: &[f64],
) -> Result<(), PartitionError> {
    let ceilings = plan_mem_ceilings(mode, grants, mem_mb);
    for (index, (&demand_mb, &ceiling_mb)) in demands_mb.iter().zip(&ceilings).enumerate() {
        if demand_mb > ceiling_mb {
            return Err(PartitionError::MemoryExceeded { index, demand_mb, ceiling_mb });
        }
    }
    Ok(())
}

/// The admission-side SM ledger: capacity 1.0, grants taken and released.
///
/// [`SmPool::try_grant`] refuses any request that would push the granted
/// total past capacity — under *any* interleaving of grants and releases
/// the pool holds `granted <= 1.0` (the property the fleet's partition
/// admission relies on).
#[derive(Debug, Clone, Default)]
pub struct SmPool {
    granted: f64,
}

impl SmPool {
    pub fn new() -> Self {
        SmPool { granted: 0.0 }
    }

    /// Fraction currently granted out, 0..=1.
    pub fn granted(&self) -> f64 {
        self.granted
    }

    /// Fraction still available.
    pub fn available(&self) -> f64 {
        (1.0 - self.granted).max(0.0)
    }

    /// Take `f` from the pool. Refused (with the would-be total) when the
    /// request is invalid or would over-subscribe the device.
    pub fn try_grant(&mut self, f: f64) -> Result<(), PartitionError> {
        if !f.is_finite() || f <= 0.0 || f > 1.0 {
            return Err(PartitionError::BadReservation { index: 0, value: f });
        }
        let total = self.granted + f;
        if total > 1.0 + 1e-9 {
            return Err(PartitionError::Oversubscribed { total });
        }
        self.granted = total.min(1.0);
        Ok(())
    }

    /// Return `f` to the pool (clamped at empty).
    pub fn release(&mut self, f: f64) {
        self.granted = (self.granted - f.max(0.0)).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_aliases() {
        assert_eq!(PartitionMode::parse("timeshare"), Some(PartitionMode::TimeShare));
        assert_eq!(PartitionMode::parse("mps"), Some(PartitionMode::Mps));
        assert_eq!(
            PartitionMode::parse("mig"),
            Some(PartitionMode::MigSlices { slices: DEFAULT_MIG_SLICES })
        );
        assert_eq!(PartitionMode::parse("MIG:4"), Some(PartitionMode::MigSlices { slices: 4 }));
        assert_eq!(PartitionMode::parse("nvlink"), None);
        for m in [
            PartitionMode::TimeShare,
            PartitionMode::Mps,
            PartitionMode::MigSlices { slices: 3 },
        ] {
            assert_eq!(PartitionMode::parse(&m.to_string()), Some(m));
        }
        assert!(PartitionMode::Mps.is_spatial());
        assert!(!PartitionMode::TimeShare.is_spatial());
        assert_eq!(PartitionMode::default(), PartitionMode::TimeShare);
    }

    #[test]
    fn timeshare_grants_everyone_the_whole_device() {
        let g = plan_grants(PartitionMode::TimeShare, &[Some(0.2), None, Some(0.9)]).unwrap();
        assert_eq!(g, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn mps_grants_explicit_fractions_and_splits_the_rest() {
        let g = plan_grants(PartitionMode::Mps, &[Some(0.5), None, None]).unwrap();
        assert_eq!(g[0], 0.5);
        assert!((g[1] - 0.25).abs() < 1e-12);
        assert!((g[2] - 0.25).abs() < 1e-12);
        // All-default: equal split.
        let g = plan_grants(PartitionMode::Mps, &[None, None]).unwrap();
        assert_eq!(g, vec![0.5, 0.5]);
    }

    #[test]
    fn mps_rejects_bad_and_oversubscribed_reservations() {
        for bad in [0.0, -0.1, 0.5 * MIN_GRANT, 1.5, f64::NAN, f64::INFINITY] {
            assert!(matches!(
                plan_grants(PartitionMode::Mps, &[Some(bad)]),
                Err(PartitionError::BadReservation { index: 0, .. })
            ));
        }
        assert!(matches!(
            plan_grants(PartitionMode::Mps, &[Some(0.7), Some(0.7)]),
            Err(PartitionError::Oversubscribed { .. })
        ));
        // Fully reserved device with a default member left over.
        assert_eq!(
            plan_grants(PartitionMode::Mps, &[Some(1.0), None]),
            Err(PartitionError::NoShareLeft { unreserved: 1 })
        );
    }

    #[test]
    fn mig_quantizes_down_and_rejects_sub_slice_reservations() {
        let mode = PartitionMode::MigSlices { slices: 7 };
        let g = plan_grants(mode, &[Some(0.5), Some(0.4)]).unwrap();
        // 0.5 -> 3/7, 0.4 -> 2/7: both rounded DOWN.
        assert!((g[0] - 3.0 / 7.0).abs() < 1e-12);
        assert!((g[1] - 2.0 / 7.0).abs() < 1e-12);
        assert!(g[0] <= 0.5 && g[1] <= 0.4, "quantization must be conservative");
        assert_eq!(
            plan_grants(mode, &[Some(0.05)]),
            Err(PartitionError::BelowSliceFloor { index: 0, value: 0.05, slices: 7 })
        );
        assert_eq!(
            plan_grants(PartitionMode::MigSlices { slices: 0 }, &[Some(0.5)]),
            Err(PartitionError::ZeroSlices)
        );
    }

    #[test]
    fn quantize_is_conservative_and_slice_aligned() {
        for slices in [1u32, 2, 3, 7, 8] {
            for i in 0..=100 {
                let f = i as f64 / 100.0;
                let q = quantize_to_slices(f, slices);
                assert!(q <= f + 1e-9, "quantize({f}, {slices}) = {q} over-grants");
                let units = q * slices as f64;
                assert!((units - units.round()).abs() < 1e-9, "{q} not slice-aligned");
            }
        }
    }

    #[test]
    fn mig_splits_memory_with_the_slices_but_mps_does_not() {
        let mode = PartitionMode::MigSlices { slices: 4 };
        let grants = plan_grants(mode, &[Some(0.5), Some(0.25), None]).unwrap();
        // 0.5 -> 2/4, 0.25 -> 1/4, default 0.25 -> 1/4.
        let ceilings = plan_mem_ceilings(mode, &grants, 16_000.0);
        assert!((ceilings[0] - 8_000.0).abs() < 1e-6);
        assert!((ceilings[1] - 4_000.0).abs() < 1e-6);
        assert!((ceilings[2] - 4_000.0).abs() < 1e-6);
        // MPS shares the memory space: every ceiling is the whole device.
        assert_eq!(
            plan_mem_ceilings(PartitionMode::Mps, &[0.7, 0.3], 16_000.0),
            vec![16_000.0, 16_000.0]
        );
    }

    #[test]
    fn mem_ceiling_check_reports_the_offender() {
        let mode = PartitionMode::MigSlices { slices: 4 };
        let grants = vec![0.5, 0.25];
        // Member 1's 5 GB footprint cannot live in a 4 GB quarter slice.
        let err = check_mem_ceilings(mode, &grants, 16_000.0, &[1_000.0, 5_000.0]).unwrap_err();
        assert_eq!(
            err,
            PartitionError::MemoryExceeded { index: 1, demand_mb: 5_000.0, ceiling_mb: 4_000.0 }
        );
        assert!(err.to_string().contains("5000 MB"), "{err}");
        // Same demands are fine when memory is not partitioned (MPS).
        assert!(check_mem_ceilings(PartitionMode::Mps, &grants, 16_000.0, &[1_000.0, 5_000.0])
            .is_ok());
        assert!(check_mem_ceilings(mode, &grants, 16_000.0, &[1_000.0, 3_999.0]).is_ok());
    }

    #[test]
    fn pool_never_overgrants() {
        let mut pool = SmPool::new();
        assert!(pool.try_grant(0.6).is_ok());
        assert!(pool.try_grant(0.5).is_err(), "0.6 + 0.5 must be refused");
        assert!(pool.try_grant(0.4).is_ok());
        assert!(pool.granted() <= 1.0 + 1e-9);
        pool.release(0.6);
        assert!((pool.available() - 0.6).abs() < 1e-9);
        assert!(pool.try_grant(0.6).is_ok());
        for bad in [0.0, -0.5, f64::NAN] {
            assert!(pool.try_grant(bad).is_err());
        }
    }

    #[test]
    fn error_messages_name_the_problem() {
        assert!(PartitionError::Oversubscribed { total: 1.4 }.to_string().contains("1.4"));
        assert!(PartitionError::BadReservation { index: 2, value: -1.0 }
            .to_string()
            .contains("member 2"));
        assert!(PartitionError::BelowSliceFloor { index: 0, value: 0.1, slices: 7 }
            .to_string()
            .contains("1/7"));
        assert!(PartitionError::ZeroSlices.to_string().contains(">= 1"));
        assert!(PartitionError::NoShareLeft { unreserved: 2 }.to_string().contains("2"));
    }
}
