//! Calibrated per-DNN performance profiles.
//!
//! Each paper DNN gets a small set of mechanistic parameters; the perf
//! model (`perf.rs`) turns them into latency/throughput/power surfaces.
//! Calibration anchors (Table 5 of the paper, throughput in img/s):
//!
//! | job | DNN        | base   | MTL=8   | BS=32   |
//! |-----|------------|--------|---------|---------|
//! | 1   | inc-v1     | 118.66 | 237.28  | 125.67  |
//! | 2   | inc-v2     | 104.46 | 169.85  | 125.33  |
//! | 3   | inc-v4     | 36.81  | 39.61   | 116.41  |
//! | 9   | pnas-mob   | 48.49  | 148.28  | 125.44  |
//! | 10  | resv2-50   | 103.62 | 137.43  | 126.55  |
//! | 11  | resv2-101  | 62.75  | 78.63   | 125.99  |
//! | 15  | inc-v2 (C) | 102.82 | 169.31  | 235.05  |
//! | 19  | mobv1-05(C)| 241.14 | 1050.58 | 267.84  |
//! | 26  | textcnn    | 492.00 | 2163.80 | 7145.89 |
//! | 29  | deepvs     | 15.46  | 41.27   | 19.82   |
//!
//! The parameters are *fit*, not measured; DESIGN.md §3 records the
//! substitution. Unit tests in `coordinator::profiler` assert that the
//! fitted surfaces select the same Batching/Multi-Tenancy method the
//! paper reports for the 30-job workload (Table 4).


/// Input dataset; affects CPU prep cost (resize target, sentence length)
/// exactly as §4.2 of the paper describes (Inception-V2 flips from MT on
/// ImageNet to Batching on Caltech because prep shrinks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    ImageNet,
    Caltech256,
    Sentiment140,
    ImdbReviews,
    Ledov,
    Dhf1k,
    LibriSpeech,
    /// No dataset-specific prep scaling (real-mode synthetic tensors).
    Synthetic,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        Some(match s.to_ascii_lowercase().as_str() {
            "imagenet" => Dataset::ImageNet,
            "caltech" | "caltech256" | "caltech-256" => Dataset::Caltech256,
            "sentiment140" | "sent140" => Dataset::Sentiment140,
            "imdb" | "imdbreviews" => Dataset::ImdbReviews,
            "ledov" => Dataset::Ledov,
            "dhf1k" => Dataset::Dhf1k,
            "librispeech" => Dataset::LibriSpeech,
            "synthetic" => Dataset::Synthetic,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::ImageNet => "ImageNet",
            Dataset::Caltech256 => "CalTech",
            Dataset::Sentiment140 => "Sentiment140",
            Dataset::ImdbReviews => "IMDB",
            Dataset::Ledov => "LEDOV",
            Dataset::Dhf1k => "DHF1K",
            Dataset::LibriSpeech => "LibriSpeech",
            Dataset::Synthetic => "Synthetic",
        }
    }
}

/// Multiplier on per-input CPU prep (and, for sequence models, compute)
/// relative to the profile's baseline dataset.
pub fn dataset_multiplier(ds: Dataset) -> f64 {
    match ds {
        Dataset::ImageNet => 1.0,
        // Caltech-256 images are smaller on average -> cheaper resize.
        Dataset::Caltech256 => 0.45,
        Dataset::Sentiment140 => 1.0,
        // IMDB reviews are much longer than tweets (§4.2).
        Dataset::ImdbReviews => 1.6,
        Dataset::Ledov => 1.0,
        // DHF1K frames are higher-resolution than LEDOV's.
        Dataset::Dhf1k => 1.25,
        Dataset::LibriSpeech => 1.0,
        Dataset::Synthetic => 1.0,
    }
}

/// Mechanistic performance profile of one DNN on the P40 (all times ms).
#[derive(Debug, Clone)]
pub struct DnnProfile {
    /// Paper DNN name (Table 3 abbreviation).
    pub name: &'static str,
    /// Weight bytes in MB (drives instance memory + load time).
    pub weight_mb: f64,
    /// Marginal compute time per inference at full SM efficiency.
    pub t_fl_ms: f64,
    /// Batch size at which compute saturates the SMs; below it, a batch
    /// costs the same as `bsat` inputs (weight streaming + low occupancy).
    pub bsat: f64,
    /// SM residency of one instance at BS=1 (Fig. 2): the share of the
    /// GPU a single instance effectively occupies.
    pub r1: f64,
    /// Per-batch GPU-side fixed cost (kernel launches, sync).
    pub t_gpu_fixed_ms: f64,
    /// Per-input CPU prep + H2D copy (baseline dataset).
    pub t_prep_ms: f64,
    /// Superlinear prep growth with batch size (§2: data-movement share
    /// "becomes even more when increasing the batch size").
    pub prep_growth: f64,
    /// Co-location interference slope (driver/context switching).
    pub kappa: f64,
    /// Dynamic-power coefficient (instruction-mix dependent).
    pub p_dyn: f64,
    /// GPU memory per instance at BS=1 (context + weights + workspace).
    pub mem_mb: f64,
    /// Additional activation memory per batched input.
    pub act_mb: f64,
}

macro_rules! profile {
    ($name:literal, $w:expr, $tfl:expr, $bsat:expr, $r1:expr, $gf:expr,
     $prep:expr, $growth:expr, $kappa:expr, $pdyn:expr, $mem:expr, $act:expr) => {
        DnnProfile {
            name: $name,
            weight_mb: $w,
            t_fl_ms: $tfl,
            bsat: $bsat,
            r1: $r1,
            t_gpu_fixed_ms: $gf,
            t_prep_ms: $prep,
            prep_growth: $growth,
            kappa: $kappa,
            p_dyn: $pdyn,
            mem_mb: $mem,
            act_mb: $act,
        }
    };
}

/// Calibrated profiles for every DNN in the paper's Table 3.
pub const PAPER_DNNS: &[DnnProfile] = &[
    //        name          w_mb   t_fl  bsat   r1   gpu_f  prep  growth kappa p_dyn  mem   act
    profile!("inc-v1",      26.0,  2.90,  1.2, 0.45, 0.40,  5.00, 0.003, 0.17, 0.42,  700.0, 9.0),
    profile!("inc-v2",      45.0,  1.20,  3.5, 0.42, 0.40,  5.00, 0.003, 0.28, 0.45,  800.0, 10.0),
    profile!("inc-v3",      95.0,  5.50,  2.2, 0.60, 0.80,  6.00, 0.003, 0.20, 0.50, 1000.0, 14.0),
    profile!("inc-v4",     171.0,  0.536, 33.0, 0.95, 1.50, 6.00, 0.001, 0.057, 0.55, 1400.0, 18.0),
    profile!("mobv1-025",    1.9,  0.10,  1.2, 0.08, 0.20,  4.60, 0.010, 0.04, 0.10,  400.0, 3.0),
    profile!("mobv1-05",     5.2,  0.50,  1.4, 0.40, 0.30,  6.00, 0.010, 0.133, 0.14,  450.0, 4.0),
    profile!("mobv1-1",     17.0,  0.30,  1.5, 0.20, 0.35,  8.00, 0.010, 0.26, 0.28,  500.0, 5.0),
    profile!("mobv2-1",     14.0,  0.35,  1.6, 0.22, 0.40,  6.50, 0.008, 0.15, 0.22,  520.0, 5.0),
    profile!("mobv2-14",    25.0,  0.50,  1.8, 0.28, 0.50,  6.50, 0.008, 0.15, 0.25,  600.0, 6.0),
    profile!("nas-large",  360.0,  0.90, 30.0, 0.92, 2.50,  7.50, 0.002, 0.06, 0.60, 2000.0, 22.0),
    profile!("nas-mob",     21.0,  1.20,  2.0, 0.25, 0.50,  5.00, 0.005, 0.30, 0.30,  600.0, 6.0),
    profile!("pnas-large", 345.0,  1.00, 32.0, 0.93, 2.50,  7.50, 0.002, 0.06, 0.60, 2000.0, 22.0),
    profile!("pnas-mob",    20.0,  0.94, 13.4, 0.30, 1.00,  7.00, 0.005, 0.059, 0.32, 600.0, 6.0),
    profile!("resv2-50",   102.0,  0.3875, 4.26, 0.90, 0.50, 7.50, 0.003, 0.44, 0.50, 900.0, 12.0),
    profile!("resv2-101",  170.0,  0.4125, 18.5, 0.75, 0.80, 7.50, 0.003, 0.126, 0.55, 1200.0, 14.0),
    profile!("resv2-152",  240.0,  0.46, 35.0, 0.85, 1.00,  5.50, 0.001, 0.10, 0.55, 1500.0, 16.0),
    profile!("textclassif",  8.0,  0.001, 50.0, 0.15, 1.90, 0.08, 0.000, 0.117, 0.18, 350.0, 0.5),
    profile!("deepvs",      60.0,  1.27, 10.0, 0.50, 2.00, 50.00, 0.001, 0.126, 0.65, 1600.0, 30.0),
    profile!("deepspeech", 130.0,  5.00,  8.0, 0.70, 3.00, 35.00, 0.001, 0.10, 0.55, 1800.0, 20.0),
];

/// Lookup a calibrated paper profile by name.
pub fn paper_profile(name: &str) -> Option<DnnProfile> {
    PAPER_DNNS.iter().find(|p| p.name == name).cloned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_have_sane_parameters() {
        for p in PAPER_DNNS {
            assert!(p.weight_mb > 0.0, "{}", p.name);
            assert!(p.t_fl_ms > 0.0, "{}", p.name);
            assert!(p.bsat >= 1.0, "{}", p.name);
            assert!(p.r1 > 0.0 && p.r1 <= 1.0, "{}", p.name);
            assert!(p.t_prep_ms > 0.0, "{}", p.name);
            assert!(p.kappa >= 0.0 && p.kappa < 1.0, "{}", p.name);
            assert!(p.p_dyn > 0.0 && p.p_dyn <= 1.0, "{}", p.name);
            assert!(p.mem_mb > p.weight_mb, "{}: mem must include weights", p.name);
        }
    }

    #[test]
    fn lookup_is_total_over_table3() {
        for name in [
            "inc-v1", "inc-v2", "inc-v3", "inc-v4", "mobv1-025", "mobv1-05", "mobv1-1",
            "mobv2-1", "mobv2-14", "nas-large", "nas-mob", "pnas-large", "pnas-mob",
            "resv2-50", "resv2-101", "resv2-152", "textclassif", "deepvs", "deepspeech",
        ] {
            assert!(paper_profile(name).is_some(), "missing {name}");
        }
        assert!(paper_profile("vgg16").is_none());
        assert_eq!(PAPER_DNNS.len(), 19);
    }

    #[test]
    fn dataset_parse_roundtrip() {
        for ds in [
            Dataset::ImageNet, Dataset::Caltech256, Dataset::Sentiment140,
            Dataset::ImdbReviews, Dataset::Ledov, Dataset::Dhf1k,
            Dataset::LibriSpeech, Dataset::Synthetic,
        ] {
            // name() must parse back to the same dataset.
            assert_eq!(Dataset::parse(ds.name()).map(|d| d.name()), Some(ds.name()));
            assert!(dataset_multiplier(ds) > 0.0);
        }
        assert!(Dataset::parse("nope").is_none());
    }

    #[test]
    fn caltech_prep_cheaper_than_imagenet() {
        assert!(dataset_multiplier(Dataset::Caltech256) < dataset_multiplier(Dataset::ImageNet));
        assert!(dataset_multiplier(Dataset::ImdbReviews) > dataset_multiplier(Dataset::Sentiment140));
    }
}
