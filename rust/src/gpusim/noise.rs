//! Latency noise: lognormal jitter plus rare OS-induced spikes.
//!
//! §4.4 of the paper: "some short-live spikes are observed in latency that
//! violate the SLO. They happen due to some reasons (e.g., OS processes)".
//! We reproduce both components deterministically from a seed so every
//! figure regenerates bit-identically.

use crate::rng::Rng;

/// Multiplicative latency noise process.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    rng: Rng,
    mu: f64,
    sigma: f64,
    /// Probability a batch hits an OS jitter spike.
    spike_prob: f64,
    /// Spike latency multiplier range.
    spike_range: (f64, f64),
}

impl NoiseModel {
    /// Default noise: sigma = 0.055 (p95/median ~ 1.095), 0.8% spike
    /// probability with 1.5-3x multipliers.
    pub fn new(seed: u64) -> Self {
        Self::with_params(seed, 0.055, 0.008, (1.5, 3.0))
    }

    /// Fully parameterized constructor (used by tests and ablations).
    pub fn with_params(seed: u64, sigma: f64, spike_prob: f64, spike_range: (f64, f64)) -> Self {
        // mu = -sigma^2/2 keeps the mean multiplier at 1.0.
        NoiseModel {
            rng: Rng::new(seed),
            mu: -sigma * sigma / 2.0,
            sigma,
            spike_prob,
            spike_range,
        }
    }

    /// Disable all noise (deterministic latencies).
    pub fn none(seed: u64) -> Self {
        Self::with_params(seed, 1e-9, 0.0, (1.0, 1.0))
    }

    /// Sample one observed latency around `mean_ms`.
    pub fn sample_latency(&mut self, mean_ms: f64) -> f64 {
        let mut v = mean_ms * self.rng.lognormal(self.mu, self.sigma);
        if self.spike_prob > 0.0 && self.rng.chance(self.spike_prob) {
            let (lo, hi) = self.spike_range;
            v *= self.rng.uniform_range(lo, hi);
        }
        v
    }

    /// Analytic p95 multiplier of the lognormal component (spikes excluded).
    pub fn p95_multiplier(sigma: f64) -> f64 {
        (-sigma * sigma / 2.0 + 1.6449 * sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_preserving() {
        let mut n = NoiseModel::with_params(1, 0.055, 0.0, (1.0, 1.0));
        let samples: Vec<f64> = (0..20000).map(|_| n.sample_latency(100.0)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - 100.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn p95_close_to_analytic() {
        let mut n = NoiseModel::with_params(2, 0.055, 0.0, (1.0, 1.0));
        let mut samples: Vec<f64> = (0..20000).map(|_| n.sample_latency(1.0)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = samples[(samples.len() as f64 * 0.95) as usize];
        let want = NoiseModel::p95_multiplier(0.055);
        assert!((p95 - want).abs() / want < 0.02, "p95 {p95} want {want}");
    }

    #[test]
    fn spikes_appear_at_configured_rate() {
        let mut n = NoiseModel::with_params(3, 1e-9, 0.05, (2.0, 2.0));
        let spikes = (0..10000).filter(|_| n.sample_latency(1.0) > 1.5).count();
        assert!((300..=700).contains(&spikes), "spikes {spikes}");
    }

    #[test]
    fn none_is_noise_free() {
        let mut n = NoiseModel::none(4);
        for _ in 0..100 {
            let v = n.sample_latency(42.0);
            assert!((v - 42.0).abs() < 0.01);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = NoiseModel::new(9);
        let mut b = NoiseModel::new(9);
        for _ in 0..100 {
            assert_eq!(a.sample_latency(5.0), b.sample_latency(5.0));
        }
    }
}
