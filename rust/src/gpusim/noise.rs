//! Latency noise: lognormal jitter plus rare OS-induced spikes.
//!
//! §4.4 of the paper: "some short-live spikes are observed in latency that
//! violate the SLO. They happen due to some reasons (e.g., OS processes)".
//! We reproduce both components deterministically from a seed so every
//! figure regenerates bit-identically.

use crate::rng::Rng;

use std::fmt;

/// A rejected [`NoiseModel::with_params`] configuration. Silent
/// acceptance of a negative sigma or an inverted spike range would
/// produce NaN latencies (or spikes that *shrink* latency) deep inside
/// a run; reject at construction instead.
#[derive(Debug, Clone, PartialEq)]
pub enum NoiseError {
    /// `sigma` must be finite and non-negative.
    NegativeSigma { sigma: f64 },
    /// `spike_prob` must be a finite probability in `[0, 1]`.
    BadSpikeProb { spike_prob: f64 },
    /// `spike_range` must satisfy `0 < lo <= hi`, both finite (spikes
    /// are latency *inflations*).
    BadSpikeRange { lo: f64, hi: f64 },
}

impl fmt::Display for NoiseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NoiseError::NegativeSigma { sigma } => {
                write!(f, "noise sigma must be finite and >= 0, got {sigma}")
            }
            NoiseError::BadSpikeProb { spike_prob } => {
                write!(f, "spike probability must be a finite value in [0, 1], got {spike_prob}")
            }
            NoiseError::BadSpikeRange { lo, hi } => {
                write!(f, "spike range must satisfy 0 < lo <= hi, got ({lo}, {hi})")
            }
        }
    }
}

impl std::error::Error for NoiseError {}

/// Multiplicative latency noise process.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    rng: Rng,
    mu: f64,
    sigma: f64,
    /// Probability a batch hits an OS jitter spike.
    spike_prob: f64,
    /// Spike latency multiplier range.
    spike_range: (f64, f64),
}

impl NoiseModel {
    /// Default noise: sigma = 0.055 (p95/median ~ 1.095), 0.8% spike
    /// probability with 1.5-3x multipliers.
    pub fn new(seed: u64) -> Self {
        Self::with_params(seed, 0.055, 0.008, (1.5, 3.0))
            .expect("default noise parameters are valid")
    }

    /// Fully parameterized constructor (used by tests and ablations).
    /// Rejects parameters that would corrupt sampling — see
    /// [`NoiseError`].
    pub fn with_params(
        seed: u64,
        sigma: f64,
        spike_prob: f64,
        spike_range: (f64, f64),
    ) -> Result<Self, NoiseError> {
        if !sigma.is_finite() || sigma < 0.0 {
            return Err(NoiseError::NegativeSigma { sigma });
        }
        if !spike_prob.is_finite() || !(0.0..=1.0).contains(&spike_prob) {
            return Err(NoiseError::BadSpikeProb { spike_prob });
        }
        let (lo, hi) = spike_range;
        if !lo.is_finite() || !hi.is_finite() || lo <= 0.0 || hi < lo {
            return Err(NoiseError::BadSpikeRange { lo, hi });
        }
        // mu = -sigma^2/2 keeps the mean multiplier at 1.0.
        Ok(NoiseModel {
            rng: Rng::new(seed),
            mu: -sigma * sigma / 2.0,
            sigma,
            spike_prob,
            spike_range,
        })
    }

    /// Disable all noise (deterministic latencies).
    pub fn none(seed: u64) -> Self {
        Self::with_params(seed, 1e-9, 0.0, (1.0, 1.0))
            .expect("noise-free parameters are valid")
    }

    /// Sample one observed latency around `mean_ms`.
    pub fn sample_latency(&mut self, mean_ms: f64) -> f64 {
        let mut v = mean_ms * self.rng.lognormal(self.mu, self.sigma);
        if self.spike_prob > 0.0 && self.rng.chance(self.spike_prob) {
            let (lo, hi) = self.spike_range;
            v *= self.rng.uniform_range(lo, hi);
        }
        v
    }

    /// Analytic p95 multiplier of the lognormal component (spikes excluded).
    pub fn p95_multiplier(sigma: f64) -> f64 {
        (-sigma * sigma / 2.0 + 1.6449 * sigma).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_preserving() {
        let mut n = NoiseModel::with_params(1, 0.055, 0.0, (1.0, 1.0)).unwrap();
        let samples: Vec<f64> = (0..20000).map(|_| n.sample_latency(100.0)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((mean - 100.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn p95_close_to_analytic() {
        let mut n = NoiseModel::with_params(2, 0.055, 0.0, (1.0, 1.0)).unwrap();
        let mut samples: Vec<f64> = (0..20000).map(|_| n.sample_latency(1.0)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = samples[(samples.len() as f64 * 0.95) as usize];
        let want = NoiseModel::p95_multiplier(0.055);
        assert!((p95 - want).abs() / want < 0.02, "p95 {p95} want {want}");
    }

    #[test]
    fn spikes_appear_at_configured_rate() {
        let mut n = NoiseModel::with_params(3, 1e-9, 0.05, (2.0, 2.0)).unwrap();
        let spikes = (0..10000).filter(|_| n.sample_latency(1.0) > 1.5).count();
        assert!((300..=700).contains(&spikes), "spikes {spikes}");
    }

    #[test]
    fn none_is_noise_free() {
        let mut n = NoiseModel::none(4);
        for _ in 0..100 {
            let v = n.sample_latency(42.0);
            assert!((v - 42.0).abs() < 0.01);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = NoiseModel::new(9);
        let mut b = NoiseModel::new(9);
        for _ in 0..100 {
            assert_eq!(a.sample_latency(5.0), b.sample_latency(5.0));
        }
    }

    #[test]
    fn negative_or_non_finite_sigma_is_rejected() {
        for sigma in [-0.1, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert!(
                matches!(
                    NoiseModel::with_params(1, sigma, 0.0, (1.0, 1.0)),
                    Err(NoiseError::NegativeSigma { .. })
                ),
                "sigma {sigma} must be rejected"
            );
        }
        // Zero sigma is legitimate (degenerate lognormal).
        assert!(NoiseModel::with_params(1, 0.0, 0.0, (1.0, 1.0)).is_ok());
    }

    #[test]
    fn out_of_range_spike_prob_is_rejected() {
        for p in [-0.01, 1.01, f64::NAN, f64::INFINITY] {
            assert!(
                matches!(
                    NoiseModel::with_params(1, 0.05, p, (1.5, 3.0)),
                    Err(NoiseError::BadSpikeProb { .. })
                ),
                "spike_prob {p} must be rejected"
            );
        }
        // The closed endpoints are legitimate.
        assert!(NoiseModel::with_params(1, 0.05, 0.0, (1.5, 3.0)).is_ok());
        assert!(NoiseModel::with_params(1, 0.05, 1.0, (1.5, 3.0)).is_ok());
    }

    #[test]
    fn inverted_or_non_positive_spike_range_is_rejected() {
        for (lo, hi) in [
            (3.0, 1.5),
            (0.0, 2.0),
            (-1.0, 2.0),
            (f64::NAN, 2.0),
            (1.5, f64::NAN),
            (1.5, f64::INFINITY),
        ] {
            assert!(
                matches!(
                    NoiseModel::with_params(1, 0.05, 0.01, (lo, hi)),
                    Err(NoiseError::BadSpikeRange { .. })
                ),
                "spike range ({lo}, {hi}) must be rejected"
            );
        }
        // A degenerate point range is legitimate.
        assert!(NoiseModel::with_params(1, 0.05, 0.01, (2.0, 2.0)).is_ok());
    }
}
