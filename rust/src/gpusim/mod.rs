//! Tesla-P40 performance/power simulator substrate.
//!
//! The paper's testbed is an Nvidia Tesla P40 (3840 CUDA cores, 24 GB
//! GDDR5, 50 W idle / 250 W cap) running TensorFlow 1.15. No GPU exists in
//! this environment, so — per the substitution rule in DESIGN.md §3 — we
//! build the closest synthetic equivalent: a mechanistic analytical model
//! of a DNN-serving GPU, calibrated per DNN against the paper's published
//! anchor numbers (Table 5 profiling rows, Fig. 1 curves, Table 6 power).
//!
//! The model (see [`perf`]) reproduces the paper's core phenomenon from
//! first principles rather than curve-fitting throughput directly:
//!
//! * per-input CPU prep + H2D copy cost (`t_prep`) that batching cannot
//!   amortize — this is why Mobilenet/Inception-V1 gain nothing from
//!   batching (§2: "data preparation and movement ... 20.1% for BS=16");
//! * a compute roofline with a batch-saturation point `bsat` — below it a
//!   batch costs the same as one input (weight streaming + low SM
//!   occupancy dominate), which is exactly the regime where batching is
//!   free throughput for Inception-V4/ResNet-152;
//! * an SM-residency share `r1` — co-located instances scale throughput
//!   until `n * residency` exceeds the GPU, after which they time-share
//!   (why Multi-Tenancy does nothing for Inception-V4 but 4-10x for
//!   Mobilenet);
//! * a co-location interference slope `kappa` (driver/context switching);
//! * a lognormal tail-noise process with rare OS-jitter spikes (the
//!   "short-live spikes" of §4.4).
//!
//! All controller logic observes this device through latencies only, so
//! the Profiler/Scaler/Clipper implementations are identical against the
//! simulator and the real PJRT runtime.

pub mod noise;
pub mod partition;
pub mod perf;
pub mod power;
pub mod profiles;

pub use noise::{NoiseError, NoiseModel};
pub use partition::{
    check_mem_ceilings, plan_grants, plan_mem_ceilings, quantize_to_slices, PartitionError,
    PartitionMode, SmPool, DEFAULT_MIG_SLICES, MIN_GRANT,
};
pub use perf::{OperatingPoint, PerfBreakdown};
pub use profiles::{dataset_multiplier, paper_profile, Dataset, DnnProfile, PAPER_DNNS};

use crate::device::{Device, DeviceError, ExecSample};

/// Static description of the simulated accelerator (Tesla P40).
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub cuda_cores: u32,
    pub mem_mb: f64,
    pub idle_w: f64,
    pub max_w: f64,
    /// Peak f32 throughput used by the roofline, TFLOP/s.
    pub peak_tflops: f64,
    /// PCIe gen3 x16 effective H2D bandwidth, GB/s.
    pub pcie_gbps: f64,
}

/// The paper's accelerator.
pub const TESLA_P40: GpuSpec = GpuSpec {
    name: "Tesla P40",
    cuda_cores: 3840,
    mem_mb: 24576.0,
    idle_w: 50.0,
    max_w: 250.0,
    peak_tflops: 11.76,
    pcie_gbps: 12.0,
};

/// The P40's low-profile inference sibling (same Pascal generation,
/// ~47% of the compute, a third of the memory) — the canonical "small"
/// device of a heterogeneous inference pool.
pub const TESLA_P4: GpuSpec = GpuSpec {
    name: "Tesla P4",
    cuda_cores: 2560,
    mem_mb: 8192.0,
    idle_w: 25.0,
    max_w: 75.0,
    peak_tflops: 5.5,
    pcie_gbps: 12.0,
};

/// The Turing inference card that replaced the P4 in most fleets:
/// ~69% of a P40's f32 compute with 16 GB of memory.
pub const TESLA_T4: GpuSpec = GpuSpec {
    name: "Tesla T4",
    cuda_cores: 2560,
    mem_mb: 16384.0,
    idle_w: 17.0,
    max_w: 70.0,
    peak_tflops: 8.1,
    pcie_gbps: 12.0,
};

/// Lookup a catalogued accelerator by its CLI spelling (`p40`, `p4`,
/// `t4`). The perf model is calibrated on the P40; smaller devices are
/// modelled as fractional-capacity P40s (see `coordinator::cluster`).
pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    match name.trim().to_ascii_lowercase().as_str() {
        "p40" | "tesla-p40" => Some(TESLA_P40),
        "p4" | "tesla-p4" => Some(TESLA_P4),
        "t4" | "tesla-t4" => Some(TESLA_T4),
        _ => None,
    }
}

/// A simulated GPU serving one DNN job at a given operating point.
#[derive(Debug, Clone)]
pub struct GpuSim {
    pub spec: GpuSpec,
    pub profile: DnnProfile,
    pub dataset: Dataset,
    noise: NoiseModel,
}

impl GpuSim {
    /// New simulator for `profile` fed by `dataset`, with deterministic
    /// noise from `seed`.
    pub fn new(profile: DnnProfile, dataset: Dataset, seed: u64) -> Self {
        GpuSim { spec: TESLA_P40, profile, dataset, noise: NoiseModel::new(seed) }
    }

    /// Convenience: simulator for a paper DNN by name.
    pub fn for_paper_dnn(name: &str, dataset: Dataset, seed: u64) -> Option<Self> {
        paper_profile(name).map(|p| GpuSim::new(p, dataset, seed))
    }

    /// Deterministic (noise-free) per-batch latency in ms at `(bs, mtl)`.
    pub fn mean_batch_latency_ms(&self, bs: u32, mtl: u32) -> f64 {
        perf::batch_latency_ms(&self.profile, self.dataset, bs, mtl).total_ms
    }

    /// Deterministic per-batch latency in ms at `(bs, mtl)` inside a
    /// spatial SM partition of fraction `grant` (MPS share / MIG slices).
    pub fn mean_batch_latency_ms_granted(&self, bs: u32, mtl: u32, grant: f64) -> f64 {
        perf::batch_latency_ms_granted(&self.profile, self.dataset, bs, mtl, grant).total_ms
    }

    /// Full latency breakdown at `(bs, mtl)`.
    pub fn breakdown(&self, bs: u32, mtl: u32) -> PerfBreakdown {
        perf::batch_latency_ms(&self.profile, self.dataset, bs, mtl)
    }

    /// Steady-state throughput (inferences/s) at `(bs, mtl)`.
    pub fn throughput(&self, bs: u32, mtl: u32) -> f64 {
        let t = self.mean_batch_latency_ms(bs, mtl);
        (mtl as f64) * (bs as f64) / (t / 1000.0)
    }

    /// SM utilization (nvidia-smi style busy fraction x residency), 0..1.
    pub fn sm_utilization(&self, bs: u32, mtl: u32) -> f64 {
        perf::sm_utilization(&self.profile, self.dataset, bs, mtl)
    }

    /// SM utilization of this job confined to an SM partition of
    /// fraction `grant` (never exceeds the grant); `grant = 1`
    /// reproduces [`GpuSim::sm_utilization`] bit for bit.
    pub fn sm_utilization_granted(&self, bs: u32, mtl: u32, grant: f64) -> f64 {
        perf::sm_utilization_granted(&self.profile, self.dataset, bs, mtl, grant)
    }

    /// Board power draw (W) at `(bs, mtl)`.
    pub fn power_w(&self, bs: u32, mtl: u32) -> f64 {
        power::power_w(&self.spec, &self.profile, self.dataset, bs, mtl)
    }

    /// GPU memory demand (MB) at `(bs, mtl)`; must stay below
    /// `spec.mem_mb` or execution OOMs.
    pub fn mem_demand_mb(&self, bs: u32, mtl: u32) -> f64 {
        perf::mem_demand_mb(&self.profile, bs, mtl)
    }

    /// Largest batch size that fits in memory at MTL=1.
    pub fn max_batch_size(&self) -> u32 {
        let mut bs = 1;
        while bs < 4096 && self.mem_demand_mb(bs * 2, 1) <= self.spec.mem_mb {
            bs *= 2;
        }
        bs
    }

    /// Largest MTL that fits in memory at BS=1.
    pub fn max_mtl(&self) -> u32 {
        let mut n = 1;
        while n < 64 && self.mem_demand_mb(1, n + 1) <= self.spec.mem_mb {
            n += 1;
        }
        n
    }
}

impl Device for GpuSim {
    fn model(&self) -> &str {
        self.profile.name
    }

    fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError> {
        if bs == 0 || mtl == 0 {
            return Err(DeviceError::InvalidOperatingPoint { bs, mtl });
        }
        if self.mem_demand_mb(bs, mtl) > self.spec.mem_mb {
            return Err(DeviceError::OutOfMemory {
                demand_mb: self.mem_demand_mb(bs, mtl),
                capacity_mb: self.spec.mem_mb,
            });
        }
        let mean = self.mean_batch_latency_ms(bs, mtl);
        let latency_ms = self.noise.sample_latency(mean);
        Ok(ExecSample {
            latency_ms,
            batch_size: bs,
            mtl,
            power_w: self.power_w(bs, mtl),
            sm_util: self.sm_utilization(bs, mtl),
        })
    }

    fn execute_batch_granted(
        &mut self,
        bs: u32,
        mtl: u32,
        grant: f64,
    ) -> Result<ExecSample, DeviceError> {
        if bs == 0 || mtl == 0 {
            return Err(DeviceError::InvalidOperatingPoint { bs, mtl });
        }
        if !grant.is_finite() || grant <= 0.0 || grant > 1.0 {
            return Err(DeviceError::InvalidGrant { grant });
        }
        // Memory stays a whole-device resource (MPS does not partition
        // it, and our MIG model partitions SMs only); the fleet's shared
        // admission check guards the combined demand.
        if self.mem_demand_mb(bs, mtl) > self.spec.mem_mb {
            return Err(DeviceError::OutOfMemory {
                demand_mb: self.mem_demand_mb(bs, mtl),
                capacity_mb: self.spec.mem_mb,
            });
        }
        let mean = self.mean_batch_latency_ms_granted(bs, mtl, grant);
        let latency_ms = self.noise.sample_latency(mean);
        Ok(ExecSample {
            latency_ms,
            batch_size: bs,
            mtl,
            power_w: self.power_w(bs, mtl),
            sm_util: perf::sm_utilization_granted(
                &self.profile,
                self.dataset,
                bs,
                mtl,
                grant,
            ),
        })
    }

    fn launch_overhead_ms(&self) -> f64 {
        // Launching a new co-located instance costs a model load +
        // context creation; the paper calls frequent launch/terminate
        // "significant overhead" — we charge ~2 s, in line with TF 1.x
        // session + cuDNN init times.
        2000.0 + self.profile.weight_mb * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(name: &str) -> GpuSim {
        GpuSim::for_paper_dnn(name, Dataset::ImageNet, 7).unwrap()
    }

    #[test]
    fn throughput_positive_and_monotone_latency() {
        let s = sim("inc-v4");
        let mut prev = 0.0;
        for bs in [1u32, 2, 4, 8, 16, 32, 64, 128] {
            let t = s.mean_batch_latency_ms(bs, 1);
            assert!(t > prev, "latency must increase with bs: {t} !> {prev}");
            prev = t;
            assert!(s.throughput(bs, 1) > 0.0);
        }
        let mut prevn = 0.0;
        for n in 1..=10u32 {
            let t = s.mean_batch_latency_ms(1, n);
            assert!(t >= prevn);
            prevn = t;
        }
    }

    #[test]
    fn oom_and_invalid_points_rejected() {
        let mut s = sim("resv2-152");
        assert!(matches!(
            s.execute_batch(0, 1),
            Err(DeviceError::InvalidOperatingPoint { .. })
        ));
        // A preposterous operating point must OOM on 24 GB.
        let demand = s.mem_demand_mb(4096, 64);
        assert!(demand > s.spec.mem_mb);
        assert!(matches!(s.execute_batch(4096, 64), Err(DeviceError::OutOfMemory { .. })));
    }

    #[test]
    fn caps_are_sane() {
        for name in ["inc-v1", "inc-v4", "mobv1-025", "resv2-152"] {
            let s = sim(name);
            assert!(s.max_batch_size() >= 128, "{name} must support BS=128");
            assert!(s.max_mtl() >= 10, "{name} must support MTL=10");
        }
    }

    #[test]
    fn granted_execution_matches_full_gpu_at_grant_one() {
        // Same seed, same call count: a grant of 1.0 consumes the noise
        // stream identically and lands on identical samples.
        let mut a = GpuSim::for_paper_dnn("mobv1-05", Dataset::ImageNet, 5).unwrap();
        let mut b = GpuSim::for_paper_dnn("mobv1-05", Dataset::ImageNet, 5).unwrap();
        for _ in 0..20 {
            let sa = a.execute_batch(2, 3).unwrap();
            let sb = b.execute_batch_granted(2, 3, 1.0).unwrap();
            assert_eq!(sa.latency_ms, sb.latency_ms);
            assert_eq!(sa.sm_util, sb.sm_util);
            assert_eq!(sa.power_w, sb.power_w);
        }
    }

    #[test]
    fn granted_execution_rejects_bad_grants() {
        let mut s = sim("inc-v1");
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            assert!(matches!(
                s.execute_batch_granted(1, 1, bad),
                Err(DeviceError::InvalidGrant { .. })
            ));
        }
        assert!(matches!(
            s.execute_batch_granted(0, 1, 0.5),
            Err(DeviceError::InvalidOperatingPoint { .. })
        ));
        // A half-GPU partition slows a contended member down on average.
        let mean_full = s.mean_batch_latency_ms(1, 8);
        let mean_half = s.mean_batch_latency_ms_granted(1, 8, 0.5);
        assert!(mean_half > mean_full, "{mean_half} vs {mean_full}");
    }

    #[test]
    fn gpu_catalogue_lookup_and_sanity() {
        assert_eq!(gpu_by_name("p40").unwrap().name, "Tesla P40");
        assert_eq!(gpu_by_name("P4").unwrap().name, "Tesla P4");
        assert_eq!(gpu_by_name(" t4 ").unwrap().name, "Tesla T4");
        assert!(gpu_by_name("a100").is_none());
        // The catalogue's heterogeneity is real: every non-P40 device is
        // strictly smaller than the calibration GPU in compute.
        for g in [TESLA_P4, TESLA_T4] {
            assert!(g.peak_tflops < TESLA_P40.peak_tflops, "{}", g.name);
            assert!(g.mem_mb < TESLA_P40.mem_mb, "{}", g.name);
        }
    }

    #[test]
    fn noise_is_deterministic_per_seed() {
        let mut a = GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 3).unwrap();
        let mut b = GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 3).unwrap();
        for _ in 0..50 {
            let sa = a.execute_batch(4, 1).unwrap();
            let sb = b.execute_batch(4, 1).unwrap();
            assert_eq!(sa.latency_ms, sb.latency_ms);
        }
    }
}
