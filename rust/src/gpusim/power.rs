//! Board power model (Table 6 of the paper).
//!
//! P40 power = 50 W idle + up to 200 W dynamic. Dynamic power tracks how
//! busy the SMs are *and* the instruction mix (memory-bound kernels burn
//! far less than dense GEMM — this is why Clipper pushing huge batches on
//! a prep-bound Mobilenet barely moves power, §4.3). We model it as
//!
//! ```text
//! P = idle + (max - idle) * p_dyn * busy(b, n)
//! ```
//!
//! with `busy` the GPU busy-time fraction from the perf model and `p_dyn`
//! the per-DNN instruction-mix coefficient calibrated against Table 6.

use super::perf::{batch_latency_ms, compute_ms};
use super::profiles::{Dataset, DnnProfile};
use super::GpuSpec;

/// GPU busy-time fraction at `(b, n)` (0..1).
pub fn busy_fraction(p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> f64 {
    let bd = batch_latency_ms(p, ds, b, n);
    let own_gpu_ms = p.t_gpu_fixed_ms + compute_ms(p, ds, b);
    ((n as f64) * own_gpu_ms / bd.total_ms).min(1.0)
}

/// Board power (W) at `(b, n)`.
pub fn power_w(spec: &GpuSpec, p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> f64 {
    let busy = busy_fraction(p, ds, b, n);
    spec.idle_w + (spec.max_w - spec.idle_w) * p.p_dyn * busy
}

/// Power efficiency (inferences per joule = throughput / watts).
pub fn power_efficiency(spec: &GpuSpec, p: &DnnProfile, ds: Dataset, b: u32, n: u32) -> f64 {
    super::perf::throughput(p, ds, b, n) / power_w(spec, p, ds, b, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::paper_profile;
    use crate::gpusim::TESLA_P40;

    #[test]
    fn power_bounded_by_spec() {
        for p in crate::gpusim::profiles::PAPER_DNNS {
            for (b, n) in [(1u32, 1u32), (32, 1), (128, 1), (1, 8), (1, 10), (8, 4)] {
                let w = power_w(&TESLA_P40, p, Dataset::ImageNet, b, n);
                assert!(w >= TESLA_P40.idle_w - 1e-9, "{}: {w} below idle", p.name);
                assert!(w <= TESLA_P40.max_w + 1e-9, "{}: {w} above cap", p.name);
            }
        }
    }

    #[test]
    fn mt_on_small_dnn_raises_power_but_efficiency_wins() {
        // Table 6 shape: DNNScaler's MT draws more power than Clipper's
        // batching on the same small DNN, but efficiency still improves.
        let p = paper_profile("inc-v1").unwrap();
        let ds = Dataset::ImageNet;
        let p_mt = power_w(&TESLA_P40, &p, ds, 1, 8);
        let p_batch = power_w(&TESLA_P40, &p, ds, 32, 1);
        assert!(p_mt > p_batch, "MT must draw more power ({p_mt:.1} vs {p_batch:.1})");
        let eff_mt = power_efficiency(&TESLA_P40, &p, ds, 1, 8);
        let eff_batch = power_efficiency(&TESLA_P40, &p, ds, 32, 1);
        // The paper's Table 6 gap is larger (their Clipper throughput
        // collapses under the tight SLO); on the raw surfaces we require
        // a clear but smaller margin.
        assert!(
            eff_mt > 1.2 * eff_batch,
            "MT efficiency {eff_mt:.2} must beat batching {eff_batch:.2}"
        );
    }

    #[test]
    fn busy_fraction_in_unit_interval() {
        for p in crate::gpusim::profiles::PAPER_DNNS {
            for (b, n) in [(1u32, 1u32), (64, 2), (1, 10)] {
                let f = busy_fraction(p, Dataset::ImageNet, b, n);
                assert!((0.0..=1.0).contains(&f), "{}: busy {f}", p.name);
            }
        }
    }

    #[test]
    fn prep_bound_batching_stays_near_idle() {
        // Clipper pushing BS=128 on mobv1-025: GPU mostly waits on prep,
        // so power stays near idle (paper: 51.8 W).
        let p = paper_profile("mobv1-025").unwrap();
        let w = power_w(&TESLA_P40, &p, Dataset::ImageNet, 128, 1);
        assert!(w < 70.0, "prep-bound batching power {w:.1} should be near idle");
    }
}
