//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! AOT-lowered `(model, batch_size)` pair; the runtime uses it to discover
//! which HLO files exist, their input/output shapes and their static cost
//! metadata (params, FLOPs) without ever importing python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Json};

/// One AOT artifact: a compiled-constant model at a fixed batch size.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Zoo model name, e.g. `mobv1-1`.
    pub model: String,
    /// Zoo family, e.g. `mobile`, `resnet`.
    pub family: String,
    /// Which paper DNN this zoo entry stands in for.
    pub paper_analogue: String,
    /// Batch size the HLO was specialized to.
    pub batch_size: usize,
    /// Full input shape including the batch dimension.
    pub input_shape: Vec<usize>,
    /// Full output shape (logits `[batch, num_classes]`).
    pub output_shape: Vec<usize>,
    /// Element dtype (always `f32` in v1).
    pub dtype: String,
    /// Trainable parameters baked into the HLO as constants.
    pub param_count: u64,
    /// XLA cost-analysis FLOPs for one batch.
    pub flops_per_batch: f64,
    /// `flops_per_batch / batch_size`.
    pub flops_per_inference: f64,
    /// HLO text file name, relative to the manifest directory.
    pub path: String,
}

impl ArtifactEntry {
    /// Number of f32 elements the input tensor holds.
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Number of f32 elements the output tensor holds.
    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// Parsed `manifest.json` plus its base directory for resolving HLO paths.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub num_classes: usize,
    pub entries: Vec<ArtifactEntry>,
    base_dir: PathBuf,
}

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| anyhow!("manifest: missing field {key:?}"))
}

fn str_field(obj: &Json, key: &str) -> Result<String> {
    Ok(field(obj, key)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: {key:?} not a string"))?
        .to_string())
}

fn num_field(obj: &Json, key: &str) -> Result<f64> {
    field(obj, key)?.as_f64().ok_or_else(|| anyhow!("manifest: {key:?} not a number"))
}

fn shape_field(obj: &Json, key: &str) -> Result<Vec<usize>> {
    field(obj, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("manifest: {key:?} not an array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("manifest: {key:?} has non-integer dim")))
        .collect()
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(ArtifactEntry {
            model: str_field(v, "model")?,
            family: str_field(v, "family")?,
            paper_analogue: str_field(v, "paper_analogue")?,
            batch_size: num_field(v, "batch_size")? as usize,
            input_shape: shape_field(v, "input_shape")?,
            output_shape: shape_field(v, "output_shape")?,
            dtype: str_field(v, "dtype")?,
            param_count: num_field(v, "param_count")? as u64,
            flops_per_batch: num_field(v, "flops_per_batch")?,
            flops_per_inference: num_field(v, "flops_per_inference")?,
            path: str_field(v, "path")?,
        })
    }
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let entries = field(&root, "entries")?
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: entries not an array"))?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            version: num_field(&root, "version")? as u32,
            num_classes: num_field(&root, "num_classes")? as usize,
            entries,
            base_dir: dir.to_path_buf(),
        })
    }

    /// Absolute path of an entry's HLO text file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.base_dir.join(&entry.path)
    }

    /// All distinct model names, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut set: Vec<String> = self
            .entries
            .iter()
            .map(|e| e.model.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        set.sort();
        set
    }

    /// Batch sizes available for `model`, ascending.
    pub fn batch_sizes(&self, model: &str) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.model == model)
            .map(|e| e.batch_size)
            .collect();
        bs.sort_unstable();
        bs
    }

    /// The entry for `(model, batch_size)`, if exported.
    pub fn get(&self, model: &str, batch_size: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.model == model && e.batch_size == batch_size)
    }

    /// The entry for `model` with the largest batch size `<= batch_size`
    /// (serving pads up to an exported size; see `runtime::pool`).
    pub fn best_fit(&self, model: &str, batch_size: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.model == model && e.batch_size >= batch_size)
            .min_by_key(|e| e.batch_size)
    }

    /// Validate internal consistency (shapes, files on disk, positive costs).
    pub fn validate(&self) -> Result<()> {
        if self.entries.is_empty() {
            return Err(anyhow!("manifest has no entries"));
        }
        let mut seen: BTreeMap<(String, usize), ()> = BTreeMap::new();
        for e in &self.entries {
            if e.input_shape.first() != Some(&e.batch_size) {
                return Err(anyhow!(
                    "{} bs{}: input_shape {:?} does not start with batch size",
                    e.model, e.batch_size, e.input_shape
                ));
            }
            if e.output_shape != vec![e.batch_size, self.num_classes] {
                return Err(anyhow!(
                    "{} bs{}: output_shape {:?} != [bs, {}]",
                    e.model, e.batch_size, e.output_shape, self.num_classes
                ));
            }
            if e.param_count == 0 || e.flops_per_batch <= 0.0 {
                return Err(anyhow!("{} bs{}: non-positive cost metadata", e.model, e.batch_size));
            }
            if !self.hlo_path(e).exists() {
                return Err(anyhow!("missing artifact file {}", e.path));
            }
            if seen.insert((e.model.clone(), e.batch_size), ()).is_some() {
                return Err(anyhow!("duplicate entry {} bs{}", e.model, e.batch_size));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entry() -> ArtifactEntry {
        ArtifactEntry {
            model: "m".into(),
            family: "mobile".into(),
            paper_analogue: "Mobilenet".into(),
            batch_size: 2,
            input_shape: vec![2, 32, 32, 3],
            output_shape: vec![2, 16],
            dtype: "f32".into(),
            param_count: 10,
            flops_per_batch: 100.0,
            flops_per_inference: 50.0,
            path: "m_bs2.hlo.txt".into(),
        }
    }

    fn manifest_with(entries: Vec<ArtifactEntry>) -> Manifest {
        Manifest { version: 1, num_classes: 16, entries, base_dir: PathBuf::from("/nonexistent") }
    }

    #[test]
    fn input_output_elems() {
        let e = sample_entry();
        assert_eq!(e.input_elems(), 2 * 32 * 32 * 3);
        assert_eq!(e.output_elems(), 32);
    }

    #[test]
    fn lookup_and_best_fit() {
        let mut e1 = sample_entry();
        e1.batch_size = 1;
        e1.input_shape = vec![1, 32, 32, 3];
        e1.output_shape = vec![1, 16];
        let mut e4 = sample_entry();
        e4.batch_size = 4;
        e4.input_shape = vec![4, 32, 32, 3];
        e4.output_shape = vec![4, 16];
        let m = manifest_with(vec![e1, sample_entry(), e4]);
        assert_eq!(m.get("m", 2).unwrap().batch_size, 2);
        assert!(m.get("m", 3).is_none());
        assert_eq!(m.best_fit("m", 3).unwrap().batch_size, 4);
        assert_eq!(m.best_fit("m", 4).unwrap().batch_size, 4);
        assert!(m.best_fit("m", 5).is_none());
        assert_eq!(m.batch_sizes("m"), vec![1, 2, 4]);
        assert_eq!(m.models(), vec!["m".to_string()]);
    }

    #[test]
    fn validate_catches_shape_mismatch() {
        let mut e = sample_entry();
        e.output_shape = vec![2, 17];
        assert!(manifest_with(vec![e]).validate().is_err());
        let mut e = sample_entry();
        e.input_shape = vec![3, 32, 32, 3];
        assert!(manifest_with(vec![e]).validate().is_err());
        assert!(manifest_with(vec![]).validate().is_err());
    }

    #[test]
    fn validate_catches_duplicates() {
        // Both entries fail on the missing file first unless we check dup
        // ordering — use entries whose file-existence check would pass by
        // pointing base_dir at a real dir with the file absent anyway; the
        // missing-file error is fine too: validate must err either way.
        let m = manifest_with(vec![sample_entry(), sample_entry()]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            m.validate().unwrap();
            assert!(m.models().len() >= 4);
        }
    }
}
