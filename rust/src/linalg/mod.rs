//! Minimal dense linear algebra, built from scratch for the
//! matrix-completion substrate (DESIGN.md §3: the paper used TFOCS; we
//! implement the SVD + soft-impute machinery ourselves rather than pulling
//! a linear-algebra crate).

pub mod matrix;
pub mod svd;

pub use matrix::Mat;
pub use svd::{svd, Svd};
