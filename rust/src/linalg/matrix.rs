//! A small row-major dense f64 matrix — just enough for soft-impute.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zero `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a row-major slice; panics if lengths mismatch.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `self * other`; panics on dimension mismatch.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Column `j` as a vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Elementwise subtraction; panics on shape mismatch.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data: Vec<f64> = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Scale every element.
    pub fn scale(&self, s: f64) -> Mat {
        let data: Vec<f64> = self.data.iter().map(|x| x * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1., 2., 3., 4.]);
        let b = Mat::from_rows(2, 2, &[5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[19., 22.]);
        assert_eq!(c.row(1), &[43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().row(0), &[1., 4.]);
    }

    #[test]
    fn norms_and_ops() {
        let a = Mat::from_rows(1, 2, &[3., 4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        let z = a.sub(&a);
        assert_eq!(z.fro_norm(), 0.0);
        assert_eq!(a.scale(2.0).row(0), &[6., 8.]);
    }

    #[test]
    #[should_panic]
    fn matmul_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
