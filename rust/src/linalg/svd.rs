//! One-sided Jacobi SVD.
//!
//! The matrices soft-impute decomposes here are tiny (a few dozen profiled
//! DNNs x 10 MTL levels), so the classic one-sided Jacobi iteration —
//! orthogonalize pairs of columns of `A` by plane rotations until
//! convergence — is plenty: O(n^2) sweeps of O(m) rotations, numerically
//! robust, no external dependencies.

use super::matrix::Mat;

/// Result of [`svd`]: `a = u * diag(s) * v^T` with `s` descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// `m x r` left singular vectors (orthonormal columns).
    pub u: Mat,
    /// `r` singular values, descending, non-negative.
    pub s: Vec<f64>,
    /// `n x r` right singular vectors (orthonormal columns).
    pub v: Mat,
}

impl Svd {
    /// Reconstruct `u * diag(s) * v^T`, truncated to the leading `rank`
    /// components (rank 0 means all).
    pub fn reconstruct(&self, rank: usize) -> Mat {
        let r = if rank == 0 { self.s.len() } else { rank.min(self.s.len()) };
        let m = self.u.rows();
        let n = self.v.rows();
        let mut out = Mat::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += uik * self.v[(j, k)];
                }
            }
        }
        out
    }
}

/// Compute the thin SVD of `a` (m x n, any aspect ratio) by one-sided
/// Jacobi on the side with fewer columns.
pub fn svd(a: &Mat) -> Svd {
    if a.rows() < a.cols() {
        // svd(A^T) = (V, S, U)
        let Svd { u, s, v } = svd_tall(&a.t());
        return Svd { u: v, s, v: u };
    }
    svd_tall(a)
}

/// One-sided Jacobi for m >= n: rotate columns of a working copy `w` of
/// `a` until all column pairs are orthogonal; then s_j = ||w_j||,
/// u_j = w_j / s_j, and the accumulated rotations give V.
fn svd_tall(a: &Mat) -> Svd {
    let m = a.rows();
    let n = a.cols();
    let mut w = a.clone();
    let mut v = Mat::eye(n);
    let eps = 1e-12;
    let max_sweeps = 60;

    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                off = off.max(apq.abs() / (app.sqrt() * aqq.sqrt() + f64::MIN_POSITIVE));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation annihilating the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // Extract singular values and left vectors; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&x, &y| norms[y].partial_cmp(&norms[x]).unwrap());

    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (k, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(nj);
        if nj > 0.0 {
            for i in 0..m {
                u[(i, k)] = w[(i, j)] / nj;
            }
        }
        for i in 0..n {
            vv[(i, k)] = v[(i, j)];
        }
    }
    Svd { u, s, v: vv }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let d = a.sub(b).fro_norm();
        let scale = b.fro_norm().max(1.0);
        assert!(d / scale < tol, "fro diff {} vs scale {}", d, scale);
    }

    #[test]
    fn reconstructs_diagonal() {
        let a = Mat::from_rows(3, 3, &[3., 0., 0., 0., 2., 0., 0., 0., 1.]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-9);
        assert!((d.s[1] - 2.0).abs() < 1e-9);
        assert!((d.s[2] - 1.0).abs() < 1e-9);
        assert_close(&d.reconstruct(0), &a, 1e-9);
    }

    #[test]
    fn reconstructs_random_tall_and_wide() {
        // Deterministic pseudo-random fill.
        let mut x = 1u64;
        let mut next = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((x >> 33) as f64 / (1u64 << 31) as f64) - 1.0
        };
        for (m, n) in [(7, 4), (4, 7), (10, 10), (5, 1), (1, 5)] {
            let data: Vec<f64> = (0..m * n).map(|_| next()).collect();
            let a = Mat::from_rows(m, n, &data);
            let d = svd(&a);
            assert_close(&d.reconstruct(0), &a, 1e-8);
            // Singular values descending and non-negative.
            for w in d.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(d.s.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn orthonormal_factors() {
        let a = Mat::from_rows(5, 3, &[1., 2., 0., 0., 1., 1., 3., 0., 1., 1., 1., 1., 0., 2., 2.]);
        let d = svd(&a);
        let utu = d.u.t().matmul(&d.u);
        let vtv = d.v.t().matmul(&d.v);
        assert_close(&utu, &Mat::eye(3), 1e-8);
        assert_close(&vtv, &Mat::eye(3), 1e-8);
    }

    #[test]
    fn low_rank_truncation() {
        // Rank-1 matrix: truncating to rank 1 must be exact.
        let u = Mat::from_rows(4, 1, &[1., 2., 3., 4.]);
        let v = Mat::from_rows(1, 3, &[1., 0., -1.]);
        let a = u.matmul(&v);
        let d = svd(&a);
        assert!(d.s[1] < 1e-9 * d.s[0].max(1.0));
        assert_close(&d.reconstruct(1), &a, 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat::zeros(3, 2);
        let d = svd(&a);
        assert!(d.s.iter().all(|&s| s == 0.0));
        assert_close(&d.reconstruct(0), &a, 1e-12);
    }
}
