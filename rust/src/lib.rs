//! # DNNScaler — Batching or Multi-Tenancy?
//!
//! A production-shaped reproduction of *Throughput Maximization of DNN
//! Inference: Batching or Multi-Tenancy?* (CS.DC 2023). The crate is the
//! L3 coordinator of a three-layer stack:
//!
//! * **L1/L2 (build time, python)** — a JAX/Pallas model zoo AOT-lowered to
//!   HLO-text artifacts (`make artifacts`); python never runs at serve time.
//! * **runtime** — loads those artifacts through the PJRT C API (`xla`
//!   crate) and executes them on the CPU client.
//! * **coordinator** — the paper's contribution plus an event-driven
//!   serving core around it (see below).
//! * **gpusim** — a Tesla-P40 performance/power model substrate that
//!   reproduces the paper's GPU economics on hardware that has no GPU
//!   (see DESIGN.md §3 for the substitution argument).
//!
//! ## Serving API: one engine, two drivers
//!
//! All open-loop serving machinery lives in `coordinator::engine` — a
//! virtual-time event loop (arrival generation, timestamped queueing,
//! size/timeout batch formation, sojourn-latency charging, bounded-queue
//! drop accounting, SLO deadline shedding) packaged as a per-member core.
//! Two public drivers sit on top:
//!
//! [`coordinator::session::ServingSession`] serves ONE job: pick a
//! [`JobSpec`], a [`device::Device`] ([`GpuSim`] or the real PJRT
//! runtime), a [`coordinator::session::PolicySpec`] (DNNScaler, Clipper,
//! the queue-aware proactive scaler, a static knob, or any custom
//! [`coordinator::policy::Policy`]), and an [`workload::ArrivalPattern`]:
//!
//! ```ignore
//! use dnnscaler::coordinator::session::{PolicySpec, ServingSession};
//! use dnnscaler::workload::ArrivalPattern;
//!
//! let job = dnnscaler::coordinator::job::paper_job(1).unwrap();
//! let sim = dnnscaler::GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap();
//! let outcome = ServingSession::builder()
//!     .job(job)
//!     .device(sim)
//!     .policy(PolicySpec::DnnScaler)
//!     .arrivals(ArrivalPattern::bursty(40.0, 2.0, 4.0, 1.0)) // or Closed
//!     .shed_deadline(true) // drop requests that already blew the SLO
//!     .build()?   // typed ConfigError on a bad configuration
//!     .run()?;    // JobOutcome: throughput, goodput, sojourn p95, drops
//! ```
//!
//! [`coordinator::fleet::Fleet`] serves SEVERAL jobs concurrently on one
//! simulated GPU with shared memory (admission control) and shared SMs
//! (cross-job contention):
//!
//! ```ignore
//! let fleet = dnnscaler::Fleet::builder()
//!     .job_with_arrivals(job_a, PolicySpec::QueueAware,
//!                        ArrivalPattern::bursty(60.0, 3.0, 4.0, 1.0))
//!     .queue_capacity(256)      // knobs apply to the last-added member
//!     .shed_deadline(true)
//!     .job_with_arrivals(job_b, PolicySpec::DnnScaler,
//!                        ArrivalPattern::from_trace_file("azure.txt")?)
//!     .build()?
//!     .run()?;                  // per-member outcomes + contention trace
//! ```
//!
//! * `ArrivalPattern::Closed` reproduces the paper's closed-loop results
//!   exactly (every figure/table regenerates through this path; closed
//!   fleets keep their lockstep-window accounting byte for byte);
//! * open patterns (`poisson`, `uniform`, `bursty`, and `Trace` — replay
//!   of a recorded arrival log via `from_trace_file`) drive the engine's
//!   event loop, where queueing delay is part of every observed latency,
//!   bounded queues drop + count overflow, and deadline shedding counts
//!   SLO-hopeless requests separately (goodput in every outcome);
//! * an open-loop fleet gives each member its own arrival process, queue
//!   bound, batch timeout, and shedding switch, and interleaves members'
//!   batch rounds by next-event time — cross-job burst interference and
//!   admission-under-overload are first-class, testable scenarios;
//! * fleets pick an SM regime via [`gpusim::PartitionMode`]: `TimeShare`
//!   (the paper's inflation-factor coupling, byte-identical to the
//!   legacy fleet) or spatial `Mps`/`MigSlices` capacity grants
//!   (`FleetBuilder::sm_reservation`; MIG quantizes down to whole
//!   slices), where a bursty member can only slow itself — a
//!   [`coordinator::policy::PartitionPolicy`] may rebalance grants at
//!   window boundaries (see `docs/partitioning.md`);
//! * policies receive a typed [`coordinator::policy::WindowObservation`]
//!   (p95/mean latency, queue depth, arrival rate, drops, sheds, power,
//!   SM utilization) each control window — richer than the legacy
//!   p95-only [`coordinator::controller::Controller`] trait, which now
//!   plugs in through an adapter. [`coordinator::policy::QueuePolicy`]
//!   uses the demand-side fields to scale *before* p95 degrades.
//!
//! [`coordinator::cluster::Cluster`] is the scheduling layer ABOVE one
//! device: jobs placed across a heterogeneous pool of GPUs and MIG
//! slices (each slice a virtual device with its own SM grant and memory
//! ceiling) by a pluggable [`coordinator::cluster::Placement`]
//! (round-robin, memory best-fit, interference-aware), every device
//! served by the same fleet engine in one global virtual-time loop — a
//! single-device cluster reproduces `Fleet` byte for byte (see
//! `docs/cluster.md`).
//!
//! Everything the paper's evaluation section reports is regenerated by
//! `cargo bench` (see DESIGN.md §6).

pub mod coordinator;
pub mod device;
pub mod gpusim;
pub mod json;
pub mod linalg;
pub mod manifest;
pub mod metrics;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod workload;

pub use coordinator::cluster::{
    Assignment, BestFit, Cluster, ClusterBuilder, ClusterOutcome, DeviceDesc, DeviceOutcome,
    DeviceSpec, InterferenceAware, Placement, PlacementError, PlacementJob, RoundRobin,
};
pub use coordinator::fleet::{Fleet, FleetBuilder, FleetOutcome};
pub use coordinator::job::{JobSpec, PAPER_JOBS};
pub use coordinator::policy::{
    Action, DemandPartition, PartitionPolicy, Policy, QueuePolicy, StaticPolicy,
    WindowObservation,
};
pub use coordinator::session::{
    ConfigError, JobOutcome, PolicySpec, RunConfig, ServingSession, SessionBuilder,
};
pub use device::Device;
pub use gpusim::{GpuSim, PartitionError, PartitionMode};
pub use workload::{ArrivalPattern, TraceError};

/// Debug-mode allocation counter (unit-test builds only): a counting
/// wrapper around the system allocator so perf-sensitive tests can
/// assert that the steady-state serving path performs zero heap
/// allocations (see `coordinator::engine` and `docs/perf.md`). The
/// count is per-thread, so concurrently running tests cannot perturb
/// each other's measurements. Not compiled into release artifacts,
/// benches, or integration tests.
#[cfg(test)]
pub(crate) mod alloc_probe {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    pub(crate) struct CountingAlloc;

    // SAFETY: delegates every operation to `System`; the bookkeeping is
    // a plain thread-local counter bump that itself never allocates.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    /// Heap allocations (alloc + realloc) performed by THIS thread since
    /// it started; subtract two readings to meter a code region.
    pub(crate) fn thread_allocs() -> u64 {
        ALLOCS.with(|c| c.get())
    }
}

#[cfg(test)]
#[global_allocator]
static ALLOC_PROBE: alloc_probe::CountingAlloc = alloc_probe::CountingAlloc;
