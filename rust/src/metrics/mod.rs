//! Metrics and reporting: percentiles, weighted CDFs, and the table/CSV
//! writers the benches use to regenerate the paper's figures.

pub mod cdf;
pub mod report;

pub use cdf::WeightedCdf;
pub use report::{csv_writer, Table};
