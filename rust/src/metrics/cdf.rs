//! Weighted latency CDF (Fig. 6 of the paper).
//!
//! Batches contribute their latency once per request they carried, so CDF
//! points are (latency, weight) pairs.

/// Cumulative distribution over weighted samples.
#[derive(Debug, Clone, Default)]
pub struct WeightedCdf {
    samples: Vec<(f64, f64)>,
    sorted: bool,
}

impl WeightedCdf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: &[(f64, f64)]) -> Self {
        let mut c = Self::new();
        for &(v, w) in samples {
            c.add(v, w);
        }
        c
    }

    /// Add a sample with weight `w` (> 0).
    pub fn add(&mut self, value: f64, w: f64) {
        assert!(w > 0.0, "weight must be positive");
        self.samples.push((value, w));
        self.sorted = false;
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            self.sorted = true;
        }
    }

    pub fn total_weight(&self) -> f64 {
        self.samples.iter().map(|(_, w)| w).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Weighted quantile (`q` in [0,1]): smallest value v such that the
    /// cumulative weight of samples <= v reaches q * total.
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        self.ensure_sorted();
        let target = q.clamp(0.0, 1.0) * self.total_weight();
        let mut acc = 0.0;
        for &(v, w) in &self.samples {
            acc += w;
            if acc >= target {
                return Some(v);
            }
        }
        Some(self.samples.last().unwrap().0)
    }

    /// Fraction of weight at or below `value`.
    pub fn fraction_below(&mut self, value: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let total = self.total_weight();
        let mut acc = 0.0;
        for &(v, w) in &self.samples {
            if v > value {
                break;
            }
            acc += w;
        }
        acc / total
    }

    /// `n` evenly spaced CDF points `(value, cumulative_fraction)` for
    /// plotting (Fig. 6 series).
    pub fn curve(&mut self, n: usize) -> Vec<(f64, f64)> {
        if self.samples.is_empty() || n == 0 {
            return Vec::new();
        }
        self.ensure_sorted();
        let total = self.total_weight();
        let mut out = Vec::with_capacity(n);
        let mut acc = 0.0;
        let mut next_i = 0usize;
        for &(v, w) in &self.samples {
            acc += w;
            let frac = acc / total;
            while next_i < n && frac >= (next_i + 1) as f64 / n as f64 - 1e-12 {
                out.push((v, frac));
                next_i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unweighted_quantiles() {
        let mut c = WeightedCdf::new();
        for i in 1..=100 {
            c.add(i as f64, 1.0);
        }
        assert_eq!(c.quantile(0.95), Some(95.0));
        assert_eq!(c.quantile(0.0), Some(1.0));
        assert_eq!(c.quantile(1.0), Some(100.0));
    }

    #[test]
    fn weights_shift_quantiles() {
        let mut c = WeightedCdf::new();
        c.add(1.0, 95.0);
        c.add(100.0, 5.0);
        assert_eq!(c.quantile(0.95), Some(1.0));
        assert_eq!(c.quantile(0.96), Some(100.0));
        assert!((c.fraction_below(1.0) - 0.95).abs() < 1e-12);
        assert!((c.fraction_below(0.5) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn curve_monotone() {
        let mut c = WeightedCdf::new();
        for i in 0..50 {
            c.add((i * 7 % 13) as f64, 1.0 + (i % 3) as f64);
        }
        let pts = c.curve(10);
        assert!(!pts.is_empty());
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_cdf() {
        let mut c = WeightedCdf::new();
        assert_eq!(c.quantile(0.5), None);
        assert!(c.curve(5).is_empty());
        assert_eq!(c.fraction_below(1.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_weight_rejected() {
        WeightedCdf::new().add(1.0, 0.0);
    }
}
