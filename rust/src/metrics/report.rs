//! Plain-text tables and CSV output for the bench harnesses.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// A simple fixed-column text table (the benches print paper tables with
/// it so the rows can be eyeballed against the PDF).
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$}  ", c, w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write the table as CSV to `path` (creates parent dirs).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(w, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// CSV writer for raw series (figure data).
pub fn csv_writer(path: impl AsRef<Path>, header: &str) -> std::io::Result<BufWriter<File>> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{header}")?;
    Ok(w)
}

/// Format helper: fixed 2-decimal float cell.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Format helper: fixed 1-decimal float cell.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_padded() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("long-header"));
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        Table::new("T", &["a", "b"]).row(&["1".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("dnnscaler-test-{}-{:?}", std::process::id(), std::thread::current().id()));
        let path = dir.join("sub/t.csv");
        let mut t = Table::new("T", &["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,2\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(f1(2.36), "2.4");
    }
}
