//! Request arrival generators (open-loop Poisson, bursty, uniform) plus
//! the `Closed` sentinel used by `ServingSession` to request the legacy
//! closed-loop serving mode (batches issued back-to-back, no queue).

use crate::rng::Rng;

/// Arrival pattern of a workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Closed loop: no external arrival process — the server issues
    /// batches back-to-back (the paper's evaluation setup). Generators
    /// built from this pattern yield no arrivals.
    Closed,
    /// Deterministic arrivals at exactly `rate` requests/s.
    Uniform { rate: f64 },
    /// Poisson process at `rate` requests/s.
    Poisson { rate: f64 },
    /// Poisson base load with periodic bursts: every `period_s` seconds a
    /// burst multiplies the rate by `factor` for `burst_s` seconds
    /// (the AWS "bursty inference workloads" shape from §3.3).
    Bursty { rate: f64, factor: f64, period_s: f64, burst_s: f64 },
}

impl ArrivalPattern {
    /// Closed-loop serving (no arrival process).
    pub fn closed() -> Self {
        ArrivalPattern::Closed
    }

    /// Deterministic arrivals at `rate` requests/s.
    pub fn uniform(rate: f64) -> Self {
        ArrivalPattern::Uniform { rate }
    }

    /// Poisson arrivals at `rate` requests/s.
    pub fn poisson(rate: f64) -> Self {
        ArrivalPattern::Poisson { rate }
    }

    /// Poisson base `rate` with `factor`x bursts of `burst_s` seconds
    /// every `period_s` seconds.
    pub fn bursty(rate: f64, factor: f64, period_s: f64, burst_s: f64) -> Self {
        ArrivalPattern::Bursty { rate, factor, period_s, burst_s }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, ArrivalPattern::Closed)
    }

    /// Long-run mean offered rate (requests/s); 0 for `Closed`.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                rate * (factor * burst_s + (period_s - burst_s)) / period_s
            }
        }
    }
}

/// Generates request arrival timestamps (seconds).
#[derive(Debug, Clone)]
pub struct ArrivalGenerator {
    pattern: ArrivalPattern,
    rng: Rng,
    now_s: f64,
}

impl ArrivalGenerator {
    pub fn new(pattern: ArrivalPattern, seed: u64) -> Self {
        ArrivalGenerator { pattern, rng: Rng::new(seed), now_s: 0.0 }
    }

    /// Instantaneous rate at time `t` (requests/s).
    pub fn rate_at(&self, t: f64) -> f64 {
        match self.pattern {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                let phase = t % period_s;
                if phase < burst_s {
                    rate * factor
                } else {
                    rate
                }
            }
        }
    }

    /// Next arrival timestamp (monotone, seconds); `f64::INFINITY` for the
    /// `Closed` pattern (it never produces arrivals).
    pub fn next_arrival(&mut self) -> f64 {
        let gap = match self.pattern {
            ArrivalPattern::Closed => return f64::INFINITY,
            ArrivalPattern::Uniform { rate } => 1.0 / rate,
            ArrivalPattern::Poisson { .. } | ArrivalPattern::Bursty { .. } => {
                // Thinning-free exponential gap at the local rate; for the
                // bursty pattern the rate is evaluated at the current time,
                // which is exact for bursts much longer than a gap.
                self.rng.exponential(self.rate_at(self.now_s).max(1e-9))
            }
        };
        self.now_s += gap;
        self.now_s
    }

    /// All arrivals in `[0, horizon_s)`.
    pub fn arrivals_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival();
            if t >= horizon_s {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_rate_exact() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Uniform { rate: 100.0 }, 1);
        let a = g.arrivals_until(1.0);
        assert_eq!(a.len(), 99); // arrivals at 0.01, 0.02, ..., 0.99
        assert!((a[1] - a[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn poisson_rate_within_tolerance() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 500.0 }, 2);
        let a = g.arrivals_until(20.0);
        let rate = a.len() as f64 / 20.0;
        assert!((rate - 500.0).abs() / 500.0 < 0.05, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 5.0, period_s: 1.0, burst_s: 0.2 },
            3,
        );
        let a = g.arrivals_until(5.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bursts_raise_local_rate() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 10.0, period_s: 1.0, burst_s: 0.2 },
            4,
        );
        let a = g.arrivals_until(10.0);
        let in_burst = a.iter().filter(|t| *t % 1.0 < 0.2).count() as f64;
        let off_burst = a.iter().filter(|t| *t % 1.0 >= 0.2).count() as f64;
        // Burst windows are 1/4 the duration of off-burst but 10x rate:
        // expect ~2.5x the requests.
        assert!(in_burst > 1.5 * off_burst, "in {in_burst} off {off_burst}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        let mut b = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        assert_eq!(a.arrivals_until(2.0), b.arrivals_until(2.0));
    }

    #[test]
    fn closed_pattern_never_arrives() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::closed(), 1);
        assert!(g.arrivals_until(1e6).is_empty());
        assert_eq!(g.next_arrival(), f64::INFINITY);
        assert_eq!(g.rate_at(12.0), 0.0);
        assert!(ArrivalPattern::closed().is_closed());
        assert!(!ArrivalPattern::poisson(10.0).is_closed());
    }

    #[test]
    fn mean_rate_matches_pattern() {
        assert_eq!(ArrivalPattern::closed().mean_rate(), 0.0);
        assert_eq!(ArrivalPattern::poisson(80.0).mean_rate(), 80.0);
        // 3x bursts for 1 s out of every 4 s: mean = (3 + 3) / 4 = 1.5x.
        let b = ArrivalPattern::bursty(40.0, 3.0, 4.0, 1.0);
        assert!((b.mean_rate() - 60.0).abs() < 1e-9);
    }
}
