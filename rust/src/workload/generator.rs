//! Request arrival generators (open-loop Poisson, bursty, uniform,
//! trace replay) plus the `Closed` sentinel used by `ServingSession` to
//! request the legacy closed-loop serving mode (batches issued
//! back-to-back, no queue).

use crate::rng::Rng;

use std::fmt;
use std::path::Path;

/// Arrival pattern of a workload.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalPattern {
    /// Closed loop: no external arrival process — the server issues
    /// batches back-to-back (the paper's evaluation setup). Generators
    /// built from this pattern yield no arrivals.
    Closed,
    /// Deterministic arrivals at exactly `rate` requests/s.
    Uniform { rate: f64 },
    /// Poisson process at `rate` requests/s.
    Poisson { rate: f64 },
    /// Poisson base load with periodic bursts: every `period_s` seconds a
    /// burst multiplies the rate by `factor` for `burst_s` seconds
    /// (the AWS "bursty inference workloads" shape from §3.3).
    Bursty { rate: f64, factor: f64, period_s: f64, burst_s: f64 },
    /// Replay of recorded arrival timestamps (seconds, sorted ascending,
    /// non-negative) — e.g. an Azure Functions or Twitter trace. The
    /// generator emits exactly these timestamps in order and then goes
    /// silent (`f64::INFINITY`). Build with [`ArrivalPattern::trace`] or
    /// [`ArrivalPattern::from_trace_file`], which validate the data.
    Trace(Vec<f64>),
}

/// Why a recorded arrival trace was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// A trace must contain at least one arrival.
    Empty,
    /// A timestamp was negative (serving starts at t = 0).
    Negative { index: usize, t: f64 },
    /// Timestamps must be sorted ascending (equal timestamps are fine).
    Unsorted { index: usize, prev: f64, t: f64 },
    /// NaN or infinite timestamp.
    NotFinite { index: usize },
    /// A trace-file line did not parse as a number.
    Parse { line: usize, token: String },
    /// The trace file could not be read.
    Io { path: String, error: String },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Empty => write!(f, "trace has no arrivals"),
            TraceError::Negative { index, t } => {
                write!(f, "trace timestamp #{index} is negative ({t})")
            }
            TraceError::Unsorted { index, prev, t } => {
                write!(f, "trace timestamp #{index} ({t}) precedes its predecessor ({prev})")
            }
            TraceError::NotFinite { index } => {
                write!(f, "trace timestamp #{index} is NaN or infinite")
            }
            TraceError::Parse { line, token } => {
                write!(f, "trace line {line}: {token:?} is not a number")
            }
            TraceError::Io { path, error } => write!(f, "cannot read trace {path:?}: {error}"),
        }
    }
}

impl std::error::Error for TraceError {}

/// Validate a candidate arrival trace (sorted, non-negative, finite,
/// non-empty). Shared by the constructors and the session builders, so a
/// hand-built `ArrivalPattern::Trace` is re-checked before serving.
pub fn validate_trace(ts: &[f64]) -> Result<(), TraceError> {
    if ts.is_empty() {
        return Err(TraceError::Empty);
    }
    let mut prev = 0.0f64;
    for (index, &t) in ts.iter().enumerate() {
        if !t.is_finite() {
            return Err(TraceError::NotFinite { index });
        }
        if t < 0.0 {
            return Err(TraceError::Negative { index, t });
        }
        if t < prev {
            return Err(TraceError::Unsorted { index, prev, t });
        }
        prev = t;
    }
    Ok(())
}

impl ArrivalPattern {
    /// Closed-loop serving (no arrival process).
    pub fn closed() -> Self {
        ArrivalPattern::Closed
    }

    /// Deterministic arrivals at `rate` requests/s.
    pub fn uniform(rate: f64) -> Self {
        ArrivalPattern::Uniform { rate }
    }

    /// Poisson arrivals at `rate` requests/s.
    pub fn poisson(rate: f64) -> Self {
        ArrivalPattern::Poisson { rate }
    }

    /// Poisson base `rate` with `factor`x bursts of `burst_s` seconds
    /// every `period_s` seconds.
    pub fn bursty(rate: f64, factor: f64, period_s: f64, burst_s: f64) -> Self {
        ArrivalPattern::Bursty { rate, factor, period_s, burst_s }
    }

    /// Replay of recorded arrival `timestamps` (seconds). Rejects empty,
    /// unsorted, negative, or non-finite data with a typed [`TraceError`].
    pub fn trace(timestamps: Vec<f64>) -> Result<Self, TraceError> {
        validate_trace(&timestamps)?;
        Ok(ArrivalPattern::Trace(timestamps))
    }

    /// Parse a trace file: one arrival timestamp (seconds) per line, in
    /// the first whitespace-separated column (extra columns are ignored);
    /// blank lines and `#` comments are skipped. The resulting trace is
    /// validated like [`ArrivalPattern::trace`].
    pub fn from_trace_file(path: impl AsRef<Path>) -> Result<Self, TraceError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| TraceError::Io {
            path: path.display().to_string(),
            error: e.to_string(),
        })?;
        let mut ts = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let token = line.split_whitespace().next().unwrap_or(line);
            let t: f64 = token
                .parse()
                .map_err(|_| TraceError::Parse { line: i + 1, token: token.to_string() })?;
            ts.push(t);
        }
        Self::trace(ts)
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, ArrivalPattern::Closed)
    }

    /// Long-run mean offered rate (requests/s); 0 for `Closed`. For a
    /// trace this is the count divided by the trace span `[0, last]`.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                rate * (factor * burst_s + (period_s - burst_s)) / period_s
            }
            ArrivalPattern::Trace(ts) => match ts.last() {
                Some(&last) if last > 0.0 => ts.len() as f64 / last,
                _ => 0.0,
            },
        }
    }
}

/// How many arrivals the serving engine's feed prefetches per refill
/// (see `coordinator::engine::Feed`). Chunked synthesis amortizes the
/// per-arrival call and keeps the generator's RNG state hot in cache;
/// the stream itself is identical — a generator produces the same
/// timestamp sequence whether it is drained one at a time or in chunks.
pub const ARRIVAL_CHUNK: usize = 64;

/// Generates request arrival timestamps (seconds).
#[derive(Debug, Clone)]
pub struct ArrivalGenerator {
    pattern: ArrivalPattern,
    rng: Rng,
    now_s: f64,
    /// Next unread entry of a `Trace` pattern.
    trace_idx: usize,
    /// Arrival generated but not yet handed out: `arrivals_until` stashes
    /// its horizon-overshooting sample here so no arrival is ever lost
    /// (a replayed trace must emit *exactly* its timestamps).
    pending: Option<f64>,
}

impl ArrivalGenerator {
    pub fn new(pattern: ArrivalPattern, seed: u64) -> Self {
        ArrivalGenerator { pattern, rng: Rng::new(seed), now_s: 0.0, trace_idx: 0, pending: None }
    }

    /// Instantaneous rate at time `t` (requests/s). A trace reports its
    /// long-run mean (its instantaneous rate is a spike train).
    pub fn rate_at(&self, t: f64) -> f64 {
        match &self.pattern {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                let phase = t % period_s;
                if phase < *burst_s {
                    rate * factor
                } else {
                    *rate
                }
            }
            ArrivalPattern::Trace(_) => self.pattern.mean_rate(),
        }
    }

    /// Next arrival timestamp (monotone, seconds); `f64::INFINITY` for the
    /// `Closed` pattern (it never produces arrivals) and for an exhausted
    /// `Trace`.
    pub fn next_arrival(&mut self) -> f64 {
        if let Some(t) = self.pending.take() {
            return t;
        }
        if let ArrivalPattern::Trace(ts) = &self.pattern {
            return match ts.get(self.trace_idx) {
                Some(&t) => {
                    self.trace_idx += 1;
                    self.now_s = t;
                    t
                }
                None => f64::INFINITY,
            };
        }
        let gap = match self.pattern {
            ArrivalPattern::Closed => return f64::INFINITY,
            ArrivalPattern::Uniform { rate } => 1.0 / rate,
            ArrivalPattern::Poisson { .. } | ArrivalPattern::Bursty { .. } => {
                // Thinning-free exponential gap at the local rate; for the
                // bursty pattern the rate is evaluated at the current time,
                // which is exact for bursts much longer than a gap.
                self.rng.exponential(self.rate_at(self.now_s).max(1e-9))
            }
            ArrivalPattern::Trace(_) => unreachable!("handled above"),
        };
        self.now_s += gap;
        self.now_s
    }

    /// Append up to `max` upcoming arrivals to `out`, stopping early when
    /// the stream ends (`Closed`, or an exhausted `Trace`). Returns how
    /// many were appended; 0 means the stream is exhausted for good.
    ///
    /// This is the chunked form of [`ArrivalGenerator::next_arrival`]:
    /// the timestamps produced are exactly the same sequence (traces are
    /// copied verbatim; synthetic patterns consume the RNG in the same
    /// order), just synthesized in batches so the serving engine pays one
    /// refill per [`ARRIVAL_CHUNK`] requests instead of one generator
    /// call per request.
    pub fn fill_next(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        // Trace fast path: memcpy the next slice of recorded timestamps.
        // (Skipped when a horizon-overshooting sample is pending — the
        // generic loop below consumes it first via `next_arrival`.)
        if self.pending.is_none() {
            if let ArrivalPattern::Trace(ts) = &self.pattern {
                let take = max.min(ts.len().saturating_sub(self.trace_idx));
                out.extend_from_slice(&ts[self.trace_idx..self.trace_idx + take]);
                self.trace_idx += take;
                if take > 0 {
                    self.now_s = ts[self.trace_idx - 1];
                }
                return take;
            }
        }
        let mut n = 0;
        while n < max {
            let t = self.next_arrival();
            if !t.is_finite() {
                break;
            }
            out.push(t);
            n += 1;
        }
        n
    }

    /// All arrivals in `[0, horizon_s)`. The first arrival at or past the
    /// horizon is retained (not discarded): the next call — to this
    /// method or [`ArrivalGenerator::next_arrival`] — yields it.
    pub fn arrivals_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival();
            if t >= horizon_s {
                if t.is_finite() {
                    self.pending = Some(t);
                }
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_rate_exact() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Uniform { rate: 100.0 }, 1);
        let a = g.arrivals_until(1.0);
        assert_eq!(a.len(), 99); // arrivals at 0.01, 0.02, ..., 0.99
        assert!((a[1] - a[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn poisson_rate_within_tolerance() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 500.0 }, 2);
        let a = g.arrivals_until(20.0);
        let rate = a.len() as f64 / 20.0;
        assert!((rate - 500.0).abs() / 500.0 < 0.05, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 5.0, period_s: 1.0, burst_s: 0.2 },
            3,
        );
        let a = g.arrivals_until(5.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bursts_raise_local_rate() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 10.0, period_s: 1.0, burst_s: 0.2 },
            4,
        );
        let a = g.arrivals_until(10.0);
        let in_burst = a.iter().filter(|t| *t % 1.0 < 0.2).count() as f64;
        let off_burst = a.iter().filter(|t| *t % 1.0 >= 0.2).count() as f64;
        // Burst windows are 1/4 the duration of off-burst but 10x rate:
        // expect ~2.5x the requests.
        assert!(in_burst > 1.5 * off_burst, "in {in_burst} off {off_burst}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        let mut b = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        assert_eq!(a.arrivals_until(2.0), b.arrivals_until(2.0));
    }

    #[test]
    fn closed_pattern_never_arrives() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::closed(), 1);
        assert!(g.arrivals_until(1e6).is_empty());
        assert_eq!(g.next_arrival(), f64::INFINITY);
        assert_eq!(g.rate_at(12.0), 0.0);
        assert!(ArrivalPattern::closed().is_closed());
        assert!(!ArrivalPattern::poisson(10.0).is_closed());
    }

    #[test]
    fn mean_rate_matches_pattern() {
        assert_eq!(ArrivalPattern::closed().mean_rate(), 0.0);
        assert_eq!(ArrivalPattern::poisson(80.0).mean_rate(), 80.0);
        // 3x bursts for 1 s out of every 4 s: mean = (3 + 3) / 4 = 1.5x.
        let b = ArrivalPattern::bursty(40.0, 3.0, 4.0, 1.0);
        assert!((b.mean_rate() - 60.0).abs() < 1e-9);
        // 4 arrivals over [0, 2] s -> 2 req/s.
        let t = ArrivalPattern::trace(vec![0.5, 1.0, 1.5, 2.0]).unwrap();
        assert!((t.mean_rate() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn trace_replays_exact_timestamps_then_goes_silent() {
        let ts = vec![0.0, 0.1, 0.1, 0.35, 2.0];
        let mut g = ArrivalGenerator::new(ArrivalPattern::trace(ts.clone()).unwrap(), 99);
        for &want in &ts {
            assert_eq!(g.next_arrival(), want);
        }
        assert_eq!(g.next_arrival(), f64::INFINITY);
        assert_eq!(g.next_arrival(), f64::INFINITY);
        // The seed is irrelevant: replay consumes no randomness.
        let mut a = ArrivalGenerator::new(ArrivalPattern::trace(ts.clone()).unwrap(), 1);
        let mut b = ArrivalGenerator::new(ArrivalPattern::trace(ts).unwrap(), 2);
        assert_eq!(a.arrivals_until(1.0), b.arrivals_until(1.0));
    }

    #[test]
    fn trace_constructor_rejects_bad_data() {
        assert_eq!(ArrivalPattern::trace(vec![]), Err(TraceError::Empty));
        assert_eq!(
            ArrivalPattern::trace(vec![0.0, -1.0]),
            Err(TraceError::Negative { index: 1, t: -1.0 })
        );
        assert_eq!(
            ArrivalPattern::trace(vec![0.0, 2.0, 1.0]),
            Err(TraceError::Unsorted { index: 2, prev: 2.0, t: 1.0 })
        );
        assert!(matches!(
            ArrivalPattern::trace(vec![0.0, f64::NAN]),
            Err(TraceError::NotFinite { index: 1 })
        ));
        assert!(matches!(
            ArrivalPattern::trace(vec![f64::INFINITY]),
            Err(TraceError::NotFinite { index: 0 })
        ));
        // Equal timestamps (simultaneous arrivals) are allowed.
        assert!(ArrivalPattern::trace(vec![1.0, 1.0]).is_ok());
    }

    #[test]
    fn fill_next_matches_one_at_a_time_synthesis() {
        // Chunked synthesis must produce the identical timestamp stream,
        // for every pattern kind, whatever the chunk size.
        let patterns = [
            ArrivalPattern::uniform(50.0),
            ArrivalPattern::poisson(120.0),
            ArrivalPattern::bursty(80.0, 4.0, 1.0, 0.25),
            ArrivalPattern::trace(vec![0.0, 0.1, 0.1, 0.4, 2.5]).unwrap(),
        ];
        for pattern in patterns {
            for chunk in [1usize, 3, 64] {
                let mut one = ArrivalGenerator::new(pattern.clone(), 77);
                let mut many = ArrivalGenerator::new(pattern.clone(), 77);
                let mut got: Vec<f64> = Vec::new();
                while got.len() < 200 {
                    if many.fill_next(&mut got, chunk) == 0 {
                        break;
                    }
                }
                for &want in &got {
                    assert_eq!(one.next_arrival(), want);
                }
                // Both generators agree on what comes next (INFINITY for
                // an exhausted trace, the same sample otherwise).
                assert_eq!(one.next_arrival(), {
                    let mut rest = Vec::new();
                    if many.fill_next(&mut rest, 1) == 0 {
                        f64::INFINITY
                    } else {
                        rest[0]
                    }
                });
            }
        }
    }

    #[test]
    fn fill_next_is_silent_for_closed_and_exhausted_streams() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::closed(), 1);
        let mut out = Vec::new();
        assert_eq!(g.fill_next(&mut out, 16), 0);
        assert!(out.is_empty());
        let mut t = ArrivalGenerator::new(ArrivalPattern::trace(vec![0.5]).unwrap(), 1);
        assert_eq!(t.fill_next(&mut out, 16), 1);
        assert_eq!(t.fill_next(&mut out, 16), 0);
        assert_eq!(out, vec![0.5]);
    }

    #[test]
    fn fill_next_respects_a_pending_horizon_sample() {
        // arrivals_until stashes its overshooting sample; the next chunk
        // must begin with it (trace and synthetic alike).
        let mut g = ArrivalGenerator::new(ArrivalPattern::trace(vec![0.1, 0.9, 1.2]).unwrap(), 1);
        assert_eq!(g.arrivals_until(0.5), vec![0.1]);
        let mut out = Vec::new();
        assert_eq!(g.fill_next(&mut out, 8), 2);
        assert_eq!(out, vec![0.9, 1.2]);
    }

    #[test]
    fn trace_file_parser_skips_blanks_and_comments() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-ok-{}.txt", std::process::id()));
        std::fs::write(&path, "# a recorded trace\n\n0.0\n0.5 extra columns ignored\n\n1.25\n")
            .unwrap();
        let got = ArrivalPattern::from_trace_file(&path);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(got, Ok(ArrivalPattern::Trace(vec![0.0, 0.5, 1.25])));
    }

    #[test]
    fn trace_file_parser_reports_line_and_io_errors() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "0.0\noops\n").unwrap();
        let got = ArrivalPattern::from_trace_file(&path);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(got, Err(TraceError::Parse { line: 2, token: "oops".into() }));
        assert!(matches!(
            ArrivalPattern::from_trace_file("/nonexistent/dnnscaler-trace.txt"),
            Err(TraceError::Io { .. })
        ));
    }
}
