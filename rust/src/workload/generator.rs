//! Request arrival generators (open-loop Poisson, bursty, uniform).

use crate::rng::Rng;

/// Arrival pattern of an open-loop workload.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalPattern {
    /// Deterministic arrivals at exactly `rate` requests/s.
    Uniform { rate: f64 },
    /// Poisson process at `rate` requests/s.
    Poisson { rate: f64 },
    /// Poisson base load with periodic bursts: every `period_s` seconds a
    /// burst multiplies the rate by `factor` for `burst_s` seconds
    /// (the AWS "bursty inference workloads" shape from §3.3).
    Bursty { rate: f64, factor: f64, period_s: f64, burst_s: f64 },
}

/// Generates request arrival timestamps (seconds).
#[derive(Debug, Clone)]
pub struct ArrivalGenerator {
    pattern: ArrivalPattern,
    rng: Rng,
    now_s: f64,
}

impl ArrivalGenerator {
    pub fn new(pattern: ArrivalPattern, seed: u64) -> Self {
        ArrivalGenerator { pattern, rng: Rng::new(seed), now_s: 0.0 }
    }

    /// Instantaneous rate at time `t` (requests/s).
    pub fn rate_at(&self, t: f64) -> f64 {
        match self.pattern {
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                let phase = t % period_s;
                if phase < burst_s {
                    rate * factor
                } else {
                    rate
                }
            }
        }
    }

    /// Next arrival timestamp (monotone, seconds).
    pub fn next_arrival(&mut self) -> f64 {
        let gap = match self.pattern {
            ArrivalPattern::Uniform { rate } => 1.0 / rate,
            ArrivalPattern::Poisson { .. } | ArrivalPattern::Bursty { .. } => {
                // Thinning-free exponential gap at the local rate; for the
                // bursty pattern the rate is evaluated at the current time,
                // which is exact for bursts much longer than a gap.
                self.rng.exponential(self.rate_at(self.now_s).max(1e-9))
            }
        };
        self.now_s += gap;
        self.now_s
    }

    /// All arrivals in `[0, horizon_s)`.
    pub fn arrivals_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival();
            if t >= horizon_s {
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_rate_exact() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Uniform { rate: 100.0 }, 1);
        let a = g.arrivals_until(1.0);
        assert_eq!(a.len(), 99); // arrivals at 0.01, 0.02, ..., 0.99
        assert!((a[1] - a[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn poisson_rate_within_tolerance() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 500.0 }, 2);
        let a = g.arrivals_until(20.0);
        let rate = a.len() as f64 / 20.0;
        assert!((rate - 500.0).abs() / 500.0 < 0.05, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 5.0, period_s: 1.0, burst_s: 0.2 },
            3,
        );
        let a = g.arrivals_until(5.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bursts_raise_local_rate() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 10.0, period_s: 1.0, burst_s: 0.2 },
            4,
        );
        let a = g.arrivals_until(10.0);
        let in_burst = a.iter().filter(|t| *t % 1.0 < 0.2).count() as f64;
        let off_burst = a.iter().filter(|t| *t % 1.0 >= 0.2).count() as f64;
        // Burst windows are 1/4 the duration of off-burst but 10x rate:
        // expect ~2.5x the requests.
        assert!(in_burst > 1.5 * off_burst, "in {in_burst} off {off_burst}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        let mut b = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        assert_eq!(a.arrivals_until(2.0), b.arrivals_until(2.0));
    }
}
