//! Request arrival generators (open-loop Poisson, bursty, uniform,
//! trace replay) plus the `Closed` sentinel used by `ServingSession` to
//! request the legacy closed-loop serving mode (batches issued
//! back-to-back, no queue).

use crate::rng::Rng;

use std::fmt;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Arrival pattern of a workload.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalPattern {
    /// Closed loop: no external arrival process — the server issues
    /// batches back-to-back (the paper's evaluation setup). Generators
    /// built from this pattern yield no arrivals.
    Closed,
    /// Deterministic arrivals at exactly `rate` requests/s.
    Uniform { rate: f64 },
    /// Poisson process at `rate` requests/s.
    Poisson { rate: f64 },
    /// Poisson base load with periodic bursts: every `period_s` seconds a
    /// burst multiplies the rate by `factor` for `burst_s` seconds
    /// (the AWS "bursty inference workloads" shape from §3.3).
    Bursty { rate: f64, factor: f64, period_s: f64, burst_s: f64 },
    /// Replay of recorded arrival timestamps (seconds, sorted ascending,
    /// non-negative) — e.g. an Azure Functions or Twitter trace. The
    /// generator emits exactly these timestamps in order and then goes
    /// silent (`f64::INFINITY`). Build with [`ArrivalPattern::trace`],
    /// which validates the data.
    Trace(Vec<f64>),
    /// Replay of a recorded trace streamed from disk chunk-by-chunk. The
    /// file is validated once when the source is opened
    /// ([`TraceSource::open`]); each generator then re-reads it lazily
    /// through a buffered reader, so a full-day trace is never
    /// materialized, and cloning the pattern into every fleet member
    /// shares one [`TraceSource`] instead of copying the arrival vector.
    /// Build with [`ArrivalPattern::from_trace_file`].
    Streamed(Arc<TraceSource>),
}

/// Why a recorded arrival trace was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// A trace must contain at least one arrival.
    Empty,
    /// A timestamp was negative (serving starts at t = 0).
    Negative { index: usize, t: f64 },
    /// Timestamps must be sorted ascending (equal timestamps are fine).
    Unsorted { index: usize, prev: f64, t: f64 },
    /// NaN or infinite timestamp.
    NotFinite { index: usize },
    /// A trace-file line did not parse as a number.
    Parse { line: usize, token: String },
    /// The trace file could not be read.
    Io { path: String, error: String },
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Empty => write!(f, "trace has no arrivals"),
            TraceError::Negative { index, t } => {
                write!(f, "trace timestamp #{index} is negative ({t})")
            }
            TraceError::Unsorted { index, prev, t } => {
                write!(f, "trace timestamp #{index} ({t}) precedes its predecessor ({prev})")
            }
            TraceError::NotFinite { index } => {
                write!(f, "trace timestamp #{index} is NaN or infinite")
            }
            TraceError::Parse { line, token } => {
                write!(f, "trace line {line}: {token:?} is not a number")
            }
            TraceError::Io { path, error } => write!(f, "cannot read trace {path:?}: {error}"),
        }
    }
}

impl std::error::Error for TraceError {}

/// Validate a candidate arrival trace (sorted, non-negative, finite,
/// non-empty). Shared by the constructors and the session builders, so a
/// hand-built `ArrivalPattern::Trace` is re-checked before serving.
pub fn validate_trace(ts: &[f64]) -> Result<(), TraceError> {
    if ts.is_empty() {
        return Err(TraceError::Empty);
    }
    let mut prev = 0.0f64;
    for (index, &t) in ts.iter().enumerate() {
        if !t.is_finite() {
            return Err(TraceError::NotFinite { index });
        }
        if t < 0.0 {
            return Err(TraceError::Negative { index, t });
        }
        if t < prev {
            return Err(TraceError::Unsorted { index, prev, t });
        }
        prev = t;
    }
    Ok(())
}

/// Parse one trace-file line: the first whitespace-separated column is
/// the arrival timestamp (seconds), extra columns are ignored; blank
/// lines and `#` comments yield `None`. `line_no` is 1-based.
fn parse_trace_line(line_no: usize, raw: &str) -> Result<Option<f64>, TraceError> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let token = line.split_whitespace().next().unwrap_or(line);
    token
        .parse()
        .map(Some)
        .map_err(|_| TraceError::Parse { line: line_no, token: token.to_string() })
}

/// A validated on-disk arrival trace. Opening the source makes one
/// streaming pass over the file to check the data — same rules as
/// [`validate_trace`]: sorted, non-negative, finite, at least one
/// arrival — and records the arrival count and span. The timestamps
/// themselves stay on disk; [`ArrivalGenerator`] re-reads them lazily
/// chunk-by-chunk, so validation and replay both run in O(1) memory.
#[derive(Debug)]
pub struct TraceSource {
    path: PathBuf,
    len: usize,
    last_s: f64,
}

/// Sources compare by their identity-defining metadata (path, count,
/// span): two patterns over the same validated file are interchangeable.
impl PartialEq for TraceSource {
    fn eq(&self, other: &Self) -> bool {
        self.path == other.path && self.len == other.len && self.last_s == other.last_s
    }
}

impl TraceSource {
    /// Open and validate `path` (one timestamp per line, first column,
    /// `#` comments and blanks skipped) without materializing the
    /// arrivals. A file with zero arrivals is a typed
    /// [`TraceError::Empty`], not a silent never-firing source.
    pub fn open(path: impl AsRef<Path>) -> Result<TraceSource, TraceError> {
        let path = path.as_ref().to_path_buf();
        let shown = path.display().to_string();
        let file = File::open(&path)
            .map_err(|e| TraceError::Io { path: shown.clone(), error: e.to_string() })?;
        let mut reader = BufReader::new(file);
        let mut raw = String::new();
        let (mut line_no, mut len, mut prev) = (0usize, 0usize, 0.0f64);
        loop {
            raw.clear();
            let read = reader
                .read_line(&mut raw)
                .map_err(|e| TraceError::Io { path: shown.clone(), error: e.to_string() })?;
            if read == 0 {
                break;
            }
            line_no += 1;
            let Some(t) = parse_trace_line(line_no, &raw)? else { continue };
            if !t.is_finite() {
                return Err(TraceError::NotFinite { index: len });
            }
            if t < 0.0 {
                return Err(TraceError::Negative { index: len, t });
            }
            if t < prev {
                return Err(TraceError::Unsorted { index: len, prev, t });
            }
            prev = t;
            len += 1;
        }
        if len == 0 {
            return Err(TraceError::Empty);
        }
        Ok(TraceSource { path, len, last_s: prev })
    }

    /// Number of arrivals in the trace (always at least 1).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Timestamp of the last arrival — the trace span's right edge.
    pub fn last_s(&self) -> f64 {
        self.last_s
    }

    /// The backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Lazily-opened reader over a [`TraceSource`], owned by one generator:
/// a buffered file handle plus a line scratch buffer.
struct TraceStream {
    reader: BufReader<File>,
    line: String,
    line_no: usize,
}

impl TraceStream {
    /// Open the source and skip the first `skip` arrivals (how a cloned
    /// generator resumes from its `trace_idx`). `None` when the file has
    /// changed underneath the validated source (treated as exhaustion).
    fn open_at(src: &TraceSource, skip: usize) -> Option<TraceStream> {
        let file = File::open(src.path()).ok()?;
        let mut s = TraceStream { reader: BufReader::new(file), line: String::new(), line_no: 0 };
        for _ in 0..skip {
            s.next()?;
        }
        Some(s)
    }

    /// Next arrival timestamp, or `None` at end of file. The file was
    /// validated by [`TraceSource::open`]; if it mutates mid-run (an IO
    /// or parse failure on data that validated), the stream ends early —
    /// debug builds assert, release builds treat it as exhaustion.
    fn next(&mut self) -> Option<f64> {
        loop {
            self.line.clear();
            let read = match self.reader.read_line(&mut self.line) {
                Ok(n) => n,
                Err(e) => {
                    debug_assert!(false, "validated trace became unreadable: {e}");
                    return None;
                }
            };
            if read == 0 {
                return None;
            }
            self.line_no += 1;
            match parse_trace_line(self.line_no, &self.line) {
                Ok(Some(t)) => return Some(t),
                Ok(None) => continue,
                Err(e) => {
                    debug_assert!(false, "validated trace changed mid-run: {e}");
                    return None;
                }
            }
        }
    }
}

impl fmt::Debug for TraceStream {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TraceStream").field("line_no", &self.line_no).finish()
    }
}

impl ArrivalPattern {
    /// Closed-loop serving (no arrival process).
    pub fn closed() -> Self {
        ArrivalPattern::Closed
    }

    /// Deterministic arrivals at `rate` requests/s.
    pub fn uniform(rate: f64) -> Self {
        ArrivalPattern::Uniform { rate }
    }

    /// Poisson arrivals at `rate` requests/s.
    pub fn poisson(rate: f64) -> Self {
        ArrivalPattern::Poisson { rate }
    }

    /// Poisson base `rate` with `factor`x bursts of `burst_s` seconds
    /// every `period_s` seconds.
    pub fn bursty(rate: f64, factor: f64, period_s: f64, burst_s: f64) -> Self {
        ArrivalPattern::Bursty { rate, factor, period_s, burst_s }
    }

    /// Replay of recorded arrival `timestamps` (seconds). Rejects empty,
    /// unsorted, negative, or non-finite data with a typed [`TraceError`].
    pub fn trace(timestamps: Vec<f64>) -> Result<Self, TraceError> {
        validate_trace(&timestamps)?;
        Ok(ArrivalPattern::Trace(timestamps))
    }

    /// Open a trace file for streamed replay: one arrival timestamp
    /// (seconds) per line, in the first whitespace-separated column
    /// (extra columns are ignored); blank lines and `#` comments are
    /// skipped. The file is validated up front with the same rules as
    /// [`ArrivalPattern::trace`] — including [`TraceError::Empty`] for a
    /// zero-arrival file — but the timestamps are NOT materialized:
    /// generators stream them from disk chunk-by-chunk, and cloning the
    /// pattern across fleet members shares one [`TraceSource`] instead
    /// of duplicating the full arrival vector per member.
    pub fn from_trace_file(path: impl AsRef<Path>) -> Result<Self, TraceError> {
        Ok(ArrivalPattern::Streamed(Arc::new(TraceSource::open(path)?)))
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, ArrivalPattern::Closed)
    }

    /// Long-run mean offered rate (requests/s); 0 for `Closed`. For a
    /// trace this is the count divided by the trace span `[0, last]`.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                rate * (factor * burst_s + (period_s - burst_s)) / period_s
            }
            ArrivalPattern::Trace(ts) => match ts.last() {
                Some(&last) if last > 0.0 => ts.len() as f64 / last,
                _ => 0.0,
            },
            ArrivalPattern::Streamed(src) => {
                if src.last_s() > 0.0 {
                    src.len() as f64 / src.last_s()
                } else {
                    0.0
                }
            }
        }
    }
}

/// How many arrivals the serving engine's feed prefetches per refill
/// (see `coordinator::engine::Feed`). Chunked synthesis amortizes the
/// per-arrival call and keeps the generator's RNG state hot in cache;
/// the stream itself is identical — a generator produces the same
/// timestamp sequence whether it is drained one at a time or in chunks.
pub const ARRIVAL_CHUNK: usize = 64;

/// Generates request arrival timestamps (seconds).
#[derive(Debug)]
pub struct ArrivalGenerator {
    pattern: ArrivalPattern,
    rng: Rng,
    now_s: f64,
    /// Next unread entry of a `Trace` or `Streamed` pattern.
    trace_idx: usize,
    /// Arrival generated but not yet handed out: `arrivals_until` stashes
    /// its horizon-overshooting sample here so no arrival is ever lost
    /// (a replayed trace must emit *exactly* its timestamps).
    pending: Option<f64>,
    /// Lazily-opened reader for a `Streamed` pattern. `trace_idx` is the
    /// position source of truth: a cloned generator drops the handle and
    /// reopens at `trace_idx` on its next read.
    stream: Option<TraceStream>,
}

/// Hand-rolled because the stream handle is not clonable: the clone
/// re-opens the file lazily at the same `trace_idx`, so it produces the
/// identical remaining timestamp sequence.
impl Clone for ArrivalGenerator {
    fn clone(&self) -> Self {
        ArrivalGenerator {
            pattern: self.pattern.clone(),
            rng: self.rng.clone(),
            now_s: self.now_s,
            trace_idx: self.trace_idx,
            pending: self.pending,
            stream: None,
        }
    }
}

impl ArrivalGenerator {
    pub fn new(pattern: ArrivalPattern, seed: u64) -> Self {
        ArrivalGenerator {
            pattern,
            rng: Rng::new(seed),
            now_s: 0.0,
            trace_idx: 0,
            pending: None,
            stream: None,
        }
    }

    /// Instantaneous rate at time `t` (requests/s). A trace reports its
    /// long-run mean (its instantaneous rate is a spike train).
    pub fn rate_at(&self, t: f64) -> f64 {
        match &self.pattern {
            ArrivalPattern::Closed => 0.0,
            ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
                let phase = t % period_s;
                if phase < *burst_s {
                    rate * factor
                } else {
                    *rate
                }
            }
            ArrivalPattern::Trace(_) | ArrivalPattern::Streamed(_) => self.pattern.mean_rate(),
        }
    }

    /// Pull the next timestamp of a `Streamed` pattern, opening (or
    /// re-opening, after a clone) the reader on demand. `None` means the
    /// trace is exhausted for good.
    fn next_streamed(&mut self) -> Option<f64> {
        let ArrivalPattern::Streamed(src) = &self.pattern else {
            unreachable!("next_streamed on a non-streamed pattern")
        };
        if self.trace_idx >= src.len() {
            return None;
        }
        if self.stream.is_none() {
            self.stream = TraceStream::open_at(src, self.trace_idx);
            if self.stream.is_none() {
                // The validated file vanished mid-run; end the stream.
                self.trace_idx = src.len();
                return None;
            }
        }
        match self.stream.as_mut().and_then(TraceStream::next) {
            Some(t) => {
                self.trace_idx += 1;
                Some(t)
            }
            None => {
                self.trace_idx = src.len();
                None
            }
        }
    }

    /// Next arrival timestamp (monotone, seconds); `f64::INFINITY` for the
    /// `Closed` pattern (it never produces arrivals) and for an exhausted
    /// `Trace`.
    pub fn next_arrival(&mut self) -> f64 {
        if let Some(t) = self.pending.take() {
            return t;
        }
        if let ArrivalPattern::Trace(ts) = &self.pattern {
            return match ts.get(self.trace_idx) {
                Some(&t) => {
                    self.trace_idx += 1;
                    self.now_s = t;
                    t
                }
                None => f64::INFINITY,
            };
        }
        if let ArrivalPattern::Streamed(_) = &self.pattern {
            return match self.next_streamed() {
                Some(t) => {
                    self.now_s = t;
                    t
                }
                None => f64::INFINITY,
            };
        }
        let gap = match self.pattern {
            ArrivalPattern::Closed => return f64::INFINITY,
            ArrivalPattern::Uniform { rate } => 1.0 / rate,
            ArrivalPattern::Poisson { .. } | ArrivalPattern::Bursty { .. } => {
                // Thinning-free exponential gap at the local rate; for the
                // bursty pattern the rate is evaluated at the current time,
                // which is exact for bursts much longer than a gap.
                self.rng.exponential(self.rate_at(self.now_s).max(1e-9))
            }
            ArrivalPattern::Trace(_) | ArrivalPattern::Streamed(_) => {
                unreachable!("handled above")
            }
        };
        self.now_s += gap;
        self.now_s
    }

    /// Append up to `max` upcoming arrivals to `out`, stopping early when
    /// the stream ends (`Closed`, or an exhausted `Trace`). Returns how
    /// many were appended; 0 means the stream is exhausted for good.
    ///
    /// This is the chunked form of [`ArrivalGenerator::next_arrival`]:
    /// the timestamps produced are exactly the same sequence (traces are
    /// copied verbatim; synthetic patterns consume the RNG in the same
    /// order), just synthesized in batches so the serving engine pays one
    /// refill per [`ARRIVAL_CHUNK`] requests instead of one generator
    /// call per request.
    pub fn fill_next(&mut self, out: &mut Vec<f64>, max: usize) -> usize {
        // Trace fast path: memcpy the next slice of recorded timestamps.
        // (Skipped when a horizon-overshooting sample is pending — the
        // generic loop below consumes it first via `next_arrival`.)
        if self.pending.is_none() {
            if let ArrivalPattern::Trace(ts) = &self.pattern {
                let take = max.min(ts.len().saturating_sub(self.trace_idx));
                out.extend_from_slice(&ts[self.trace_idx..self.trace_idx + take]);
                self.trace_idx += take;
                if take > 0 {
                    self.now_s = ts[self.trace_idx - 1];
                }
                return take;
            }
        }
        let mut n = 0;
        while n < max {
            let t = self.next_arrival();
            if !t.is_finite() {
                break;
            }
            out.push(t);
            n += 1;
        }
        n
    }

    /// All arrivals in `[0, horizon_s)`. The first arrival at or past the
    /// horizon is retained (not discarded): the next call — to this
    /// method or [`ArrivalGenerator::next_arrival`] — yields it.
    pub fn arrivals_until(&mut self, horizon_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        loop {
            let t = self.next_arrival();
            if t >= horizon_s {
                if t.is_finite() {
                    self.pending = Some(t);
                }
                break;
            }
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_rate_exact() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Uniform { rate: 100.0 }, 1);
        let a = g.arrivals_until(1.0);
        assert_eq!(a.len(), 99); // arrivals at 0.01, 0.02, ..., 0.99
        assert!((a[1] - a[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn poisson_rate_within_tolerance() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 500.0 }, 2);
        let a = g.arrivals_until(20.0);
        let rate = a.len() as f64 / 20.0;
        assert!((rate - 500.0).abs() / 500.0 < 0.05, "rate {rate}");
    }

    #[test]
    fn arrivals_monotone() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 5.0, period_s: 1.0, burst_s: 0.2 },
            3,
        );
        let a = g.arrivals_until(5.0);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bursts_raise_local_rate() {
        let mut g = ArrivalGenerator::new(
            ArrivalPattern::Bursty { rate: 100.0, factor: 10.0, period_s: 1.0, burst_s: 0.2 },
            4,
        );
        let a = g.arrivals_until(10.0);
        let in_burst = a.iter().filter(|t| *t % 1.0 < 0.2).count() as f64;
        let off_burst = a.iter().filter(|t| *t % 1.0 >= 0.2).count() as f64;
        // Burst windows are 1/4 the duration of off-burst but 10x rate:
        // expect ~2.5x the requests.
        assert!(in_burst > 1.5 * off_burst, "in {in_burst} off {off_burst}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        let mut b = ArrivalGenerator::new(ArrivalPattern::Poisson { rate: 50.0 }, 9);
        assert_eq!(a.arrivals_until(2.0), b.arrivals_until(2.0));
    }

    #[test]
    fn closed_pattern_never_arrives() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::closed(), 1);
        assert!(g.arrivals_until(1e6).is_empty());
        assert_eq!(g.next_arrival(), f64::INFINITY);
        assert_eq!(g.rate_at(12.0), 0.0);
        assert!(ArrivalPattern::closed().is_closed());
        assert!(!ArrivalPattern::poisson(10.0).is_closed());
    }

    #[test]
    fn mean_rate_matches_pattern() {
        assert_eq!(ArrivalPattern::closed().mean_rate(), 0.0);
        assert_eq!(ArrivalPattern::poisson(80.0).mean_rate(), 80.0);
        // 3x bursts for 1 s out of every 4 s: mean = (3 + 3) / 4 = 1.5x.
        let b = ArrivalPattern::bursty(40.0, 3.0, 4.0, 1.0);
        assert!((b.mean_rate() - 60.0).abs() < 1e-9);
        // 4 arrivals over [0, 2] s -> 2 req/s.
        let t = ArrivalPattern::trace(vec![0.5, 1.0, 1.5, 2.0]).unwrap();
        assert!((t.mean_rate() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn trace_replays_exact_timestamps_then_goes_silent() {
        let ts = vec![0.0, 0.1, 0.1, 0.35, 2.0];
        let mut g = ArrivalGenerator::new(ArrivalPattern::trace(ts.clone()).unwrap(), 99);
        for &want in &ts {
            assert_eq!(g.next_arrival(), want);
        }
        assert_eq!(g.next_arrival(), f64::INFINITY);
        assert_eq!(g.next_arrival(), f64::INFINITY);
        // The seed is irrelevant: replay consumes no randomness.
        let mut a = ArrivalGenerator::new(ArrivalPattern::trace(ts.clone()).unwrap(), 1);
        let mut b = ArrivalGenerator::new(ArrivalPattern::trace(ts).unwrap(), 2);
        assert_eq!(a.arrivals_until(1.0), b.arrivals_until(1.0));
    }

    #[test]
    fn trace_constructor_rejects_bad_data() {
        assert_eq!(ArrivalPattern::trace(vec![]), Err(TraceError::Empty));
        assert_eq!(
            ArrivalPattern::trace(vec![0.0, -1.0]),
            Err(TraceError::Negative { index: 1, t: -1.0 })
        );
        assert_eq!(
            ArrivalPattern::trace(vec![0.0, 2.0, 1.0]),
            Err(TraceError::Unsorted { index: 2, prev: 2.0, t: 1.0 })
        );
        assert!(matches!(
            ArrivalPattern::trace(vec![0.0, f64::NAN]),
            Err(TraceError::NotFinite { index: 1 })
        ));
        assert!(matches!(
            ArrivalPattern::trace(vec![f64::INFINITY]),
            Err(TraceError::NotFinite { index: 0 })
        ));
        // Equal timestamps (simultaneous arrivals) are allowed.
        assert!(ArrivalPattern::trace(vec![1.0, 1.0]).is_ok());
    }

    #[test]
    fn fill_next_matches_one_at_a_time_synthesis() {
        // Chunked synthesis must produce the identical timestamp stream,
        // for every pattern kind, whatever the chunk size.
        let patterns = [
            ArrivalPattern::uniform(50.0),
            ArrivalPattern::poisson(120.0),
            ArrivalPattern::bursty(80.0, 4.0, 1.0, 0.25),
            ArrivalPattern::trace(vec![0.0, 0.1, 0.1, 0.4, 2.5]).unwrap(),
        ];
        for pattern in patterns {
            for chunk in [1usize, 3, 64] {
                let mut one = ArrivalGenerator::new(pattern.clone(), 77);
                let mut many = ArrivalGenerator::new(pattern.clone(), 77);
                let mut got: Vec<f64> = Vec::new();
                while got.len() < 200 {
                    if many.fill_next(&mut got, chunk) == 0 {
                        break;
                    }
                }
                for &want in &got {
                    assert_eq!(one.next_arrival(), want);
                }
                // Both generators agree on what comes next (INFINITY for
                // an exhausted trace, the same sample otherwise).
                assert_eq!(one.next_arrival(), {
                    let mut rest = Vec::new();
                    if many.fill_next(&mut rest, 1) == 0 {
                        f64::INFINITY
                    } else {
                        rest[0]
                    }
                });
            }
        }
    }

    #[test]
    fn fill_next_is_silent_for_closed_and_exhausted_streams() {
        let mut g = ArrivalGenerator::new(ArrivalPattern::closed(), 1);
        let mut out = Vec::new();
        assert_eq!(g.fill_next(&mut out, 16), 0);
        assert!(out.is_empty());
        let mut t = ArrivalGenerator::new(ArrivalPattern::trace(vec![0.5]).unwrap(), 1);
        assert_eq!(t.fill_next(&mut out, 16), 1);
        assert_eq!(t.fill_next(&mut out, 16), 0);
        assert_eq!(out, vec![0.5]);
    }

    #[test]
    fn fill_next_respects_a_pending_horizon_sample() {
        // arrivals_until stashes its overshooting sample; the next chunk
        // must begin with it (trace and synthetic alike).
        let mut g = ArrivalGenerator::new(ArrivalPattern::trace(vec![0.1, 0.9, 1.2]).unwrap(), 1);
        assert_eq!(g.arrivals_until(0.5), vec![0.1]);
        let mut out = Vec::new();
        assert_eq!(g.fill_next(&mut out, 8), 2);
        assert_eq!(out, vec![0.9, 1.2]);
    }

    #[test]
    fn trace_file_parser_skips_blanks_and_comments() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-ok-{}.txt", std::process::id()));
        std::fs::write(&path, "# a recorded trace\n\n0.0\n0.5 extra columns ignored\n\n1.25\n")
            .unwrap();
        let got = ArrivalPattern::from_trace_file(&path).unwrap();
        let ArrivalPattern::Streamed(src) = &got else {
            panic!("expected a streamed trace, got {got:?}")
        };
        assert_eq!(src.len(), 3);
        assert!(!src.is_empty());
        assert_eq!(src.last_s(), 1.25);
        // The generator replays exactly the recorded timestamps.
        let mut g = ArrivalGenerator::new(got.clone(), 7);
        assert_eq!(g.arrivals_until(10.0), vec![0.0, 0.5, 1.25]);
        assert_eq!(g.next_arrival(), f64::INFINITY);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trace_file_with_no_arrivals_is_rejected() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-empty-{}.txt", std::process::id()));
        std::fs::write(&path, "# comments only\n\n").unwrap();
        let got = ArrivalPattern::from_trace_file(&path);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(got, Err(TraceError::Empty));
    }

    #[test]
    fn streamed_trace_matches_materialized_replay() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-stream-{}.txt", std::process::id()));
        let ts: Vec<f64> = (0..500).map(|i| i as f64 * 0.01).collect();
        let body: String = ts.iter().map(|t| format!("{t}\n")).collect();
        std::fs::write(&path, body).unwrap();
        let streamed = ArrivalPattern::from_trace_file(&path).unwrap();
        let mem_pattern = ArrivalPattern::trace(ts).unwrap();
        assert!((streamed.mean_rate() - mem_pattern.mean_rate()).abs() < 1e-9);
        // One-at-a-time, chunked, and horizon draining agree with the
        // in-memory replay, and a mid-stream clone (which drops the file
        // handle and must reopen at `trace_idx`) resumes correctly.
        let mut mem = ArrivalGenerator::new(mem_pattern, 1);
        let mut disk = ArrivalGenerator::new(streamed.clone(), 2);
        assert_eq!(mem.arrivals_until(1.0), disk.arrivals_until(1.0));
        let mut cloned = disk.clone();
        let (mut a, mut b, mut rest) = (Vec::new(), Vec::new(), Vec::new());
        while disk.fill_next(&mut a, 7) > 0 {}
        while cloned.fill_next(&mut b, 64) > 0 {}
        while mem.fill_next(&mut rest, 16) > 0 {}
        assert_eq!(a, b);
        assert_eq!(a, rest);
        // Cloning the *pattern* shares the source, not a copied vector.
        let ArrivalPattern::Streamed(src) = &streamed else { unreachable!() };
        assert!(std::sync::Arc::strong_count(src) >= 2);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trace_machinery_is_thread_safe_by_construction() {
        // The cluster's data-parallel runners move arrival generators
        // (and the Arc'd trace sources they share) across worker
        // threads. TraceSource must be shareable (Sync) and the
        // generator movable (Send); keep both compile-time guarantees.
        fn assert_send_sync<T: Send + Sync>() {}
        fn assert_send<T: Send>() {}
        assert_send_sync::<TraceSource>();
        assert_send::<ArrivalGenerator>();
        assert_send::<ArrivalPattern>();
    }

    #[test]
    fn concurrent_members_replay_one_trace_source_identically() {
        // Two members on different worker threads share one
        // Streamed(Arc<TraceSource>). Each generator owns its lazy
        // BufReader (no shared seek state), so both must see the exact
        // recorded stream — this is the regression test for concurrent
        // per-member trace readers.
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-conc-{}.txt", std::process::id()));
        let ts: Vec<f64> = (0..2000).map(|i| i as f64 * 0.003).collect();
        let body: String = ts.iter().map(|t| format!("{t}\n")).collect();
        std::fs::write(&path, body).unwrap();
        let streamed = ArrivalPattern::from_trace_file(&path).unwrap();
        let drain = |pattern: ArrivalPattern, seed: u64, chunk: usize| {
            move || {
                let mut g = ArrivalGenerator::new(pattern, seed);
                let mut out = Vec::new();
                while g.fill_next(&mut out, chunk) > 0 {}
                out
            }
        };
        let (a, b) = std::thread::scope(|s| {
            // Different seeds and chunk sizes: replay must depend on
            // neither (the trace is the stream), and interleaved reads
            // from two threads must not perturb each other.
            let ha = s.spawn(drain(streamed.clone(), 3, 7));
            let hb = s.spawn(drain(streamed.clone(), 11, 64));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        assert_eq!(a, ts);
        assert_eq!(a, b);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trace_file_parser_reports_line_and_io_errors() {
        let path = std::env::temp_dir()
            .join(format!("dnnscaler-trace-bad-{}.txt", std::process::id()));
        std::fs::write(&path, "0.0\noops\n").unwrap();
        let got = ArrivalPattern::from_trace_file(&path);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(got, Err(TraceError::Parse { line: 2, token: "oops".into() }));
        assert!(matches!(
            ArrivalPattern::from_trace_file("/nonexistent/dnnscaler-trace.txt"),
            Err(TraceError::Io { .. })
        ));
    }
}
