//! Request queue for open-loop serving: arrivals wait here until the
//! batcher drains them, so queueing delay is part of observed latency.
//! A queue may be *bounded*, in which case arrivals beyond the capacity
//! are dropped and counted — the backpressure signal `ServingSession`
//! reports to policies and in `JobOutcome::drops`. Queues also support
//! SLO-aware *deadline shedding* ([`RequestQueue::shed_expired`]): a
//! request whose queueing delay alone already exceeds the SLO can never
//! meet it, so serving it only wastes GPU time — the serving engine
//! drops it at dispatch and counts it separately from capacity drops.
//!
//! ## Allocation discipline (see `docs/perf.md`)
//!
//! The queue is a hand-rolled power-of-two ring buffer, not a
//! `VecDeque`: the storage grows geometrically until it reaches the
//! queue's high-water mark and is never reallocated after that, and
//! [`RequestQueue::take_batch_into`] drains a batch into a caller-owned
//! scratch buffer instead of collecting a fresh `Vec` per batch. Steady-
//! state serving therefore performs **zero** heap allocations on the
//! queue (asserted by the engine's allocation-counter test). The ring is
//! behaviorally identical to a `VecDeque` FIFO — `tests/properties.rs`
//! checks it against exactly that model under random interleavings of
//! `push` / `take_batch` / `shed_expired`.

/// A pending inference request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival timestamp, seconds.
    pub arrival_s: f64,
}

/// Placeholder filling unused ring slots (never observable: `head`/`len`
/// bound every read).
const EMPTY_SLOT: Request = Request { id: u64::MAX, arrival_s: f64::NEG_INFINITY };

/// Smallest ring allocation (slots) once the queue holds anything.
const MIN_RING: usize = 8;

/// FIFO request queue with batch draining and optional capacity bound,
/// backed by a growable power-of-two ring buffer.
#[derive(Debug, Default)]
pub struct RequestQueue {
    /// Ring storage; `buf.len()` is 0 (nothing ever queued) or a power
    /// of two, so slot indices are computed with a mask, not a modulo.
    buf: Vec<Request>,
    /// Slot of the oldest waiting request.
    head: usize,
    /// Number of waiting requests.
    len: usize,
    next_id: u64,
    capacity: Option<usize>,
    /// High-water mark (backpressure signal).
    pub max_depth: usize,
    /// Arrivals rejected because the queue was full.
    pub dropped: u64,
    /// Accepted requests later shed because their queueing delay alone
    /// exceeded the deadline (see [`RequestQueue::shed_expired`]).
    pub dropped_deadline: u64,
    /// Accepted requests lost wholesale to a device failure (see
    /// [`RequestQueue::fail_all`]), separate from capacity and deadline
    /// drops.
    pub dropped_failure: u64,
}

impl RequestQueue {
    /// Unbounded queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue that holds at most `capacity` pending requests; arrivals
    /// beyond that are dropped (counted in [`RequestQueue::dropped`]).
    pub fn bounded(capacity: usize) -> Self {
        RequestQueue { capacity: Some(capacity), ..Self::default() }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Current ring allocation in slots (0 until the first push). Grows
    /// to the smallest power of two holding the high-water mark, then
    /// stays put — the zero-steady-state-allocation invariant.
    pub fn ring_slots(&self) -> usize {
        self.buf.len()
    }

    #[inline]
    fn slot(&self, offset: usize) -> usize {
        debug_assert!(self.buf.len().is_power_of_two());
        (self.head + offset) & (self.buf.len() - 1)
    }

    /// Double the ring (or create it), re-linearizing the live requests
    /// to the front of the new storage.
    fn grow(&mut self) {
        let old = self.buf.len();
        let new_cap = (old * 2).max(MIN_RING);
        let mut nbuf = Vec::with_capacity(new_cap);
        for k in 0..self.len {
            nbuf.push(self.buf[(self.head + k) & (old - 1)]);
        }
        nbuf.resize(new_cap, EMPTY_SLOT);
        self.buf = nbuf;
        self.head = 0;
    }

    /// Enqueue one arrival; `None` when the queue is full (the request is
    /// dropped and counted).
    pub fn push(&mut self, arrival_s: f64) -> Option<u64> {
        if let Some(cap) = self.capacity {
            if self.len >= cap {
                self.dropped += 1;
                return None;
            }
        }
        if self.len == self.buf.len() {
            self.grow();
        }
        let id = self.next_id;
        self.next_id += 1;
        let tail = self.slot(self.len);
        self.buf[tail] = Request { id, arrival_s };
        self.len += 1;
        self.max_depth = self.max_depth.max(self.len);
        Some(id)
    }

    /// Enqueue many arrivals (full ones dropped as in [`RequestQueue::push`]).
    pub fn extend(&mut self, arrivals: impl IntoIterator<Item = f64>) {
        for a in arrivals {
            let _ = self.push(a);
        }
    }

    #[inline]
    fn pop_front(&mut self) -> Option<Request> {
        if self.len == 0 {
            return None;
        }
        let r = self.buf[self.head];
        self.head = self.slot(1);
        self.len -= 1;
        Some(r)
    }

    /// Drain up to `bs` requests for one batch (FIFO order) into `out`,
    /// which is cleared first. `out` is caller-owned scratch: the serving
    /// engine passes the same buffer every round, so a steady-state batch
    /// costs no heap allocation (the old `take_batch` collected a fresh
    /// `Vec<Request>` per batch).
    pub fn take_batch_into(&mut self, bs: usize, out: &mut Vec<Request>) {
        out.clear();
        let n = bs.min(self.len);
        for _ in 0..n {
            // `n <= len` by construction, so the pop cannot fail.
            out.push(self.pop_front().expect("ring underflow"));
        }
    }

    /// Drain up to `bs` requests for one batch (FIFO order). Allocating
    /// convenience wrapper over [`RequestQueue::take_batch_into`] for
    /// tests and one-shot callers; the serving hot path uses the scratch
    /// variant.
    pub fn take_batch(&mut self, bs: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(bs.min(self.len));
        self.take_batch_into(bs, &mut out);
        out
    }

    /// SLO-aware deadline shedding: drop every waiting request whose
    /// queueing delay at `now_s` already exceeds `deadline_ms` — it can
    /// no longer meet the SLO, so serving it would only waste capacity.
    /// Arrivals enter in time order, so the expired requests form a FIFO
    /// prefix. Returns how many were shed; the total is counted in
    /// [`RequestQueue::dropped_deadline`], separate from capacity drops.
    pub fn shed_expired(&mut self, now_s: f64, deadline_ms: f64) -> u64 {
        let mut shed = 0u64;
        while self.len > 0 {
            if (now_s - self.buf[self.head].arrival_s) * 1000.0 > deadline_ms {
                self.head = self.slot(1);
                self.len -= 1;
                shed += 1;
            } else {
                break;
            }
        }
        self.dropped_deadline += shed;
        shed
    }

    /// Device failure: every waiting request is lost at once. Drains the
    /// queue and counts the losses in [`RequestQueue::dropped_failure`].
    /// Returns how many were lost. The ring storage is kept — a repaired
    /// or failed-over member keeps its zero-steady-state-allocation
    /// behavior.
    pub fn fail_all(&mut self) -> u64 {
        let lost = self.len as u64;
        self.len = 0;
        self.dropped_failure += lost;
        lost
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Oldest waiting request's arrival time, if any.
    pub fn oldest_arrival(&self) -> Option<f64> {
        if self.len == 0 {
            None
        } else {
            Some(self.buf[self.head].arrival_s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_ids() {
        let mut q = RequestQueue::new();
        q.extend([0.1, 0.2, 0.3]);
        assert_eq!(q.len(), 3);
        let b = q.take_batch(2);
        assert_eq!(b[0].id, 0);
        assert_eq!(b[1].id, 1);
        assert_eq!(b[0].arrival_s, 0.1);
        assert_eq!(q.len(), 1);
        assert_eq!(q.oldest_arrival(), Some(0.3));
    }

    #[test]
    fn take_more_than_available() {
        let mut q = RequestQueue::new();
        let _ = q.push(1.0);
        let b = q.take_batch(10);
        assert_eq!(b.len(), 1);
        assert!(q.is_empty());
        assert!(q.take_batch(4).is_empty());
    }

    #[test]
    fn high_water_mark() {
        let mut q = RequestQueue::new();
        q.extend([1.0, 2.0, 3.0, 4.0]);
        q.take_batch(4);
        let _ = q.push(5.0);
        assert_eq!(q.max_depth, 4);
    }

    #[test]
    fn bounded_queue_drops_overflow() {
        let mut q = RequestQueue::bounded(2);
        assert!(q.push(0.1).is_some());
        assert!(q.push(0.2).is_some());
        assert!(q.push(0.3).is_none()); // full -> dropped
        assert_eq!(q.dropped, 1);
        assert_eq!(q.len(), 2);
        // Draining frees capacity again; FIFO order survives the overflow.
        let b = q.take_batch(1);
        assert_eq!(b[0].arrival_s, 0.1);
        assert!(q.push(0.4).is_some());
        assert_eq!(q.dropped, 1);
        assert_eq!(q.oldest_arrival(), Some(0.2));
        assert_eq!(q.capacity(), Some(2));
    }

    #[test]
    fn shed_expired_drops_only_the_expired_prefix() {
        let mut q = RequestQueue::new();
        q.extend([0.0, 0.05, 0.20, 0.21]);
        // Deadline 100 ms at t = 0.3: the first two waited 300/250 ms
        // (expired); the last two waited 100/90 ms (0.20 is exactly at
        // the deadline and survives — shedding is strict).
        assert_eq!(q.shed_expired(0.3, 100.0), 2);
        assert_eq!(q.dropped_deadline, 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.oldest_arrival(), Some(0.20));
        // Nothing else expires at the same instant.
        assert_eq!(q.shed_expired(0.3, 100.0), 0);
        assert_eq!(q.dropped_deadline, 2);
        // Capacity drops stay a separate counter.
        assert_eq!(q.dropped, 0);
    }

    #[test]
    fn fail_all_drains_and_counts_separately() {
        let mut q = RequestQueue::bounded(3);
        q.extend([0.1, 0.2, 0.3, 0.4]); // fourth overflows
        assert_eq!(q.dropped, 1);
        assert_eq!(q.fail_all(), 3);
        assert!(q.is_empty());
        assert_eq!(q.dropped_failure, 3);
        assert_eq!(q.dropped, 1, "capacity drops stay a separate counter");
        assert_eq!(q.dropped_deadline, 0);
        // The queue keeps working (and counting) after the failure.
        assert!(q.push(0.5).is_some());
        assert_eq!(q.oldest_arrival(), Some(0.5));
        assert_eq!(q.fail_all(), 1);
        assert_eq!(q.dropped_failure, 4);
        assert_eq!(q.fail_all(), 0, "empty-queue failure is a no-op");
    }

    #[test]
    fn shed_expired_empty_queue_is_a_noop() {
        let mut q = RequestQueue::bounded(2);
        assert_eq!(q.shed_expired(1e9, 0.0), 0);
        assert_eq!(q.dropped_deadline, 0);
    }

    #[test]
    fn unbounded_queue_never_drops() {
        let mut q = RequestQueue::new();
        for i in 0..10_000 {
            assert!(q.push(i as f64).is_some());
        }
        assert_eq!(q.dropped, 0);
        assert_eq!(q.max_depth, 10_000);
        assert_eq!(q.capacity(), None);
    }

    #[test]
    fn ring_wraps_around_without_reordering() {
        // Force head to travel around the ring repeatedly: with MIN_RING
        // slots, interleaved push/drain wraps the ring many times while
        // the FIFO contract must hold exactly.
        let mut q = RequestQueue::new();
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        for round in 0..100 {
            let burst = 1 + (round % MIN_RING as u64);
            for _ in 0..burst {
                let _ = q.push(next_in as f64 * 0.001);
                next_in += 1;
            }
            for r in q.take_batch(burst as usize) {
                assert_eq!(r.id, next_out, "ids must leave in FIFO order");
                assert_eq!(r.arrival_s, next_out as f64 * 0.001);
                next_out += 1;
            }
        }
        assert!(q.is_empty());
        assert_eq!(next_in, next_out);
        // Depth never exceeded one burst, so the ring never had to grow
        // past the minimum allocation.
        assert_eq!(q.ring_slots(), MIN_RING);
    }

    #[test]
    fn ring_grows_across_a_wrapped_boundary() {
        // Queue contents straddling the wrap point when growth hits must
        // be re-linearized, not scrambled.
        let mut q = RequestQueue::new();
        for i in 0..MIN_RING {
            let _ = q.push(i as f64);
        }
        // Advance head past the ring midpoint, then refill past the old
        // allocation so grow() copies a wrapped range.
        let _ = q.take_batch(5);
        for i in MIN_RING..(3 * MIN_RING) {
            let _ = q.push(i as f64);
        }
        assert!(q.ring_slots() > MIN_RING);
        let all = q.take_batch(usize::MAX >> 1);
        let want: Vec<f64> = (5..3 * MIN_RING).map(|i| i as f64).collect();
        let got: Vec<f64> = all.iter().map(|r| r.arrival_s).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn take_batch_into_reuses_the_scratch_buffer() {
        let mut q = RequestQueue::new();
        let mut scratch = Vec::new();
        q.extend([0.1, 0.2, 0.3, 0.4]);
        q.take_batch_into(3, &mut scratch);
        assert_eq!(scratch.len(), 3);
        let cap = scratch.capacity();
        // A second, smaller batch must clear and refill the same storage.
        q.take_batch_into(3, &mut scratch);
        assert_eq!(scratch.len(), 1);
        assert_eq!(scratch[0].arrival_s, 0.4);
        assert_eq!(scratch.capacity(), cap, "scratch must not be reallocated");
        // Draining an empty queue leaves the scratch empty but intact.
        q.take_batch_into(8, &mut scratch);
        assert!(scratch.is_empty());
        assert_eq!(scratch.capacity(), cap);
    }

    #[test]
    fn steady_state_ring_never_reallocates() {
        let mut q = RequestQueue::bounded(64);
        let mut scratch = Vec::with_capacity(16);
        // Warm up to the high-water mark.
        for i in 0..64 {
            let _ = q.push(i as f64);
        }
        let slots = q.ring_slots();
        assert_eq!(slots, 64);
        // Sustained churn at that depth must never touch the allocation.
        for i in 0..1000 {
            q.take_batch_into(16, &mut scratch);
            for k in 0..16 {
                let _ = q.push((64 + i * 16 + k) as f64);
            }
            assert_eq!(q.ring_slots(), slots);
        }
    }
}
