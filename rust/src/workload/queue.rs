//! Request queue for open-loop serving: arrivals wait here until the
//! batcher drains them, so queueing delay is part of observed latency.
//! A queue may be *bounded*, in which case arrivals beyond the capacity
//! are dropped and counted — the backpressure signal `ServingSession`
//! reports to policies and in `JobOutcome::drops`. Queues also support
//! SLO-aware *deadline shedding* ([`RequestQueue::shed_expired`]): a
//! request whose queueing delay alone already exceeds the SLO can never
//! meet it, so serving it only wastes GPU time — the serving engine
//! drops it at dispatch and counts it separately from capacity drops.

use std::collections::VecDeque;

/// A pending inference request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival timestamp, seconds.
    pub arrival_s: f64,
}

/// FIFO request queue with batch draining and optional capacity bound.
#[derive(Debug, Default)]
pub struct RequestQueue {
    q: VecDeque<Request>,
    next_id: u64,
    capacity: Option<usize>,
    /// High-water mark (backpressure signal).
    pub max_depth: usize,
    /// Arrivals rejected because the queue was full.
    pub dropped: u64,
    /// Accepted requests later shed because their queueing delay alone
    /// exceeded the deadline (see [`RequestQueue::shed_expired`]).
    pub dropped_deadline: u64,
}

impl RequestQueue {
    /// Unbounded queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue that holds at most `capacity` pending requests; arrivals
    /// beyond that are dropped (counted in [`RequestQueue::dropped`]).
    pub fn bounded(capacity: usize) -> Self {
        RequestQueue { capacity: Some(capacity), ..Self::default() }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Enqueue one arrival; `None` when the queue is full (the request is
    /// dropped and counted).
    pub fn push(&mut self, arrival_s: f64) -> Option<u64> {
        if let Some(cap) = self.capacity {
            if self.q.len() >= cap {
                self.dropped += 1;
                return None;
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.q.push_back(Request { id, arrival_s });
        self.max_depth = self.max_depth.max(self.q.len());
        Some(id)
    }

    /// Enqueue many arrivals (full ones dropped as in [`RequestQueue::push`]).
    pub fn extend(&mut self, arrivals: impl IntoIterator<Item = f64>) {
        for a in arrivals {
            let _ = self.push(a);
        }
    }

    /// Drain up to `bs` requests for one batch (FIFO order).
    pub fn take_batch(&mut self, bs: usize) -> Vec<Request> {
        let n = bs.min(self.q.len());
        self.q.drain(..n).collect()
    }

    /// SLO-aware deadline shedding: drop every waiting request whose
    /// queueing delay at `now_s` already exceeds `deadline_ms` — it can
    /// no longer meet the SLO, so serving it would only waste capacity.
    /// Arrivals enter in time order, so the expired requests form a FIFO
    /// prefix. Returns how many were shed; the total is counted in
    /// [`RequestQueue::dropped_deadline`], separate from capacity drops.
    pub fn shed_expired(&mut self, now_s: f64, deadline_ms: f64) -> u64 {
        let mut shed = 0u64;
        while let Some(front) = self.q.front() {
            if (now_s - front.arrival_s) * 1000.0 > deadline_ms {
                self.q.pop_front();
                shed += 1;
            } else {
                break;
            }
        }
        self.dropped_deadline += shed;
        shed
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Oldest waiting request's arrival time, if any.
    pub fn oldest_arrival(&self) -> Option<f64> {
        self.q.front().map(|r| r.arrival_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_ids() {
        let mut q = RequestQueue::new();
        q.extend([0.1, 0.2, 0.3]);
        assert_eq!(q.len(), 3);
        let b = q.take_batch(2);
        assert_eq!(b[0].id, 0);
        assert_eq!(b[1].id, 1);
        assert_eq!(b[0].arrival_s, 0.1);
        assert_eq!(q.len(), 1);
        assert_eq!(q.oldest_arrival(), Some(0.3));
    }

    #[test]
    fn take_more_than_available() {
        let mut q = RequestQueue::new();
        let _ = q.push(1.0);
        let b = q.take_batch(10);
        assert_eq!(b.len(), 1);
        assert!(q.is_empty());
        assert!(q.take_batch(4).is_empty());
    }

    #[test]
    fn high_water_mark() {
        let mut q = RequestQueue::new();
        q.extend([1.0, 2.0, 3.0, 4.0]);
        q.take_batch(4);
        let _ = q.push(5.0);
        assert_eq!(q.max_depth, 4);
    }

    #[test]
    fn bounded_queue_drops_overflow() {
        let mut q = RequestQueue::bounded(2);
        assert!(q.push(0.1).is_some());
        assert!(q.push(0.2).is_some());
        assert!(q.push(0.3).is_none()); // full -> dropped
        assert_eq!(q.dropped, 1);
        assert_eq!(q.len(), 2);
        // Draining frees capacity again; FIFO order survives the overflow.
        let b = q.take_batch(1);
        assert_eq!(b[0].arrival_s, 0.1);
        assert!(q.push(0.4).is_some());
        assert_eq!(q.dropped, 1);
        assert_eq!(q.oldest_arrival(), Some(0.2));
        assert_eq!(q.capacity(), Some(2));
    }

    #[test]
    fn shed_expired_drops_only_the_expired_prefix() {
        let mut q = RequestQueue::new();
        q.extend([0.0, 0.05, 0.20, 0.21]);
        // Deadline 100 ms at t = 0.3: the first two waited 300/250 ms
        // (expired); the last two waited 100/90 ms (0.20 is exactly at
        // the deadline and survives — shedding is strict).
        assert_eq!(q.shed_expired(0.3, 100.0), 2);
        assert_eq!(q.dropped_deadline, 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.oldest_arrival(), Some(0.20));
        // Nothing else expires at the same instant.
        assert_eq!(q.shed_expired(0.3, 100.0), 0);
        assert_eq!(q.dropped_deadline, 2);
        // Capacity drops stay a separate counter.
        assert_eq!(q.dropped, 0);
    }

    #[test]
    fn shed_expired_empty_queue_is_a_noop() {
        let mut q = RequestQueue::bounded(2);
        assert_eq!(q.shed_expired(1e9, 0.0), 0);
        assert_eq!(q.dropped_deadline, 0);
    }

    #[test]
    fn unbounded_queue_never_drops() {
        let mut q = RequestQueue::new();
        for i in 0..10_000 {
            assert!(q.push(i as f64).is_some());
        }
        assert_eq!(q.dropped, 0);
        assert_eq!(q.max_depth, 10_000);
        assert_eq!(q.capacity(), None);
    }
}
