//! Workload generation: request arrival processes and the request queue.
//!
//! The paper serves closed-loop streams from real datasets; §3.3 also
//! claims DNNScaler "can quickly respond to bursty workloads" (citing
//! AWS-style bursty inference arrivals). This module is the arrival side
//! of the open-loop serving core: [`ArrivalPattern`] describes the offered
//! load (`Closed`, `Uniform`, `Poisson`, `Bursty`), [`ArrivalGenerator`]
//! turns a pattern into a deterministic timestamp stream, and
//! [`RequestQueue`] holds pending requests between arrival and batch
//! formation so queueing delay becomes part of every observed latency.
//! `coordinator::session::ServingSession` drives all three; bounded
//! queues additionally count drops for the backpressure signal policies
//! receive in their `WindowObservation`.

pub mod generator;
pub mod queue;

pub use generator::{ArrivalGenerator, ArrivalPattern};
pub use queue::{Request, RequestQueue};
