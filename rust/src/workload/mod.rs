//! Workload generation: request arrival processes and the request queue.
//!
//! The paper serves closed-loop streams from real datasets; §3.3 also
//! claims DNNScaler "can quickly respond to bursty workloads" (citing
//! AWS-style bursty inference arrivals). This module is the arrival side
//! of the open-loop serving core: [`ArrivalPattern`] describes the offered
//! load (`Closed`, `Uniform`, `Poisson`, `Bursty`, or a recorded `Trace`
//! replayed from a log file), [`ArrivalGenerator`] turns a pattern into a
//! deterministic timestamp stream, and [`RequestQueue`] holds pending
//! requests between arrival and batch formation so queueing delay becomes
//! part of every observed latency. `coordinator::engine` drives all three
//! for `ServingSession` and `Fleet` alike; bounded queues count overflow
//! drops, and [`RequestQueue::shed_expired`] implements SLO-aware deadline
//! shedding (both are backpressure signals policies receive in their
//! `WindowObservation`).

pub mod generator;
pub mod queue;

pub use generator::{
    validate_trace, ArrivalGenerator, ArrivalPattern, TraceError, TraceSource, ARRIVAL_CHUNK,
};
pub use queue::{Request, RequestQueue};
