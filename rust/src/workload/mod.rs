//! Workload generation: request arrival processes and dataset models.
//!
//! The paper serves closed-loop streams from real datasets; §3.3 also
//! claims DNNScaler "can quickly respond to bursty workloads" (citing
//! AWS-style bursty inference arrivals). This module provides open-loop
//! Poisson and burst arrival generators plus a queue so examples and
//! benches can exercise that claim, and dataset descriptors whose prep
//! costs feed the simulator.

pub mod generator;
pub mod queue;

pub use generator::{ArrivalGenerator, ArrivalPattern};
pub use queue::RequestQueue;
