//! Minimal JSON substrate (no external crates): a recursive-descent
//! parser and a small writer, sufficient for `artifacts/manifest.json`
//! and the bench report files.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), at: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize a value (compact).
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"bs":4,"f":1.5,"name":"m"}],"v":1}"#;
        let v = parse(src).unwrap();
        let out = write(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn numeric_accessors() {
        let v = parse("[4, 4.5, -1]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_u64(), Some(4));
        assert_eq!(a[0].as_usize(), Some(4));
        assert_eq!(a[1].as_u64(), None);
        assert_eq!(a[2].as_u64(), None);
        assert_eq!(a[1].as_f64(), Some(4.5));
    }

    #[test]
    fn real_manifest_parses() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.get("entries").unwrap().as_arr().unwrap().len() >= 4);
        }
    }
}
