//! Real device: PJRT CPU execution of the AOT artifacts.
//!
//! Used by the end-to-end examples: the controllers drive it exactly like
//! the simulator, but every latency sample comes from an actual XLA
//! execution of the JAX/Pallas-lowered HLO.

use anyhow::Result;

use crate::device::{Device, DeviceError, ExecSample};
use crate::manifest::Manifest;
use crate::runtime::pool::ExecutorPool;

/// A [`Device`] backed by the PJRT runtime.
pub struct RealDevice {
    pool: ExecutorPool,
    model: String,
}

impl RealDevice {
    /// Load the manifest from `artifacts_dir` and build a device serving
    /// `model`.
    pub fn open(artifacts_dir: impl AsRef<std::path::Path>, model: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.validate()?;
        let pool = ExecutorPool::new(manifest, model)?;
        Ok(RealDevice { pool, model: model.to_string() })
    }

    /// Largest batch size with an exported artifact.
    pub fn max_batch_size(&self) -> u32 {
        self.pool.max_batch_size() as u32
    }

    /// Access the underlying pool (compile report etc.).
    pub fn pool(&self) -> &ExecutorPool {
        &self.pool
    }
}

impl Device for RealDevice {
    fn model(&self) -> &str {
        &self.model
    }

    fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError> {
        if bs == 0 || mtl == 0 {
            return Err(DeviceError::InvalidOperatingPoint { bs, mtl });
        }
        if bs as usize > self.pool.max_batch_size() {
            return Err(DeviceError::InvalidOperatingPoint { bs, mtl });
        }
        self.pool.set_instances(mtl as usize);
        let lats = self
            .pool
            .execute_round(bs as usize)
            .map_err(|e| DeviceError::Exec(e.to_string()))?;
        // The controller observes the tail instance of the round — the
        // same worst-co-tenant view the paper's p95 monitor sees.
        let latency_ms = lats.iter().cloned().fold(0.0f64, f64::max);
        Ok(ExecSample { latency_ms, batch_size: bs, mtl, power_w: 0.0, sm_util: 0.0 })
    }

    fn launch_overhead_ms(&self) -> f64 {
        // Compiling/loading an extra executable is the real-mode launch
        // cost; it is cached after first use.
        50.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_device_serves_if_artifacts_exist() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let mut dev = RealDevice::open(&dir, "mobv1-025").unwrap();
        let s1 = dev.execute_batch(1, 1).unwrap();
        assert!(s1.latency_ms > 0.0);
        // (4, 2) compiles the bs=4 artifact and runs two instances; the
        // first call carries warmup, so only sanity-check positivity.
        let s2 = dev.execute_batch(4, 2).unwrap();
        assert!(s2.latency_ms > 0.0);
        assert!(dev.execute_batch(0, 1).is_err());
        assert!(dev.execute_batch(10_000, 1).is_err());
    }
}
