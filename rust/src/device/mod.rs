//! Device abstraction: everything the coordinator can serve against.
//!
//! The Profiler/Scaler/Clipper controllers observe a device *only* through
//! executed-batch latency samples, exactly as the paper's system observes
//! its GPU. Two implementations exist:
//!
//! * [`crate::gpusim::GpuSim`] — the calibrated Tesla-P40 model used for
//!   every paper figure/table;
//! * [`real::RealDevice`] — the PJRT CPU runtime executing the AOT JAX/
//!   Pallas artifacts, used by the end-to-end examples to prove the whole
//!   stack composes.

#[cfg(feature = "xla")]
pub mod real;

use std::fmt;

/// One executed batch: the only observable the controllers get.
#[derive(Debug, Clone, Copy)]
pub struct ExecSample {
    /// End-to-end per-batch latency in ms (every request in the batch
    /// observes this latency).
    pub latency_ms: f64,
    pub batch_size: u32,
    pub mtl: u32,
    /// Board power during the batch (W); 0 when unknown (real mode).
    pub power_w: f64,
    /// SM utilization 0..1; 0 when unknown (real mode).
    pub sm_util: f64,
}

/// Errors a device can raise for an operating point.
#[derive(Debug, Clone)]
pub enum DeviceError {
    InvalidOperatingPoint { bs: u32, mtl: u32 },
    OutOfMemory { demand_mb: f64, capacity_mb: f64 },
    /// A spatial SM grant outside `(0, 1]` was requested.
    InvalidGrant { grant: f64 },
    Exec(String),
}

impl fmt::Display for DeviceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeviceError::InvalidOperatingPoint { bs, mtl } => {
                write!(f, "invalid operating point bs={bs} mtl={mtl}")
            }
            DeviceError::OutOfMemory { demand_mb, capacity_mb } => {
                write!(f, "out of GPU memory: need {demand_mb:.0} MB, have {capacity_mb:.0} MB")
            }
            DeviceError::InvalidGrant { grant } => {
                write!(f, "SM grant must be in (0, 1], got {grant}")
            }
            DeviceError::Exec(e) => write!(f, "execution failed: {e}"),
        }
    }
}

impl std::error::Error for DeviceError {}

/// A serving device: executes batches at an operating point `(bs, mtl)`.
pub trait Device {
    /// The DNN this device instance serves.
    fn model(&self) -> &str;

    /// Execute one batch of `bs` inputs while `mtl` instances are
    /// co-located, returning the observed sample.
    fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError>;

    /// Execute one batch inside a spatial SM partition of fraction
    /// `grant` (MPS share / MIG slice bundle). Devices that cannot model
    /// partitioning (the real PJRT runtime) fall back to whole-device
    /// execution; `GpuSim` overrides this with the granted perf model.
    fn execute_batch_granted(
        &mut self,
        bs: u32,
        mtl: u32,
        grant: f64,
    ) -> Result<ExecSample, DeviceError> {
        if !grant.is_finite() || grant <= 0.0 || grant > 1.0 {
            return Err(DeviceError::InvalidGrant { grant });
        }
        self.execute_batch(bs, mtl)
    }

    /// Cost (ms of wall time) of launching one more co-located instance —
    /// the overhead the paper's matrix-completion seeding avoids paying
    /// repeatedly.
    fn launch_overhead_ms(&self) -> f64 {
        0.0
    }
}

/// Forwarding impl so `&mut GpuSim` / `&mut dyn Device` can be handed to
/// `ServingSession::builder().device(..)` without giving up ownership.
impl<D: Device + ?Sized> Device for &mut D {
    fn model(&self) -> &str {
        (**self).model()
    }
    fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError> {
        (**self).execute_batch(bs, mtl)
    }
    fn execute_batch_granted(
        &mut self,
        bs: u32,
        mtl: u32,
        grant: f64,
    ) -> Result<ExecSample, DeviceError> {
        (**self).execute_batch_granted(bs, mtl, grant)
    }
    fn launch_overhead_ms(&self) -> f64 {
        (**self).launch_overhead_ms()
    }
}

/// Blanket impl so `Box<dyn Device>` composes.
impl Device for Box<dyn Device + Send> {
    fn model(&self) -> &str {
        (**self).model()
    }
    fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError> {
        (**self).execute_batch(bs, mtl)
    }
    fn execute_batch_granted(
        &mut self,
        bs: u32,
        mtl: u32,
        grant: f64,
    ) -> Result<ExecSample, DeviceError> {
        (**self).execute_batch_granted(bs, mtl, grant)
    }
    fn launch_overhead_ms(&self) -> f64 {
        (**self).launch_overhead_ms()
    }
}
