//! Dynamic batch-size Scaler: the pseudo binary search of Algorithm 1
//! (lines 10-29).
//!
//! Semantics, straight from the paper:
//!
//! * `alpha*SLO <= p95 <= SLO` — hold the current batch size;
//! * `p95 < alpha*SLO` — headroom: `minBS = currentBS`, jump to
//!   `ceil((minBS + maxBS) / 2)`;
//! * `p95 > SLO` — violation: if already at `BS = 1` the SLO cannot be
//!   met; if the search had converged (`currentBS == minBS`) restart it
//!   downward (`maxBS = currentBS, minBS = 1`); otherwise
//!   `maxBS = currentBS`, drop to `floor((minBS + maxBS) / 2)`.
//!
//! One extension the figures require (Fig. 9(b), rising SLO): when the
//! search has converged at its ceiling and latency still has headroom,
//! `maxBS` re-opens to the global maximum so the controller can chase a
//! relaxed SLO upward — the paper's "readjustment" behaviour.

use super::controller::{Controller, Decision};
use super::policy::{Action, Policy, WindowObservation};
use super::{ALPHA, MAX_BS};

/// Pseudo-binary-search batch-size controller.
#[derive(Debug, Clone)]
pub struct BatchScaler {
    min_bs: u32,
    max_bs: u32,
    current: u32,
    /// Global ceiling (GPU-memory bound; 128 in the paper).
    hard_max: u32,
    /// True once the search cannot move (reported by `converged`).
    settled: bool,
    /// Consecutive violating windows seen (spike debounce, §4.4: "short-
    /// live spikes ... are skipped to avoid excessive changes").
    violations: u32,
}

impl BatchScaler {
    /// Start at `BS = 1` with the paper's ceiling.
    pub fn new() -> Self {
        Self::with_limits(1, MAX_BS)
    }

    /// Custom initial point and ceiling (used by tests and real mode,
    /// where the ceiling is the largest exported artifact).
    pub fn with_limits(initial: u32, hard_max: u32) -> Self {
        assert!(initial >= 1 && hard_max >= initial);
        BatchScaler {
            min_bs: 1,
            max_bs: hard_max,
            current: initial,
            hard_max,
            settled: false,
            violations: 0,
        }
    }

    pub fn batch_size(&self) -> u32 {
        self.current
    }

    /// Whether the last observation left the knob unchanged.
    pub fn converged(&self) -> bool {
        self.settled
    }
}

impl Default for BatchScaler {
    fn default() -> Self {
        Self::new()
    }
}

impl Controller for BatchScaler {
    fn name(&self) -> &'static str {
        "dnnscaler-batching"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.current, 1)
    }

    fn observe_window(&mut self, p95_ms: f64, slo_ms: f64) -> Decision {
        let lo = ALPHA * slo_ms;
        let prev = self.current;

        if p95_ms > slo_ms {
            // SLO violation. Once settled, debounce one-off spikes (OS
            // jitter, §4.4: "short-live spikes ... are skipped to avoid
            // excessive changes"); during an active search react at once.
            if self.settled {
                self.violations += 1;
                if self.violations < 2 {
                    return Decision { bs: self.current, mtl: 1, changed: false };
                }
            }
            self.violations = 0;
            if self.current == 1 {
                // Line 21: further reduction impossible; SLO unmeetable.
                self.min_bs = 1;
            } else if self.current == self.min_bs {
                // Line 22-25: converged point now violates — restart the
                // search below it.
                self.max_bs = self.current;
                self.min_bs = 1;
                self.current = (self.min_bs + self.max_bs) / 2; // floor
            } else {
                // Line 26-28.
                self.max_bs = self.current;
                self.current = (self.min_bs + self.max_bs) / 2; // floor
            }
            self.current = self.current.max(1);
        } else if p95_ms < lo {
            self.violations = 0;
            // Headroom: search upward (lines 15-18).
            if self.current == self.max_bs {
                if self.max_bs < self.hard_max {
                    // Re-open the ceiling (SLO relaxed at runtime).
                    self.max_bs = self.hard_max;
                    self.min_bs = self.current;
                    self.current = (self.min_bs + self.max_bs).div_ceil(2);
                }
                // else: at the hard ceiling — no further improvement.
            } else {
                self.min_bs = self.current;
                self.current = (self.min_bs + self.max_bs).div_ceil(2);
            }
        }
        else {
            // In the alpha band — hold (line 13-14).
            self.violations = 0;
        }

        self.settled = self.current == prev;
        Decision { bs: self.current, mtl: 1, changed: self.current != prev }
    }
}

/// `Policy` view of the batch scaler: it acts on the observation's
/// p95/SLO only (the paper's Algorithm 1 uses nothing else).
impl Policy for BatchScaler {
    fn name(&self) -> &'static str {
        Controller::name(self)
    }

    fn operating_point(&self) -> (u32, u32) {
        Controller::operating_point(self)
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        Action::from_decision(self.observe_window(obs.p95_ms, obs.slo_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the scaler against a synthetic latency curve `lat(bs)` until
    /// it stops moving (two consecutive holds — the spike debounce makes
    /// a single unchanged window inconclusive); returns (final bs, steps).
    fn drive(lat: impl Fn(u32) -> f64, slo: f64, max_steps: usize) -> (u32, usize) {
        let mut s = BatchScaler::new();
        let mut steps = 0;
        let mut holds = 0;
        for _ in 0..max_steps {
            let bs = s.batch_size();
            let d = s.observe_window(lat(bs), slo);
            steps += 1;
            holds = if d.changed { 0 } else { holds + 1 };
            if holds >= 2 && steps > 2 {
                break;
            }
        }
        (s.batch_size(), steps)
    }

    #[test]
    fn finds_largest_bs_under_slo() {
        // lat(bs) = 2*bs ms, SLO 100 -> feasible set bs <= 50, alpha band
        // [85, 100] -> bs in [43, 50].
        let (bs, steps) = drive(|b| 2.0 * b as f64, 100.0, 50);
        assert!((43..=50).contains(&bs), "bs {bs}");
        assert!(steps <= 12, "binary search must converge quickly, took {steps}");
    }

    #[test]
    fn converges_in_logarithmic_steps() {
        let (_, steps) = drive(|b| 0.9 * b as f64, 60.0, 50);
        assert!(steps <= 10, "took {steps} steps (log2(128) = 7 + settle)");
    }

    #[test]
    fn stays_at_one_when_slo_unmeetable() {
        let (bs, _) = drive(|_| 500.0, 10.0, 30);
        assert_eq!(bs, 1);
    }

    #[test]
    fn grows_to_ceiling_with_loose_slo() {
        let (bs, _) = drive(|b| 0.01 * b as f64, 1e9, 30);
        assert_eq!(bs, MAX_BS);
    }

    #[test]
    fn holds_inside_alpha_band() {
        let mut s = BatchScaler::with_limits(40, 128);
        let d = s.observe_window(90.0, 100.0); // 85 <= 90 <= 100
        assert!(!d.changed);
        assert_eq!(s.batch_size(), 40);
    }

    #[test]
    fn slo_drop_triggers_downward_restart() {
        // Converge under SLO=100 first.
        let lat = |b: u32| 2.0 * b as f64;
        let mut s = BatchScaler::new();
        for _ in 0..20 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), 100.0);
        }
        let settled = s.batch_size();
        assert!(settled >= 43);
        // SLO halves (Fig. 9(a)): controller must descend.
        for _ in 0..20 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), 50.0);
        }
        let after = s.batch_size();
        assert!(after <= 25, "bs {after} must respect the tightened SLO");
        assert!(lat(after) <= 50.0);
    }

    #[test]
    fn slo_rise_reopens_ceiling() {
        let lat = |b: u32| 2.0 * b as f64;
        let mut s = BatchScaler::new();
        for _ in 0..20 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), 60.0);
        }
        let low = s.batch_size();
        assert!(low <= 30);
        // SLO doubles (Fig. 9(b)): controller must climb again.
        for _ in 0..20 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), 180.0);
        }
        assert!(s.batch_size() > low, "bs must grow after SLO relaxes");
        assert!(lat(s.batch_size()) <= 180.0);
    }

    #[test]
    fn never_leaves_valid_range() {
        let mut s = BatchScaler::new();
        // Adversarial alternating observations.
        for i in 0..200 {
            let p95 = if i % 2 == 0 { 1.0 } else { 1e6 };
            let d = s.observe_window(p95, 100.0);
            assert!((1..=MAX_BS).contains(&d.bs));
            assert_eq!(d.mtl, 1);
        }
    }
}
