//! `Fleet`: multiple jobs served concurrently on one shared-capacity GPU.
//!
//! The paper (and the legacy `JobRunner`) serve one job per device; real
//! clusters co-locate *different* models on one accelerator ("No DNN Left
//! Behind"-style multi-tenancy). `Fleet` expresses that scenario on the
//! simulated Tesla P40:
//!
//! * **Shared memory** — before every control window the members'
//!   requested operating points pass an admission check against the
//!   GPU's memory capacity; the greediest member is shrunk (batch halved,
//!   then instances shed) until the combined demand fits, so the fleet
//!   never OOMs.
//! * **Shared SMs** — the members' combined SM utilization sets a
//!   contention factor; when it exceeds 1 the GPU time-shares and every
//!   member's batch latency is inflated proportionally. Policies observe
//!   those inflated latencies and back off, which is exactly the
//!   cross-job feedback loop single-job serving cannot express.
//!
//! Members run their control windows in lockstep (window `w` of every
//! member sees the same contention snapshot), each with its own
//! [`Policy`] resolved from a [`PolicySpec`] — DNNScaler members profile
//! themselves alone at fleet start, as the paper's profiler would.

use crate::device::{Device, DeviceError};
use crate::gpusim::{GpuSim, GpuSpec, TESLA_P40};

use super::job::JobSpec;
use super::latency::LatencyWindow;
use super::policy::{Action, Policy};
use super::profiler::ProfileOutcome;
use super::session::{
    assemble_outcome, resolve_policy, serve_closed_window, AttainAcc, ConfigError, JobOutcome,
    PolicySpec, RunConfig, SloSchedule, WindowRecord,
};

/// Result of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Per-member outcomes, in the order jobs were added.
    pub members: Vec<JobOutcome>,
    /// Sum of member steady-state throughputs (inferences/s).
    pub total_throughput: f64,
    /// Peak combined GPU memory demand over the run (MB).
    pub peak_mem_mb: f64,
    /// The shared GPU's memory capacity (MB).
    pub mem_capacity_mb: f64,
    /// Peak combined SM utilization (values > 1 mean time-sharing).
    pub peak_contention: f64,
    /// Times the admission check shrank a member's requested point.
    pub admission_clamps: u64,
}

/// Builder for [`Fleet`].
pub struct FleetBuilder<'a> {
    gpu: GpuSpec,
    cfg: RunConfig,
    seed: u64,
    members: Vec<(JobSpec, PolicySpec<'a>)>,
}

impl<'a> FleetBuilder<'a> {
    fn new() -> Self {
        FleetBuilder { gpu: TESLA_P40, cfg: RunConfig::default(), seed: 42, members: Vec::new() }
    }

    /// The shared accelerator (default: the paper's Tesla P40).
    pub fn gpu(mut self, gpu: GpuSpec) -> Self {
        self.gpu = gpu;
        self
    }

    /// Replace the shared serving config.
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn windows(mut self, windows: usize) -> Self {
        self.cfg.windows = windows;
        self
    }

    pub fn rounds_per_window(mut self, rounds: usize) -> Self {
        self.cfg.rounds_per_window = rounds;
        self
    }

    /// Seed for member simulators (member `i` gets `seed + i`).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Add a member job with its serving policy.
    pub fn job(mut self, job: &JobSpec, policy: PolicySpec<'a>) -> Self {
        self.members.push((*job, policy));
        self
    }

    /// Validate and assemble the fleet.
    pub fn build(self) -> Result<Fleet<'a>, ConfigError> {
        if self.cfg.windows == 0 {
            return Err(ConfigError::ZeroWindows);
        }
        if self.cfg.rounds_per_window == 0 {
            return Err(ConfigError::ZeroRounds);
        }
        if self.cfg.max_bs == 0 || self.cfg.max_mtl == 0 {
            return Err(ConfigError::ZeroKnobCeiling {
                max_bs: self.cfg.max_bs,
                max_mtl: self.cfg.max_mtl,
            });
        }
        if self.members.is_empty() {
            return Err(ConfigError::NoFleetMembers);
        }
        for (job, _) in &self.members {
            if crate::gpusim::paper_profile(job.dnn).is_none() {
                return Err(ConfigError::UnknownDnn { dnn: job.dnn.to_string() });
            }
        }
        Ok(Fleet { gpu: self.gpu, cfg: self.cfg, seed: self.seed, members: self.members })
    }
}

/// A validated multi-job fleet, ready to run.
pub struct Fleet<'a> {
    gpu: GpuSpec,
    cfg: RunConfig,
    seed: u64,
    members: Vec<(JobSpec, PolicySpec<'a>)>,
}

struct Member<'a> {
    job: JobSpec,
    sim: GpuSim,
    policy: Box<dyn Policy + 'a>,
    profile: Option<ProfileOutcome>,
    label: Option<&'static str>,
    schedule: SloSchedule,
    window: LatencyWindow,
    trace: Vec<WindowRecord>,
    latencies: Vec<(f64, f64)>,
    acc: AttainAcc,
    pending_launch_ms: f64,
    /// Last operating point the admission check actually let this member
    /// serve at (what `JobOutcome::steady_*` reports — the policy's own
    /// request may be larger than the shared GPU ever granted).
    admitted: (u32, u32),
}

impl<'a> Fleet<'a> {
    pub fn builder() -> FleetBuilder<'a> {
        FleetBuilder::new()
    }

    /// Serve every member to completion on the shared GPU.
    pub fn run(self) -> Result<FleetOutcome, DeviceError> {
        let Fleet { gpu, cfg, seed, members } = self;
        let mut states: Vec<Member<'a>> = Vec::with_capacity(members.len());
        for (i, (job, spec)) in members.into_iter().enumerate() {
            let mut sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed + i as u64)
                .ok_or_else(|| DeviceError::Exec(format!("unknown DNN {:?}", job.dnn)))?;
            // DNNScaler members profile themselves alone at fleet start.
            let (policy, profile, label) = resolve_policy(spec, &cfg, &job, &mut sim)?;
            let admitted = policy.operating_point();
            states.push(Member {
                schedule: SloSchedule::new(job.slo_ms, cfg.slo_schedule.clone()),
                window: LatencyWindow::new(cfg.rounds_per_window),
                trace: Vec::with_capacity(cfg.windows),
                latencies: Vec::new(),
                acc: AttainAcc::new(cfg.windows / 2),
                pending_launch_ms: 0.0,
                admitted,
                job,
                sim,
                policy,
                profile,
                label,
            });
        }

        let mut peak_mem_mb: f64 = 0.0;
        let mut peak_contention: f64 = 0.0;
        let mut admission_clamps = 0u64;

        for w in 0..cfg.windows {
            // Requested operating points, then shared-memory admission:
            // shrink the largest *shrinkable* consumer (batch halved
            // first, then instances shed) until the fleet fits. Members
            // already at (1, 1) are passed over — OOM is only an error
            // when nobody can give anything back.
            let requested: Vec<(u32, u32)> =
                states.iter().map(|m| m.policy.operating_point()).collect();
            let mut points = requested.clone();
            loop {
                let demands: Vec<f64> = states
                    .iter()
                    .zip(&points)
                    .map(|(m, &(bs, mtl))| m.sim.mem_demand_mb(bs, mtl))
                    .collect();
                let total: f64 = demands.iter().sum();
                if total <= gpu.mem_mb {
                    peak_mem_mb = peak_mem_mb.max(total);
                    break;
                }
                let Some((k, _)) = demands
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| points[i] != (1, 1))
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                else {
                    return Err(DeviceError::OutOfMemory {
                        demand_mb: total,
                        capacity_mb: gpu.mem_mb,
                    });
                };
                let p = &mut points[k];
                if p.0 > 1 {
                    p.0 = (p.0 / 2).max(1);
                } else {
                    p.1 -= 1;
                }
                admission_clamps += 1;
            }

            // Combined SM pressure sets this window's time-sharing factor.
            let contention: f64 = states
                .iter()
                .zip(&points)
                .map(|(m, &(bs, mtl))| m.sim.sm_utilization(bs, mtl))
                .sum();
            peak_contention = peak_contention.max(contention);
            let factor = contention.max(1.0);

            for (i, m) in states.iter_mut().enumerate() {
                let (bs, mtl) = points[i];
                let slo = m.schedule.at(w);
                let pending = m.pending_launch_ms;
                m.pending_launch_ms = 0.0;
                m.admitted = (bs, mtl);
                let (record, obs) = serve_closed_window(
                    &cfg,
                    w,
                    slo,
                    (bs, mtl),
                    factor,
                    pending,
                    &mut m.sim,
                    &mut m.window,
                    &mut m.latencies,
                    &mut m.acc,
                )?;
                m.trace.push(record);
                // Launch overhead is charged against the policy's own
                // previous request, not the admitted point — an admission
                // clamp must not bill launches that never happened.
                let requested_mtl = requested[i].1;
                if let Action::SetPoint { mtl: new_mtl, .. } = m.policy.observe(&obs) {
                    if new_mtl > requested_mtl {
                        m.pending_launch_ms +=
                            m.sim.launch_overhead_ms() * (new_mtl - requested_mtl) as f64;
                    }
                }
            }
        }

        let mut outcomes = Vec::with_capacity(states.len());
        for m in states {
            let mut out = assemble_outcome(
                &m.job,
                m.policy.name().to_string(),
                m.admitted,
                m.trace,
                m.latencies,
                &m.acc,
                0,
                0,
            );
            if let Some(name) = m.label {
                out.controller = name.to_string();
            }
            out.method = m.profile.as_ref().map(|p| p.method);
            out.profile = m.profile;
            outcomes.push(out);
        }
        let total_throughput = outcomes.iter().map(|o| o.throughput).sum();
        Ok(FleetOutcome {
            members: outcomes,
            total_throughput,
            peak_mem_mb,
            mem_capacity_mb: gpu.mem_mb,
            peak_contention,
            admission_clamps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;

    #[test]
    fn builder_rejects_empty_fleet_and_unknown_dnn() {
        assert_eq!(Fleet::builder().build().err(), Some(ConfigError::NoFleetMembers));
        let mut bogus = *paper_job(1).unwrap();
        bogus.dnn = "vgg16";
        assert_eq!(
            Fleet::builder().job(&bogus, PolicySpec::Clipper).build().err(),
            Some(ConfigError::UnknownDnn { dnn: "vgg16".into() })
        );
        assert_eq!(
            Fleet::builder()
                .windows(0)
                .job(paper_job(1).unwrap(), PolicySpec::Clipper)
                .build()
                .err(),
            Some(ConfigError::ZeroWindows)
        );
    }

    #[test]
    fn two_member_fleet_shares_the_gpu() {
        let out = Fleet::builder()
            .windows(16)
            .rounds_per_window(10)
            .seed(11)
            .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
            .job(paper_job(4).unwrap(), PolicySpec::DnnScaler)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.members.len(), 2);
        for m in &out.members {
            assert!(m.throughput > 0.0, "{}: zero throughput", m.dnn);
            assert!((0.0..=1.0).contains(&m.slo_attainment));
            assert_eq!(m.trace.len(), 16);
        }
        assert!(out.peak_mem_mb <= out.mem_capacity_mb);
        assert!(out.peak_mem_mb > 0.0);
        assert!(out.total_throughput > 0.0);
        // Two MT-class jobs at their seeded instance counts must actually
        // contend for SMs (factor > 1 => time-sharing kicked in).
        assert!(out.peak_contention > 1.0, "contention {}", out.peak_contention);
    }

    #[test]
    fn static_members_are_admission_checked() {
        // Two members asking for preposterous static points must be
        // shrunk by admission control rather than OOMing the shared GPU,
        // and the reported steady point must be the *admitted* one, not
        // the policy's request.
        let out = Fleet::builder()
            .windows(4)
            .rounds_per_window(4)
            .seed(3)
            .job(paper_job(7).unwrap(), PolicySpec::Static { bs: 128, mtl: 10 })
            .job(paper_job(3).unwrap(), PolicySpec::Static { bs: 128, mtl: 10 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(out.admission_clamps > 0, "admission must have intervened");
        assert!(out.peak_mem_mb <= out.mem_capacity_mb);
        for m in &out.members {
            assert!(m.throughput > 0.0);
            // 2x (128, 10) demands ~85 GB on a 24 GB card: both members
            // must have been shrunk, and the outcome must say so.
            assert!(
                m.steady_bs < 128,
                "{}: steady bs {} reports the request, not the admitted point",
                m.dnn,
                m.steady_bs
            );
            let last = m.trace.last().unwrap();
            assert_eq!((last.bs, last.mtl), (m.steady_bs, m.steady_mtl));
        }
    }
}
