//! `Fleet`: multiple jobs served concurrently on one shared-capacity GPU.
//!
//! The paper (and the legacy closed-loop runner) serve one job per
//! device; real clusters co-locate *different* models on one accelerator
//! ("No DNN Left Behind"-style multi-tenancy). `Fleet` expresses that
//! scenario on the simulated Tesla P40:
//!
//! * **Shared memory** — before every control window the members'
//!   requested operating points pass an admission check against the
//!   GPU's memory capacity; the greediest member is shrunk (batch halved,
//!   then instances shed) until the combined demand fits, so the fleet
//!   never OOMs. Under `MigSlices` each member additionally owns only its
//!   slice bundle's share of the memory ([`plan_mem_ceilings`]): a model
//!   whose footprint cannot fit its slice is refused at build time
//!   (typed [`PartitionError::MemoryExceeded`]), and per window the
//!   member's operating point is clamped down to its slice ceiling.
//! * **Shared SMs** — how the members share compute is set by the
//!   fleet's [`PartitionMode`]:
//!   - `TimeShare` (default, the paper's regime): the members' combined
//!     SM utilization sets a contention factor; when it exceeds 1 the
//!     GPU time-shares and every member's batch latency is inflated
//!     proportionally. Policies observe those inflated latencies and
//!     back off — the cross-job feedback loop single-job serving cannot
//!     express.
//!   - `Mps` / `MigSlices` (spatial): each member holds an SM capacity
//!     *grant* (an MPS fraction, or whole MIG slices quantized down
//!     conservatively) and executes inside it via the granted perf
//!     model — neighbours can no longer inflate each other, they can
//!     only run slower inside their own share. Reservations come from
//!     [`FleetBuilder::sm_reservation`] (unreserved members split the
//!     rest equally), are admitted per window through an
//!     [`SmPool`] that refuses over-subscription, and can be moved
//!     between members at window boundaries by a
//!     [`PartitionPolicy`] (rebalances are re-validated; invalid ones
//!     are rejected and counted as admission clamps).
//!
//! Fleets serve in one of two modes, decided by how members are added:
//!
//! * **Closed-loop** ([`FleetBuilder::job`]): members run their control
//!   windows in lockstep (window `w` of every member sees the same
//!   contention snapshot), batches issued back-to-back — exactly the
//!   pre-engine behaviour, byte for byte.
//! * **Open-loop** ([`FleetBuilder::job_with_arrivals`]): every member
//!   gets its own [`ArrivalPattern`] (Poisson, bursty, or a recorded
//!   trace), bounded [`workload::RequestQueue`], batch-formation timeout,
//!   and optional SLO deadline shedding — all served by per-member
//!   [`engine::OpenLoop`] cores. One global event loop interleaves the
//!   members' batch rounds by next-event time (smallest member clock
//!   first) while the per-window admission check and SM-contention
//!   coupling stay exactly as in the closed loop. This is the setting
//!   where one member's burst degrades its neighbours' tails and
//!   admission-under-overload actually matters.
//!
//! Each member's [`Policy`] is resolved from a [`PolicySpec`] — DNNScaler
//! members profile themselves alone at fleet start, as the paper's
//! profiler would.
//!
//! ## One serving core, any number of devices
//!
//! Since PR 5 the window/event machinery here is written over a *slice
//! of devices*: [`run_closed_devices`] / [`run_open_devices`] drive one
//! [`DeviceCtx`] (admission capacity + SM capacity fraction +
//! partitioner + telemetry) per device, with ONE global
//! [`EventCalendar`] interleaving every member of every device by
//! next-event time. `Fleet::run` is the single-device call of that core
//! (byte-identical to the pre-cluster fleet — golden-fixture enforced),
//! and [`super::cluster::Cluster`] is the heterogeneous multi-device
//! call, so cluster serving reuses admission, partitioning, shedding,
//! and the zero-allocation steady state per device instead of
//! reimplementing them.
//!
//! [`workload::RequestQueue`]: crate::workload::RequestQueue
//! [`engine::OpenLoop`]: super::engine::OpenLoop
//! [`run_closed_devices`]: run_closed_devices
//! [`run_open_devices`]: run_open_devices
//! [`plan_mem_ceilings`]: crate::gpusim::plan_mem_ceilings
//! [`PartitionError::MemoryExceeded`]: crate::gpusim::PartitionError

use crate::device::{Device, DeviceError};
use crate::gpusim::{
    check_mem_ceilings, plan_grants, GpuSim, GpuSpec, PartitionMode, SmPool, MIN_GRANT,
    TESLA_P40,
};
use crate::workload::ArrivalPattern;

use super::calendar::{EventCalendar, NextEventQueue};
use super::engine::{OpenLoop, SmShare, WindowAccum};
use super::job::JobSpec;
use super::latency::LatencyWindow;
use super::policy::{Action, PartitionPolicy, Policy, WindowObservation};
use super::profiler::ProfileOutcome;
use super::session::{
    assemble_outcome, resolve_policy, serve_closed_window, validate_pattern, AttainAcc,
    ConfigError, JobOutcome, PolicySpec, RunConfig, SloSchedule, WindowRecord,
    DEFAULT_BATCH_TIMEOUT_MS,
};
use super::slo::{SloClass, SloReport};

/// Result of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Per-member outcomes, in the order jobs were added.
    pub members: Vec<JobOutcome>,
    /// Sum of member steady-state throughputs (inferences/s).
    pub total_throughput: f64,
    /// Sum of member steady-state goodputs (SLO-met inferences/s).
    pub total_goodput: f64,
    /// Peak combined GPU memory demand over the run (MB).
    pub peak_mem_mb: f64,
    /// The shared GPU's memory capacity (MB).
    pub mem_capacity_mb: f64,
    /// Peak combined SM pressure. TimeShare: combined SM utilization
    /// (values > 1 mean time-sharing). Spatial modes: peak total granted
    /// SM fraction (the pool never lets this exceed 1).
    pub peak_contention: f64,
    /// Combined SM pressure per control window — the raw material for
    /// watching cross-job interference build up and re-converge. In
    /// spatial modes this records the total SM fraction granted each
    /// window (the admission ledger), never above 1.
    pub contention_trace: Vec<f64>,
    /// Times the admission check shrank a member's requested point (or,
    /// in spatial modes, rejected a partition-policy rebalance).
    pub admission_clamps: u64,
    /// How the fleet divided the SMs.
    pub partition: PartitionMode,
    /// Per-window SM grants, one inner vec per window in member order.
    /// Empty for `TimeShare` (there are no grants to record).
    pub grant_trace: Vec<Vec<f64>>,
    /// Per-class goodput / shed accounting. `None` — and absent from the
    /// snapshot — unless at least one member carries an [`SloClass`].
    pub slo: Option<SloReport>,
}

/// One member's configuration: job, policy, and (open loop only) its
/// arrival process and queueing knobs. Shared with
/// [`super::cluster::ClusterBuilder`], whose jobs carry the identical
/// per-member knobs before placement scatters them across devices.
pub(crate) struct MemberCfg<'a> {
    pub(crate) job: JobSpec,
    pub(crate) policy: PolicySpec<'a>,
    pub(crate) arrivals: ArrivalPattern,
    pub(crate) queue_capacity: Option<usize>,
    /// None = engine default (5 ms); kept optional so `build()` can tell
    /// "never set" apart from "set on a closed-loop member" (an error).
    pub(crate) batch_timeout_ms: Option<f64>,
    pub(crate) shed_deadline: bool,
    /// Explicit shedding deadline (ms). None = shed against the window
    /// SLO, the legacy behaviour. Only meaningful with `shed_deadline`.
    pub(crate) deadline_ms: Option<f64>,
    /// Service class (gold / silver / best-effort). None = unclassed:
    /// full deadline, gold-equivalent admission weight, and no per-class
    /// accounting — byte-identical to the pre-class engine.
    pub(crate) slo_class: Option<SloClass>,
    /// SM fraction reserved for this member under a spatial
    /// [`PartitionMode`]; None = an equal share of the unreserved rest.
    pub(crate) sm_reservation: Option<f64>,
}

impl<'a> MemberCfg<'a> {
    pub(crate) fn new(job: &JobSpec, policy: PolicySpec<'a>, arrivals: ArrivalPattern) -> Self {
        MemberCfg {
            job: *job,
            policy,
            arrivals,
            queue_capacity: None,
            batch_timeout_ms: None,
            shed_deadline: false,
            deadline_ms: None,
            slo_class: None,
            sm_reservation: None,
        }
    }
}

/// Validate one member configuration the way both `FleetBuilder` and
/// `ClusterBuilder` must: known DNN, sane arrival pattern, queueing
/// knobs only on open-loop arrivals.
pub(crate) fn validate_member_cfg(m: &MemberCfg<'_>) -> Result<(), ConfigError> {
    if crate::gpusim::paper_profile(m.job.dnn).is_none() {
        return Err(ConfigError::UnknownDnn { dnn: m.job.dnn.to_string() });
    }
    validate_pattern(&m.arrivals)?;
    if m.queue_capacity == Some(0) {
        return Err(ConfigError::ZeroQueueCapacity);
    }
    if let Some(t) = m.batch_timeout_ms {
        if !t.is_finite() || t < 0.0 {
            return Err(ConfigError::BadBatchTimeout { timeout_ms: t });
        }
    }
    if let Some(d) = m.deadline_ms {
        if !d.is_finite() || d <= 0.0 {
            return Err(ConfigError::BadDeadline { deadline_ms: d });
        }
    }
    // Every queueing knob is meaningless on a closed-loop member
    // (there is no queue); refuse to silently discard any of them.
    if m.arrivals.is_closed() {
        if m.shed_deadline {
            return Err(ConfigError::ShedRequiresOpenLoop);
        }
        if m.queue_capacity.is_some() {
            return Err(ConfigError::KnobRequiresOpenLoop { knob: "queue_capacity" });
        }
        if m.batch_timeout_ms.is_some() {
            return Err(ConfigError::KnobRequiresOpenLoop { knob: "batch_timeout_ms" });
        }
        if m.deadline_ms.is_some() {
            return Err(ConfigError::KnobRequiresOpenLoop { knob: "deadline_ms" });
        }
        // A class drives shedding, admission weighting, and reporting —
        // all open-loop machinery.
        if m.slo_class.is_some() {
            return Err(ConfigError::KnobRequiresOpenLoop { knob: "slo_class" });
        }
    }
    // An explicit deadline acts only at shed time; without shedding it
    // would be a silent no-op. (A class alone is fine: it also weights
    // admission and reporting.)
    if m.deadline_ms.is_some() && !m.shed_deadline {
        return Err(ConfigError::DeadlineRequiresShed);
    }
    Ok(())
}

/// Bare model footprint (MB) of a validated DNN at `(bs, mtl) = (1, 1)`
/// — the least memory the job can ever occupy. THE footprint definition
/// shared by build-time MIG admission, rebalance guarding, and cluster
/// placement feasibility, so the three can never disagree. Panics on an
/// unknown DNN: every caller runs after `validate_member_cfg`.
pub(crate) fn model_footprint_mb(dnn: &str) -> f64 {
    let p = crate::gpusim::paper_profile(dnn).expect("validated DNN");
    crate::gpusim::perf::mem_demand_mb(&p, 1, 1)
}

/// Map a whole-list knob onto `members` members: one value broadcasts,
/// a full-length list applies in member order, any other count is a
/// typed [`ConfigError::ListCountMismatch`]; and when the per-member
/// form of the knob was already used, the list is refused
/// ([`ConfigError::ListOverridesMemberKnob`]) instead of silently
/// overwriting those values. One implementation for
/// `FleetBuilder::sm_reservations`, `ClusterBuilder::poisson_rates`,
/// and the `slo_classes` lists, so the count/conflict policies cannot
/// drift between knobs.
pub(crate) fn expand_member_list<T: Copy>(
    list_knob: &'static str,
    member_knob: &'static str,
    values: Vec<T>,
    members: usize,
    member_form_used: bool,
) -> Result<Vec<T>, ConfigError> {
    if member_form_used {
        return Err(ConfigError::ListOverridesMemberKnob { list: list_knob, knob: member_knob });
    }
    if values.len() == 1 {
        return Ok(vec![values[0]; members]);
    }
    if values.len() == members {
        return Ok(values);
    }
    Err(ConfigError::ListCountMismatch { knob: list_knob, got: values.len(), members })
}

/// Reject a member set that mixes lockstep windows and the event loop.
pub(crate) fn validate_arrival_modes(members: &[MemberCfg<'_>]) -> Result<(), ConfigError> {
    let closed = members.iter().filter(|m| m.arrivals.is_closed()).count();
    if closed != 0 && closed != members.len() {
        return Err(ConfigError::MixedArrivalModes);
    }
    Ok(())
}

/// Builder for [`Fleet`].
pub struct FleetBuilder<'a> {
    gpu: GpuSpec,
    cfg: RunConfig,
    seed: u64,
    members: Vec<MemberCfg<'a>>,
    partition: PartitionMode,
    partition_policy: Option<Box<dyn PartitionPolicy + 'a>>,
    /// Whole reservation list supplied through
    /// [`FleetBuilder::sm_reservations`] (applied, and count-checked, at
    /// `build()`).
    reservation_list: Option<Vec<f64>>,
    /// Whole class list supplied through [`FleetBuilder::slo_classes`]
    /// (applied, and count-checked, at `build()`).
    class_list: Option<Vec<SloClass>>,
    /// First per-member knob that was set before any member existed
    /// (reported as a typed error at `build()`).
    knob_before_job: Option<&'static str>,
}

impl<'a> FleetBuilder<'a> {
    fn new() -> Self {
        FleetBuilder {
            gpu: TESLA_P40,
            cfg: RunConfig::default(),
            seed: 42,
            members: Vec::new(),
            partition: PartitionMode::TimeShare,
            partition_policy: None,
            reservation_list: None,
            class_list: None,
            knob_before_job: None,
        }
    }

    /// The shared accelerator (default: the paper's Tesla P40).
    pub fn gpu(mut self, gpu: GpuSpec) -> Self {
        self.gpu = gpu;
        self
    }

    /// Replace the shared serving config.
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn windows(mut self, windows: usize) -> Self {
        self.cfg.windows = windows;
        self
    }

    pub fn rounds_per_window(mut self, rounds: usize) -> Self {
        self.cfg.rounds_per_window = rounds;
        self
    }

    /// Seed for member simulators (member `i` gets `seed + i`; its
    /// arrival stream gets an independent derived seed).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Add a closed-loop member job with its serving policy.
    pub fn job(self, job: &JobSpec, policy: PolicySpec<'a>) -> Self {
        self.job_with_arrivals(job, policy, ArrivalPattern::Closed)
    }

    /// Add a member job with its own open-loop arrival process. Follow
    /// with [`FleetBuilder::queue_capacity`] /
    /// [`FleetBuilder::batch_timeout_ms`] / [`FleetBuilder::shed_deadline`]
    /// to tune that member's queueing behaviour.
    pub fn job_with_arrivals(
        mut self,
        job: &JobSpec,
        policy: PolicySpec<'a>,
        arrivals: ArrivalPattern,
    ) -> Self {
        self.members.push(MemberCfg::new(job, policy, arrivals));
        self
    }

    /// How the fleet divides the GPU's SMs (default:
    /// [`PartitionMode::TimeShare`], the legacy contention-factor
    /// coupling). `Mps`/`MigSlices` switch to spatial capacity grants:
    /// members run inside their own SM share and never inflate each
    /// other's latency.
    pub fn partition_mode(mut self, mode: PartitionMode) -> Self {
        self.partition = mode;
        self
    }

    /// Reserve an SM fraction for the most recently added member
    /// (spatial modes only). Members without a reservation split the
    /// unreserved remainder equally; under `MigSlices` every grant is
    /// quantized down to whole slices.
    pub fn sm_reservation(mut self, fraction: f64) -> Self {
        if let Some(m) = self.last_member("sm_reservation") {
            m.sm_reservation = Some(fraction);
        }
        self
    }

    /// Reserve SM fractions for ALL members at once: one value
    /// (broadcast to every member) or exactly one per member, in member
    /// order. Any other count — in particular a list *longer* than the
    /// member count, which used to be possible to silently truncate at
    /// the CLI boundary — is a typed
    /// [`ConfigError::ListCountMismatch`] at `build()`.
    pub fn sm_reservations(mut self, fractions: &[f64]) -> Self {
        self.reservation_list = Some(fractions.to_vec());
        self
    }

    /// Install a fleet-level [`PartitionPolicy`] that may move SM
    /// reservations between members at window boundaries (spatial modes
    /// only). Rebalances are re-validated like build-time reservations;
    /// invalid proposals are rejected and counted as admission clamps.
    pub fn partition_policy(mut self, policy: impl PartitionPolicy + 'a) -> Self {
        self.partition_policy = Some(Box::new(policy));
        self
    }

    fn last_member(&mut self, knob: &'static str) -> Option<&mut MemberCfg<'a>> {
        if self.members.is_empty() && self.knob_before_job.is_none() {
            self.knob_before_job = Some(knob);
        }
        self.members.last_mut()
    }

    /// Bound the most recently added member's request queue; overflowing
    /// arrivals are dropped and counted (default: unbounded).
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        if let Some(m) = self.last_member("queue_capacity") {
            m.queue_capacity = Some(capacity);
        }
        self
    }

    /// Batch-formation timeout for the most recently added member
    /// (default 5 ms).
    pub fn batch_timeout_ms(mut self, timeout_ms: f64) -> Self {
        if let Some(m) = self.last_member("batch_timeout_ms") {
            m.batch_timeout_ms = Some(timeout_ms);
        }
        self
    }

    /// Enable SLO deadline shedding for the most recently added member:
    /// requests whose queueing delay alone already exceeds the member's
    /// SLO are dropped at dispatch and counted separately.
    pub fn shed_deadline(mut self, enabled: bool) -> Self {
        if let Some(m) = self.last_member("shed_deadline") {
            m.shed_deadline = enabled;
        }
        self
    }

    /// Explicit shedding deadline (ms) for the most recently added
    /// member, replacing the window SLO at shed time (the member's SLO
    /// target itself is untouched — attainment and goodput still judge
    /// against it). Requires `shed_deadline`; must be finite and > 0.
    pub fn deadline_ms(mut self, deadline_ms: f64) -> Self {
        if let Some(m) = self.last_member("deadline_ms") {
            m.deadline_ms = Some(deadline_ms);
        }
        self
    }

    /// Service class for the most recently added member: scales its
    /// effective shedding deadline ([`SloClass::shed_scale`]), weights it
    /// in memory-overload admission ([`SloClass::shed_weight`] — under
    /// pressure best-effort shrinks before silver before gold), and adds
    /// it to the per-class `slo` accounting of the outcome. Open-loop
    /// members only.
    pub fn slo_class(mut self, class: SloClass) -> Self {
        if let Some(m) = self.last_member("slo_class") {
            m.slo_class = Some(class);
        }
        self
    }

    /// Service classes for ALL members at once: one class (broadcast) or
    /// exactly one per member, in member order — same count/conflict
    /// rules as [`FleetBuilder::sm_reservations`].
    pub fn slo_classes(mut self, classes: &[SloClass]) -> Self {
        self.class_list = Some(classes.to_vec());
        self
    }

    /// Validate and assemble the fleet.
    pub fn build(mut self) -> Result<Fleet<'a>, ConfigError> {
        if let Some(knob) = self.knob_before_job {
            return Err(ConfigError::MemberKnobBeforeJob { knob });
        }
        if self.cfg.windows == 0 {
            return Err(ConfigError::ZeroWindows);
        }
        if self.cfg.rounds_per_window == 0 {
            return Err(ConfigError::ZeroRounds);
        }
        if self.cfg.max_bs == 0 || self.cfg.max_mtl == 0 {
            return Err(ConfigError::ZeroKnobCeiling {
                max_bs: self.cfg.max_bs,
                max_mtl: self.cfg.max_mtl,
            });
        }
        if self.members.is_empty() {
            return Err(ConfigError::NoFleetMembers);
        }
        // A whole reservation list maps onto the members here (the
        // longer-than-members case is the PR 5 bugfix; mixing with
        // per-member sm_reservation calls is refused, not overwritten).
        if let Some(list) = self.reservation_list.take() {
            let expanded = expand_member_list(
                "sm_reservations",
                "sm_reservation",
                list,
                self.members.len(),
                self.members.iter().any(|m| m.sm_reservation.is_some()),
            )?;
            for (m, f) in self.members.iter_mut().zip(expanded) {
                m.sm_reservation = Some(f);
            }
        }
        // A whole class list maps the same way (broadcast / one per
        // member / typed mismatch; mixing with per-member slo_class is
        // refused, not overwritten).
        if let Some(list) = self.class_list.take() {
            let expanded = expand_member_list(
                "slo_classes",
                "slo_class",
                list,
                self.members.len(),
                self.members.iter().any(|m| m.slo_class.is_some()),
            )?;
            for (m, c) in self.members.iter_mut().zip(expanded) {
                m.slo_class = Some(c);
            }
        }
        for m in &self.members {
            validate_member_cfg(m)?;
        }
        // Lockstep windows and the event loop cannot be mixed in one run.
        validate_arrival_modes(&self.members)?;
        // Partition plan: spatial modes validate the reservations up
        // front (typed error, not a mid-run surprise); TimeShare has no
        // partitions, so partition knobs on it are refused outright.
        if self.partition.is_spatial() {
            let reservations: Vec<Option<f64>> =
                self.members.iter().map(|m| m.sm_reservation).collect();
            let grants =
                plan_grants(self.partition, &reservations).map_err(ConfigError::BadPartition)?;
            // MIG partitions memory along with the SMs: a member whose
            // bare model footprint cannot fit its slice bundle's memory
            // ceiling can never serve, whatever the admission check
            // later shrinks it to.
            let footprints: Vec<f64> =
                self.members.iter().map(|m| model_footprint_mb(m.job.dnn)).collect();
            check_mem_ceilings(self.partition, &grants, self.gpu.mem_mb, &footprints)
                .map_err(ConfigError::BadPartition)?;
        } else {
            if self.members.iter().any(|m| m.sm_reservation.is_some()) {
                return Err(ConfigError::KnobRequiresPartition { knob: "sm_reservation" });
            }
            if self.partition_policy.is_some() {
                return Err(ConfigError::KnobRequiresPartition { knob: "partition_policy" });
            }
        }
        Ok(Fleet {
            gpu: self.gpu,
            cfg: self.cfg,
            seed: self.seed,
            members: self.members,
            partition: self.partition,
            partition_policy: self.partition_policy,
        })
    }
}

/// A validated multi-job fleet, ready to run. Fields are crate-visible
/// so `coordinator::testkit` can re-serve the identical validated
/// configuration through its naive reference executor.
pub struct Fleet<'a> {
    pub(crate) gpu: GpuSpec,
    pub(crate) cfg: RunConfig,
    pub(crate) seed: u64,
    pub(crate) members: Vec<MemberCfg<'a>>,
    pub(crate) partition: PartitionMode,
    pub(crate) partition_policy: Option<Box<dyn PartitionPolicy + 'a>>,
}

/// Closed-loop member state (lockstep windows). Fields are crate-visible
/// for the `coordinator::testkit` reference executor.
pub(crate) struct Member<'a> {
    pub(crate) job: JobSpec,
    pub(crate) sim: GpuSim,
    pub(crate) policy: Box<dyn Policy + 'a>,
    pub(crate) profile: Option<ProfileOutcome>,
    pub(crate) label: Option<&'static str>,
    pub(crate) schedule: SloSchedule,
    pub(crate) window: LatencyWindow,
    pub(crate) trace: Vec<WindowRecord>,
    pub(crate) latencies: Vec<(f64, f64)>,
    pub(crate) acc: AttainAcc,
    pub(crate) pending_launch_ms: f64,
    /// Last operating point the admission check actually let this member
    /// serve at (what `JobOutcome::steady_*` reports — the policy's own
    /// request may be larger than the shared GPU ever granted).
    pub(crate) admitted: (u32, u32),
}

/// Build one closed-loop member: resolve its policy (DNNScaler members
/// profile themselves alone) on a simulator seeded with `sim_seed`.
pub(crate) fn new_closed_member<'a>(
    m: MemberCfg<'a>,
    cfg: &RunConfig,
    sim_seed: u64,
) -> Result<Member<'a>, DeviceError> {
    let mut sim = GpuSim::for_paper_dnn(m.job.dnn, m.job.dataset, sim_seed)
        .ok_or_else(|| DeviceError::Exec(format!("unknown DNN {:?}", m.job.dnn)))?;
    let (policy, profile, label) = resolve_policy(m.policy, cfg, &m.job, &mut sim)?;
    let admitted = policy.operating_point();
    Ok(Member {
        schedule: SloSchedule::new(m.job.slo_ms, cfg.slo_schedule.clone()),
        window: LatencyWindow::new(cfg.rounds_per_window),
        trace: Vec::with_capacity(cfg.windows),
        latencies: Vec::new(),
        acc: AttainAcc::new(cfg.windows / 2),
        pending_launch_ms: 0.0,
        admitted,
        job: m.job,
        sim,
        policy,
        profile,
        label,
    })
}

/// Open-loop member state (per-member engine core). Fields are
/// crate-visible so `coordinator::dynamics` can drive the same members
/// through churn, migration, and autoscaling window loops.
pub(crate) struct OpenMember<'a> {
    pub(crate) job: JobSpec,
    pub(crate) sim: GpuSim,
    pub(crate) policy: Box<dyn Policy + 'a>,
    pub(crate) profile: Option<ProfileOutcome>,
    pub(crate) label: Option<&'static str>,
    pub(crate) schedule: SloSchedule,
    pub(crate) lp: OpenLoop,
    pub(crate) trace: Vec<WindowRecord>,
    pub(crate) latencies: Vec<(f64, f64)>,
    pub(crate) acc: AttainAcc,
    pub(crate) admitted: (u32, u32),
    /// Service class, carried through to the outcome and the device's
    /// admission weights (None = unclassed).
    pub(crate) slo_class: Option<SloClass>,
}

/// Build one open-loop member (engine core seeded independently of the
/// device noise — the same u64 would replay the identical RNG stream).
pub(crate) fn new_open_member<'a>(
    m: MemberCfg<'a>,
    cfg: &RunConfig,
    sim_seed: u64,
    arrival_seed: u64,
) -> Result<OpenMember<'a>, DeviceError> {
    let mut sim = GpuSim::for_paper_dnn(m.job.dnn, m.job.dataset, sim_seed)
        .ok_or_else(|| DeviceError::Exec(format!("unknown DNN {:?}", m.job.dnn)))?;
    let (policy, profile, label) = resolve_policy(m.policy, cfg, &m.job, &mut sim)?;
    // Profiling consumed virtual time: arrivals during it form the
    // member's starting backlog, as in single-job serving.
    let overhead_ms = profile.as_ref().map_or(0.0, |p| p.overhead_ms);
    let admitted = policy.operating_point();
    let mut lp = OpenLoop::new(
        m.arrivals,
        arrival_seed,
        m.queue_capacity,
        m.batch_timeout_ms.unwrap_or(DEFAULT_BATCH_TIMEOUT_MS),
        m.shed_deadline,
        overhead_ms / 1000.0,
    );
    // An explicit deadline (if set) replaces the window SLO at shed
    // time, and the class multiplier tightens it. Defaults (None, 1.0)
    // leave shedding bit-identical to the pre-class engine.
    lp.set_shed_deadline(m.deadline_ms, m.slo_class.map_or(1.0, SloClass::shed_scale));
    Ok(OpenMember {
        schedule: SloSchedule::new(m.job.slo_ms, cfg.slo_schedule.clone()),
        lp,
        trace: Vec::with_capacity(cfg.windows),
        latencies: Vec::new(),
        acc: AttainAcc::new(cfg.windows / 2),
        admitted,
        slo_class: m.slo_class,
        job: m.job,
        sim,
        policy,
        profile,
        label,
    })
}

/// Derive a member's arrival-stream seed from the fleet seed and the
/// member's (global) index, independent of its simulator seed.
pub(crate) fn arrival_seed(seed: u64, index: usize) -> u64 {
    seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(index as u64)
}

/// Fold a finished closed-loop member into its outcome.
pub(crate) fn closed_member_outcome(m: Member<'_>) -> JobOutcome {
    let mut out = assemble_outcome(
        &m.job,
        m.policy.name().to_string(),
        m.admitted,
        m.trace,
        m.latencies,
        &m.acc,
        0,
        0,
        0,
        0,
    );
    if let Some(name) = m.label {
        out.controller = name.to_string();
    }
    out.method = m.profile.as_ref().map(|p| p.method);
    out.profile = m.profile;
    out
}

/// Fold a finished open-loop member into its outcome.
pub(crate) fn open_member_outcome(m: OpenMember<'_>) -> JobOutcome {
    let mut out = assemble_outcome(
        &m.job,
        m.policy.name().to_string(),
        m.admitted,
        m.trace,
        m.latencies,
        &m.acc,
        m.lp.arrived(),
        m.lp.dropped(),
        m.lp.dropped_deadline(),
        m.lp.max_depth(),
    );
    out.dropped_failure = m.lp.dropped_failure();
    out.slo_class = m.slo_class;
    if let Some(name) = m.label {
        out.controller = name.to_string();
    }
    out.method = m.profile.as_ref().map(|p| p.method);
    out.profile = m.profile;
    out
}

/// Shared-memory admission: shrink the greediest *shrinkable* consumer
/// (batch halved first, then instances shed) until the fleet fits.
/// Members already at (1, 1) are passed over — OOM is only an error when
/// nobody can give anything back. Used verbatim by both serving paths
/// (and per device by the cluster) so the admission semantics cannot
/// drift. Peak-memory telemetry is recorded by the caller from the
/// final served points (the MIG slice clamp can shrink them further
/// after this admission — the peak must reflect demand that was
/// actually resident, not a point that never served).
///
/// `weights` (per-member [`SloClass::shed_weight`] values; None for
/// runs with no classes) class-weights the victim choice: only the
/// *lowest-weight* shrinkable members are candidates, so under pressure
/// best-effort gives memory back before silver before gold. Equal
/// weights — in particular the all-unclassed / all-gold case — restrict
/// nothing, reducing bit-for-bit to the unweighted greediest-member
/// rule.
pub(crate) fn admit_window(
    demand: &dyn Fn(usize, (u32, u32)) -> f64,
    n_members: usize,
    requested: &[(u32, u32)],
    weights: Option<&[f64]>,
    mem_capacity_mb: f64,
    admission_clamps: &mut u64,
) -> Result<Vec<(u32, u32)>, DeviceError> {
    let weight = |i: usize| weights.map_or(1.0, |ws| ws[i]);
    let mut points = requested.to_vec();
    loop {
        let demands: Vec<f64> = (0..n_members).map(|i| demand(i, points[i])).collect();
        let total: f64 = demands.iter().sum();
        if total <= mem_capacity_mb {
            break;
        }
        let w_min = (0..n_members)
            .filter(|&i| points[i] != (1, 1))
            .map(weight)
            .fold(f64::INFINITY, f64::min);
        let Some((k, _)) = demands
            .iter()
            .enumerate()
            .filter(|&(i, _)| points[i] != (1, 1) && weight(i) <= w_min)
            .max_by(|a, b| a.1.total_cmp(b.1))
        else {
            return Err(DeviceError::OutOfMemory {
                demand_mb: total,
                capacity_mb: mem_capacity_mb,
            });
        };
        let p = &mut points[k];
        if p.0 > 1 {
            p.0 = (p.0 / 2).max(1);
        } else {
            p.1 -= 1;
        }
        *admission_clamps += 1;
    }
    Ok(points)
}

/// MIG memory-ceiling admission: clamp each member's admitted point
/// until its demand fits its slice bundle's share of device memory
/// (`grant * mem_mb`), same shrink discipline as [`admit_window`]
/// (batch halved first, then instances shed). No-op for modes that do
/// not partition memory. A member whose (1, 1) footprint still exceeds
/// its ceiling is a hard OOM — defensive only: the builder refuses such
/// configurations up front, and `Partitioner::maybe_rebalance` rejects
/// any rebalance whose ceilings would drop below a member's footprint.
pub(crate) fn clamp_to_slice_ceilings(
    mode: PartitionMode,
    grants: &[f64],
    mem_mb: f64,
    demand: &dyn Fn(usize, (u32, u32)) -> f64,
    points: &mut [(u32, u32)],
    admission_clamps: &mut u64,
) -> Result<(), DeviceError> {
    if !matches!(mode, PartitionMode::MigSlices { .. }) {
        return Ok(());
    }
    for (i, p) in points.iter_mut().enumerate() {
        let ceiling_mb = grants[i] * mem_mb;
        while demand(i, *p) > ceiling_mb {
            if *p == (1, 1) {
                return Err(DeviceError::OutOfMemory {
                    demand_mb: demand(i, *p),
                    capacity_mb: ceiling_mb,
                });
            }
            if p.0 > 1 {
                p.0 = (p.0 / 2).max(1);
            } else {
                p.1 -= 1;
            }
            *admission_clamps += 1;
        }
    }
    Ok(())
}

/// Per-run spatial-partition ledger shared by both serving paths: holds
/// the live reservations, plans + admits each window's grants through an
/// [`SmPool`], and applies (re-validated) `PartitionPolicy` rebalances.
pub(crate) struct Partitioner<'a> {
    mode: PartitionMode,
    reservations: Vec<Option<f64>>,
    policy: Option<Box<dyn PartitionPolicy + 'a>>,
    /// Per-member bare model footprints (MB) and the device memory they
    /// are measured against: a MIG rebalance must keep every member's
    /// slice ceiling above its footprint, or the run would OOM at the
    /// next window's slice clamp.
    mem_floors_mb: Vec<f64>,
    mem_mb: f64,
}

impl<'a> Partitioner<'a> {
    pub(crate) fn new(
        mode: PartitionMode,
        members: &[MemberCfg<'_>],
        policy: Option<Box<dyn PartitionPolicy + 'a>>,
        mem_mb: f64,
    ) -> Self {
        Partitioner {
            mode,
            reservations: members.iter().map(|m| m.sm_reservation).collect(),
            policy,
            // Only MIG partitions memory; other modes never read the
            // floors (check_mem_ceilings is vacuous for them).
            mem_floors_mb: if matches!(mode, PartitionMode::MigSlices { .. }) {
                members.iter().map(|m| model_footprint_mb(m.job.dnn)).collect()
            } else {
                Vec::new()
            },
            mem_mb,
        }
    }

    /// A time-sharing partitioner over `n` members — what every cluster
    /// device uses (within a device, members time-share; spatial
    /// partitioning across devices is the cluster's job). TimeShare
    /// records no grants, so rebalancing (and its memory-floor check)
    /// never runs.
    pub(crate) fn timeshare(n: usize) -> Self {
        Partitioner {
            mode: PartitionMode::TimeShare,
            reservations: vec![None; n],
            policy: None,
            mem_floors_mb: Vec::new(),
            mem_mb: f64::INFINITY,
        }
    }

    pub(crate) fn mode(&self) -> PartitionMode {
        self.mode
    }

    /// Plan this window's grants and admit them against the SM pool.
    /// The builder validated the reservations (and every accepted
    /// rebalance is re-validated), so failures here are defensive.
    fn window_grants(&self) -> Result<Vec<f64>, DeviceError> {
        let grants = plan_grants(self.mode, &self.reservations)
            .map_err(|e| DeviceError::Exec(format!("SM partition plan: {e}")))?;
        let mut pool = SmPool::new();
        for g in &grants {
            pool.try_grant(*g)
                .map_err(|e| DeviceError::Exec(format!("SM partition admission: {e}")))?;
        }
        Ok(grants)
    }

    /// This window's SM shares plus telemetry: spatial modes plan + admit
    /// per-member grants (recorded in `grant_trace`, totals in
    /// `contention_trace`); `TimeShare` evaluates `contention` (the
    /// members' combined SM utilization, relative to the device's
    /// `perf_fraction` of the calibration GPU) and inflates everyone by
    /// it. A whole device (`perf_fraction = 1`) takes the exact legacy
    /// path (division by 1.0 is exact); a slice-as-device executes
    /// inside its grant AND time-shares within it
    /// ([`SmShare::GrantInflate`]). One implementation for both serving
    /// paths — and for every cluster device — like [`admit_window`].
    pub(crate) fn window_shares(
        &self,
        contention: impl FnOnce() -> f64,
        n_members: usize,
        perf_fraction: f64,
        peak_contention: &mut f64,
        contention_trace: &mut Vec<f64>,
        grant_trace: &mut Vec<Vec<f64>>,
    ) -> Result<Vec<SmShare>, DeviceError> {
        if self.mode.is_spatial() {
            let grants = self.window_grants()?;
            let total: f64 = grants.iter().sum();
            *peak_contention = peak_contention.max(total);
            contention_trace.push(total);
            let shares = grants.iter().map(|&g| SmShare::Grant(g)).collect();
            grant_trace.push(grants);
            Ok(shares)
        } else {
            let contention = contention() / perf_fraction;
            *peak_contention = peak_contention.max(contention);
            contention_trace.push(contention);
            let factor = contention.max(1.0);
            if perf_fraction >= 1.0 {
                Ok(vec![SmShare::Inflate(factor); n_members])
            } else {
                Ok(vec![
                    SmShare::GrantInflate { grant: perf_fraction, factor };
                    n_members
                ])
            }
        }
    }

    /// Smallest share the mode can actually grant (one MIG slice, or the
    /// global `MIN_GRANT` fraction under MPS).
    fn min_share(&self) -> f64 {
        match self.mode {
            PartitionMode::MigSlices { slices } => 1.0 / slices.max(1) as f64,
            _ => MIN_GRANT,
        }
    }

    /// Offer the window's observations to the partition policy; an
    /// accepted rebalance replaces the reservations, an invalid one is
    /// rejected and counted against `admission_clamps`. Proposals are
    /// sanitized, not trusted: a wrong-length or non-finite vector is
    /// rejected outright, values are lifted to the mode's smallest
    /// grantable share first — a policy that nudges a member just below
    /// one MIG slice must not deadlock rebalancing forever — and (MIG)
    /// a rebalance whose slice memory ceiling would drop below any
    /// member's model footprint is rejected like any other invalid
    /// proposal, instead of OOMing the run at the next window's clamp.
    pub(crate) fn maybe_rebalance(
        &mut self,
        obs: &[WindowObservation],
        grants: &[f64],
        admission_clamps: &mut u64,
    ) {
        let Some(policy) = self.policy.as_mut() else { return };
        let Some(next) = policy.rebalance(obs, grants) else { return };
        if next.len() != self.reservations.len() || next.iter().any(|v| !v.is_finite()) {
            *admission_clamps += 1;
            return;
        }
        let floor = self.min_share();
        let proposed: Vec<Option<f64>> =
            next.into_iter().map(|v| Some(v.max(floor))).collect();
        match plan_grants(self.mode, &proposed) {
            Ok(planned)
                if check_mem_ceilings(self.mode, &planned, self.mem_mb, &self.mem_floors_mb)
                    .is_ok() =>
            {
                self.reservations = proposed;
            }
            _ => *admission_clamps += 1,
        }
    }
}

/// One (virtual) device's context in a serving run: admission capacity,
/// SM capacity fraction, partitioner, and shared-GPU telemetry. `Fleet`
/// runs one of these; [`super::cluster::Cluster`] runs one per device.
pub(crate) struct DeviceCtx<'a> {
    /// Memory admission capacity (MB) — a whole GPU's memory, or a MIG
    /// virtual device's slice ceiling.
    pub(crate) mem_capacity_mb: f64,
    /// SM capacity as a fraction of the calibration GPU (1.0 = a whole
    /// P40-class device; a MIG virtual device or a smaller catalogued
    /// GPU holds less).
    pub(crate) perf_fraction: f64,
    pub(crate) parts: Partitioner<'a>,
    pub(crate) peak_mem_mb: f64,
    pub(crate) peak_contention: f64,
    pub(crate) admission_clamps: u64,
    pub(crate) contention_trace: Vec<f64>,
    pub(crate) grant_trace: Vec<Vec<f64>>,
}

impl<'a> DeviceCtx<'a> {
    pub(crate) fn new(
        mem_capacity_mb: f64,
        perf_fraction: f64,
        parts: Partitioner<'a>,
        windows: usize,
    ) -> Self {
        DeviceCtx {
            mem_capacity_mb,
            perf_fraction,
            parts,
            peak_mem_mb: 0.0,
            peak_contention: 0.0,
            admission_clamps: 0,
            contention_trace: Vec::with_capacity(windows),
            grant_trace: Vec::new(),
        }
    }
}

/// One closed-loop device: its context plus lockstep members.
pub(crate) struct ClosedDevice<'a> {
    pub(crate) ctx: DeviceCtx<'a>,
    pub(crate) members: Vec<Member<'a>>,
}

/// A device-scoped serving failure: the index of the failing device
/// within the slice the run was handed, plus the device's own first
/// error. Multi-device runs surface the failure with the LOWEST device
/// index, whatever the thread count — devices never couple, so each
/// device's error is deterministic in isolation and "lowest index" is a
/// thread-layout-independent choice (the old behaviour leaked whichever
/// shard's error happened to be collected first).
#[derive(Debug)]
pub(crate) struct DeviceFailure {
    pub(crate) device: usize,
    pub(crate) error: DeviceError,
}

/// Fold a per-device failure table into the run result: the lowest
/// failing device index wins.
fn first_device_failure(failed: Vec<Option<DeviceError>>) -> Result<(), DeviceFailure> {
    failed
        .into_iter()
        .enumerate()
        .find_map(|(device, e)| e.map(|error| DeviceFailure { device, error }))
        .map_or(Ok(()), Err)
}

/// Fold per-shard results into one: shard-local device indices are
/// rebased onto the full device slice (shard `s` starts at device
/// `s * chunk`) and the failure with the lowest flat device index wins.
/// Each shard already reports its own lowest failing device, so the
/// minimum over shards is exactly what the serial engine reports — the
/// surfaced error is identical at every thread count.
fn merge_shard_failures(
    results: Vec<Result<(), DeviceFailure>>,
    chunk: usize,
) -> Result<(), DeviceFailure> {
    results
        .into_iter()
        .enumerate()
        .filter_map(|(s, r)| {
            r.err().map(|f| DeviceFailure { device: s * chunk + f.device, error: f.error })
        })
        .min_by_key(|f| f.device)
        .map_or(Ok(()), Err)
}

/// Serve one closed-loop device's control window: admission, SM shares,
/// slice clamps, member serving, policy observation, rebalancing.
fn run_closed_device_window(
    cfg: &RunConfig,
    w: usize,
    dev: &mut ClosedDevice<'_>,
) -> Result<(), DeviceError> {
    let ClosedDevice { ctx, members: states } = dev;
    if states.is_empty() {
        return Ok(());
    }
    // Requested operating points, then shared-memory admission (classes
    // are open-loop-only, so the closed path is always unweighted).
    let requested: Vec<(u32, u32)> = states.iter().map(|m| m.policy.operating_point()).collect();
    let mut points = admit_window(
        &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
        states.len(),
        &requested,
        None,
        ctx.mem_capacity_mb,
        &mut ctx.admission_clamps,
    )?;

    // SM regime for the window: the combined-pressure time-sharing
    // factor, or (spatial modes) per-member capacity grants taken
    // from the SM pool. On a fractional device each member's
    // utilization is measured inside the device grant (capped at
    // it), so a lone member on a slice is slowed only by the
    // grant itself, never additionally by "contention" with
    // nobody; the whole-device path is the exact legacy call.
    let g = ctx.perf_fraction;
    let shares = ctx.parts.window_shares(
        || {
            states
                .iter()
                .zip(&points)
                .map(|(m, &(bs, mtl))| {
                    if g >= 1.0 {
                        m.sim.sm_utilization(bs, mtl)
                    } else {
                        m.sim.sm_utilization_granted(bs, mtl, g)
                    }
                })
                .sum()
        },
        states.len(),
        ctx.perf_fraction,
        &mut ctx.peak_contention,
        &mut ctx.contention_trace,
        &mut ctx.grant_trace,
    )?;
    // MIG also partitions memory: clamp each member to its slice
    // bundle's memory ceiling (no-op for other modes).
    if let Some(grants) = ctx.grant_trace.last() {
        clamp_to_slice_ceilings(
            ctx.parts.mode(),
            grants,
            ctx.mem_capacity_mb,
            &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
            &mut points,
            &mut ctx.admission_clamps,
        )?;
    }
    // Peak telemetry from the points that actually serve (the
    // slice clamp may have shrunk them below the admitted ones).
    let resident: f64 = states
        .iter()
        .zip(&points)
        .map(|(m, &(bs, mtl))| m.sim.mem_demand_mb(bs, mtl))
        .sum();
    ctx.peak_mem_mb = ctx.peak_mem_mb.max(resident);

    let mut window_obs: Vec<WindowObservation> = Vec::with_capacity(states.len());
    for (i, m) in states.iter_mut().enumerate() {
        let (bs, mtl) = points[i];
        let slo = m.schedule.at(w);
        let pending = m.pending_launch_ms;
        m.pending_launch_ms = 0.0;
        m.admitted = (bs, mtl);
        let (record, obs) = serve_closed_window(
            cfg,
            w,
            slo,
            (bs, mtl),
            shares[i],
            pending,
            &mut m.sim,
            &mut m.window,
            &mut m.latencies,
            &mut m.acc,
        )?;
        m.trace.push(record);
        // Launch overhead is charged against the policy's own
        // previous request, not the admitted point — an admission
        // clamp must not bill launches that never happened.
        let requested_mtl = requested[i].1;
        if let Action::SetPoint { mtl: new_mtl, .. } = m.policy.observe(&obs) {
            if new_mtl > requested_mtl {
                m.pending_launch_ms +=
                    m.sim.launch_overhead_ms() * (new_mtl - requested_mtl) as f64;
            }
        }
        window_obs.push(obs);
    }
    if let Some(grants) = ctx.grant_trace.last() {
        ctx.parts.maybe_rebalance(&window_obs, grants, &mut ctx.admission_clamps);
    }
    Ok(())
}

/// Serve every control window of every closed-loop device. Devices are
/// independent (each member owns its simulator; coupling is per-device
/// admission + contention), so iterating them in order preserves the
/// single-device byte-for-byte behaviour exactly. A device that errors
/// goes dead — it is skipped for the rest of the run while the other
/// devices finish — and the failure surfaced at the end is the one with
/// the lowest device index, so serial and sharded runs report the
/// identical error.
pub(crate) fn run_closed_devices(
    cfg: &RunConfig,
    devs: &mut [ClosedDevice<'_>],
) -> Result<(), DeviceFailure> {
    let mut failed: Vec<Option<DeviceError>> = (0..devs.len()).map(|_| None).collect();
    for w in 0..cfg.windows {
        for (d, dev) in devs.iter_mut().enumerate() {
            if failed[d].is_some() {
                continue;
            }
            if let Err(e) = run_closed_device_window(cfg, w, dev) {
                failed[d] = Some(e);
            }
        }
    }
    first_device_failure(failed)
}

/// Number of whole-device shards a `threads` request actually gets:
/// at least one, never more than the device count (a worker with no
/// devices would be pure overhead).
pub(crate) fn shard_count(threads: usize, devices: usize) -> usize {
    threads.max(1).min(devices.max(1))
}

/// Data-parallel form of [`run_closed_devices`]: split the device list
/// into `threads` contiguous shards and run the UNCHANGED serial window
/// loop on each shard from its own scoped worker thread.
///
/// This is byte-identical to the serial engine because devices never
/// couple: every per-window interaction (admission, SM contention,
/// slice clamps, rebalancing) is scoped to one device's members, each
/// member owns its simulator RNG, and closed-loop windows have no
/// cross-device event interleaving at all. Sharding therefore changes
/// *which thread* executes a device's windows, never *what* they
/// compute. `threads <= 1` dispatches straight to the serial reference
/// engine. On error runs, every shard finishes, each reporting its own
/// lowest failing device; the merge rebases those onto flat device
/// indices and surfaces the lowest — the same error the serial loop
/// reports, at every thread count.
pub(crate) fn run_closed_devices_parallel(
    cfg: &RunConfig,
    devs: &mut [ClosedDevice<'_>],
    threads: usize,
) -> Result<(), DeviceFailure> {
    let threads = shard_count(threads, devs.len());
    if threads <= 1 {
        return run_closed_devices(cfg, devs);
    }
    let chunk = devs.len().div_ceil(threads);
    let results: Vec<Result<(), DeviceFailure>> = std::thread::scope(|s| {
        let handles: Vec<_> = devs
            .chunks_mut(chunk)
            .map(|shard| s.spawn(move || run_closed_devices(cfg, shard)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("closed shard worker panicked")).collect()
    });
    merge_shard_failures(results, chunk)
}

/// Data-parallel form of [`run_open_devices`]: contiguous whole-device
/// shards, one scoped worker thread per shard, each running the
/// UNCHANGED serial engine (so each shard interleaves its own members
/// through a per-shard [`EventCalendar`]).
///
/// Byte-identity argument: the global calendar's cross-device
/// interleaving is observationally irrelevant — `serve_round` mutates
/// only the popped member's state (`lp`, `sim`, its window accumulator),
/// and all cross-member coupling happens per-device at window
/// boundaries. Within one device, the per-shard calendar pops members in
/// exactly the order the global calendar would (same keys, ties toward
/// the lower index), so every member serves the identical round
/// sequence whatever the shard layout. The differential suite in
/// `tests/parallel.rs` enforces this snapshot-byte-for-byte.
/// Error runs mirror [`run_closed_devices_parallel`]: every shard
/// finishes with dead-device semantics, and the lowest flat device
/// index's failure is surfaced, identical at every thread count.
pub(crate) fn run_open_devices_parallel(
    cfg: &RunConfig,
    devs: &mut [OpenDevice<'_>],
    threads: usize,
) -> Result<(), DeviceFailure> {
    let threads = shard_count(threads, devs.len());
    if threads <= 1 {
        return run_open_devices(cfg, devs);
    }
    let chunk = devs.len().div_ceil(threads);
    let results: Vec<Result<(), DeviceFailure>> = std::thread::scope(|s| {
        let handles: Vec<_> = devs
            .chunks_mut(chunk)
            .map(|shard| s.spawn(move || run_open_devices(cfg, shard)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("open shard worker panicked")).collect()
    });
    merge_shard_failures(results, chunk)
}

/// One open-loop device: context, engine members, recycled window
/// accumulators.
pub(crate) struct OpenDevice<'a> {
    pub(crate) ctx: DeviceCtx<'a>,
    pub(crate) members: Vec<OpenMember<'a>>,
    wins: Vec<WindowAccum>,
    /// Per-member admission weights, built once from the members'
    /// classes. `None` when no member is classed, so unclassed devices
    /// take the exact pre-class admission path.
    weights: Option<Vec<f64>>,
}

impl<'a> OpenDevice<'a> {
    pub(crate) fn new(ctx: DeviceCtx<'a>, members: Vec<OpenMember<'a>>) -> Self {
        let wins = (0..members.len()).map(|_| WindowAccum::new()).collect();
        let weights = members.iter().any(|m| m.slo_class.is_some()).then(|| {
            members.iter().map(|m| m.slo_class.map_or(1.0, SloClass::shed_weight)).collect()
        });
        OpenDevice { ctx, members, wins, weights }
    }
}

/// Plan one open-loop device's control window: admission, SM shares,
/// slice clamps, and resident-memory telemetry. Split out of
/// [`run_open_devices`] so a planning failure kills just this device
/// (dead-device error semantics) instead of aborting the whole loop —
/// and reused verbatim by the `coordinator::testkit` reference executor
/// so the two executors cannot drift on planning arithmetic.
pub(crate) fn plan_open_device_window(
    dev: &mut OpenDevice<'_>,
) -> Result<(Vec<(u32, u32)>, Vec<SmShare>), DeviceError> {
    let OpenDevice { ctx, members: states, weights, .. } = dev;
    let requested: Vec<(u32, u32)> = states.iter().map(|m| m.policy.operating_point()).collect();
    let mut pts = admit_window(
        &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
        states.len(),
        &requested,
        weights.as_deref(),
        ctx.mem_capacity_mb,
        &mut ctx.admission_clamps,
    )?;
    let g = ctx.perf_fraction;
    let shr = ctx.parts.window_shares(
        || {
            states
                .iter()
                .zip(&pts)
                .map(|(m, &(bs, mtl))| {
                    if g >= 1.0 {
                        m.sim.sm_utilization(bs, mtl)
                    } else {
                        m.sim.sm_utilization_granted(bs, mtl, g)
                    }
                })
                .sum()
        },
        states.len(),
        ctx.perf_fraction,
        &mut ctx.peak_contention,
        &mut ctx.contention_trace,
        &mut ctx.grant_trace,
    )?;
    if let Some(grants) = ctx.grant_trace.last() {
        clamp_to_slice_ceilings(
            ctx.parts.mode(),
            grants,
            ctx.mem_capacity_mb,
            &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
            &mut pts,
            &mut ctx.admission_clamps,
        )?;
    }
    // Peak telemetry from the points that actually serve (the
    // slice clamp may have shrunk them below the admitted ones).
    let resident: f64 = states
        .iter()
        .zip(&pts)
        .map(|(m, &(bs, mtl))| m.sim.mem_demand_mb(bs, mtl))
        .sum();
    ctx.peak_mem_mb = ctx.peak_mem_mb.max(resident);
    Ok((pts, shr))
}

/// Serve every control window of every open-loop device through ONE
/// global event loop: each window, every device runs its admission +
/// SM-share planning, then a single [`EventCalendar`] interleaves ALL
/// members of ALL devices by next-event time (ties break toward the
/// lower flattened index — device order, then member order). Members of
/// different devices never couple (admission and contention are
/// per-device), so the single-device case reproduces the pre-cluster
/// `Fleet` loop bit for bit while a heterogeneous cluster reuses the
/// same engine cores, scratch recycling, and O(log M) scheduling.
///
/// A device that errors (planning or serving) goes dead: its stale
/// calendar entries drain unserved, it is skipped for the rest of the
/// run, and the other devices finish. The failure surfaced at the end
/// is the one with the lowest device index — identical to what the
/// sharded runner reports at any thread count.
pub(crate) fn run_open_devices(
    cfg: &RunConfig,
    devs: &mut [OpenDevice<'_>],
) -> Result<(), DeviceFailure> {
    let mut failed: Vec<Option<DeviceError>> = (0..devs.len()).map(|_| None).collect();
    let total: usize = devs.iter().map(|d| d.members.len()).sum();
    // Flat index = device offset + member index (the calendar's key),
    // with an O(1) flat -> device table for the hot event loop.
    let mut offsets = Vec::with_capacity(devs.len());
    let mut device_of_flat = Vec::with_capacity(total);
    let mut off = 0usize;
    for (d, dev) in devs.iter().enumerate() {
        offsets.push(off);
        off += dev.members.len();
        device_of_flat.resize(off, d);
    }
    let mut calendar = EventCalendar::with_capacity(total);
    let mut remaining = vec![0usize; total];
    // Per-device, per-window plans (points / shares / slos), index-aligned
    // with the device's members and rebuilt every window.
    let mut points: Vec<Vec<(u32, u32)>> = devs.iter().map(|_| Vec::new()).collect();
    let mut shares: Vec<Vec<SmShare>> = devs.iter().map(|_| Vec::new()).collect();
    let mut slos: Vec<Vec<f64>> = devs.iter().map(|_| Vec::new()).collect();

    for w in 0..cfg.windows {
        calendar.clear();
        for (d, dev) in devs.iter_mut().enumerate() {
            if failed[d].is_some() || dev.members.is_empty() {
                continue;
            }
            let (pts, shr) = match plan_open_device_window(dev) {
                Ok(plan) => plan,
                Err(e) => {
                    failed[d] = Some(e);
                    continue;
                }
            };
            let OpenDevice { members: states, wins, .. } = dev;
            let sl: Vec<f64> = states.iter_mut().map(|m| m.schedule.at(w)).collect();
            for (i, (st, win)) in states.iter().zip(wins.iter_mut()).enumerate() {
                win.begin(&st.lp);
                remaining[offsets[d] + i] = cfg.rounds_per_window;
                calendar.push(offsets[d] + i, st.lp.now_s);
            }
            points[d] = pts;
            shares[d] = shr;
            slos[d] = sl;
        }

        // Global event loop: always advance the member whose virtual
        // clock is furthest behind (ties break toward the lower flat
        // index), so batch dispatches happen in global time order
        // across every device. The calendar pops that member in
        // O(log M) — each member is scheduled at most once, keyed at
        // its current clock.
        while let Some(flat) = calendar.pop() {
            let d = device_of_flat[flat];
            // A dead device's members may still hold stale calendar
            // entries from before the failure: drain them unserved.
            if failed[d].is_some() {
                continue;
            }
            let k = flat - offsets[d];
            remaining[flat] -= 1;
            let dev = &mut devs[d];
            let st = &mut dev.members[k];
            match st.lp.serve_round(
                points[d][k],
                slos[d][k],
                shares[d][k],
                &mut st.sim,
                &mut dev.wins[k],
            ) {
                // A member leaves the window's calendar when its round
                // budget is spent — or for good when its finite trace is
                // exhausted and drained (`more == false`).
                Ok(more) => {
                    if more && remaining[flat] > 0 {
                        calendar.push(flat, st.lp.now_s);
                    }
                }
                Err(e) => failed[d] = Some(e),
            }
        }

        for (d, dev) in devs.iter_mut().enumerate() {
            if failed[d].is_some() {
                continue;
            }
            let OpenDevice { ctx, members: states, wins, .. } = dev;
            if states.is_empty() {
                continue;
            }
            let mut window_obs: Vec<WindowObservation> = Vec::with_capacity(states.len());
            for (i, win) in wins.iter_mut().enumerate() {
                let st = &mut states[i];
                st.admitted = points[d][i];
                let (record, obs) = win.finish(w, slos[d][i], points[d][i], &st.lp);
                st.acc.absorb(w, slos[d][i], win.latencies());
                st.latencies.extend(win.latencies().iter().map(|&l| (l, 1.0)));
                st.trace.push(record);
                // As in single-job open-loop serving, instance launches
                // are not charged as a queue-draining stall (existing
                // instances keep serving while a new one spins up).
                st.policy.observe(&obs);
                window_obs.push(obs);
            }
            if let Some(grants) = ctx.grant_trace.last() {
                ctx.parts.maybe_rebalance(&window_obs, grants, &mut ctx.admission_clamps);
            }
        }
    }
    first_device_failure(failed)
}

impl<'a> Fleet<'a> {
    pub fn builder() -> FleetBuilder<'a> {
        FleetBuilder::new()
    }

    /// Serve every member to completion on the shared GPU.
    pub fn run(self) -> Result<FleetOutcome, DeviceError> {
        // The builder guarantees the modes are not mixed.
        if self.members.iter().all(|m| m.arrivals.is_closed()) {
            self.run_closed()
        } else {
            self.run_open()
        }
    }

    /// Closed-loop lockstep windows — byte-identical to the pre-engine
    /// `Fleet` (same device-RNG consumption order, same accounting) in
    /// `TimeShare` mode; spatial modes swap the contention factor for
    /// per-member SM grants.
    fn run_closed(self) -> Result<FleetOutcome, DeviceError> {
        let Fleet { gpu, cfg, seed, members, partition, partition_policy } = self;
        let parts = Partitioner::new(partition, &members, partition_policy, gpu.mem_mb);
        let mut states: Vec<Member<'a>> = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            states.push(new_closed_member(m, &cfg, seed + i as u64)?);
        }
        let mut devs = [ClosedDevice {
            ctx: DeviceCtx::new(gpu.mem_mb, 1.0, parts, cfg.windows),
            members: states,
        }];
        run_closed_devices(&cfg, &mut devs).map_err(|f| f.error)?;
        let [dev] = devs;
        let outcomes = dev.members.into_iter().map(closed_member_outcome).collect();
        Ok(finish_fleet(outcomes, dev.ctx, partition))
    }

    /// Open-loop fleet: one engine core per member, one global event loop
    /// interleaving batch rounds by next-event time. Admission and
    /// SM-contention are still recomputed per lockstep control window —
    /// the same coupling the closed loop applies — but inside a window
    /// members serve in virtual-time order, each against its own arrival
    /// stream and queue. Spatial partition modes replace the shared
    /// contention factor with per-member SM grants, so a bursty member
    /// can only slow itself down.
    fn run_open(self) -> Result<FleetOutcome, DeviceError> {
        let Fleet { gpu, cfg, seed, members, partition, partition_policy } = self;
        let parts = Partitioner::new(partition, &members, partition_policy, gpu.mem_mb);
        let mut states: Vec<OpenMember<'a>> = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            states.push(new_open_member(m, &cfg, seed + i as u64, arrival_seed(seed, i))?);
        }
        let mut devs =
            [OpenDevice::new(DeviceCtx::new(gpu.mem_mb, 1.0, parts, cfg.windows), states)];
        run_open_devices(&cfg, &mut devs).map_err(|f| f.error)?;
        let [dev] = devs;
        let outcomes = dev.members.into_iter().map(open_member_outcome).collect();
        Ok(finish_fleet(outcomes, dev.ctx, partition))
    }
}

/// Fold per-member outcomes + device telemetry into the fleet result.
pub(crate) fn finish_fleet(
    members: Vec<JobOutcome>,
    ctx: DeviceCtx<'_>,
    partition: PartitionMode,
) -> FleetOutcome {
    let total_throughput = members.iter().map(|o| o.throughput).sum();
    let total_goodput = members.iter().map(|o| o.goodput).sum();
    let slo = SloReport::from_members(
        members.iter().map(|o| (o.slo_class, o.goodput, o.dropped_deadline)),
    );
    FleetOutcome {
        members,
        total_throughput,
        total_goodput,
        slo,
        peak_mem_mb: ctx.peak_mem_mb,
        mem_capacity_mb: ctx.mem_capacity_mb,
        peak_contention: ctx.peak_contention,
        contention_trace: ctx.contention_trace,
        admission_clamps: ctx.admission_clamps,
        partition,
        grant_trace: ctx.grant_trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;

    #[test]
    fn device_state_is_send_for_shard_workers() {
        // The parallel runners move whole ClosedDevice / OpenDevice values
        // (boxed policies, partitioners, arrival generators and all) onto
        // scoped worker threads. Keep that a compile-time guarantee.
        fn assert_send<T: Send>() {}
        assert_send::<ClosedDevice<'static>>();
        assert_send::<OpenDevice<'static>>();
    }

    #[test]
    fn shard_count_clamps_to_device_count() {
        assert_eq!(shard_count(0, 5), 1);
        assert_eq!(shard_count(1, 5), 1);
        assert_eq!(shard_count(3, 5), 3);
        assert_eq!(shard_count(8, 5), 5);
        assert_eq!(shard_count(4, 0), 1);
    }

    #[test]
    fn builder_rejects_empty_fleet_and_unknown_dnn() {
        assert_eq!(Fleet::builder().build().err(), Some(ConfigError::NoFleetMembers));
        let mut bogus = *paper_job(1).unwrap();
        bogus.dnn = "vgg16";
        assert_eq!(
            Fleet::builder().job(&bogus, PolicySpec::Clipper).build().err(),
            Some(ConfigError::UnknownDnn { dnn: "vgg16".into() })
        );
        assert_eq!(
            Fleet::builder()
                .windows(0)
                .job(paper_job(1).unwrap(), PolicySpec::Clipper)
                .build()
                .err(),
            Some(ConfigError::ZeroWindows)
        );
    }

    #[test]
    fn builder_rejects_mixed_modes_and_misplaced_knobs() {
        let job = paper_job(1).unwrap();
        assert_eq!(
            Fleet::builder()
                .job(job, PolicySpec::Clipper)
                .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(20.0))
                .build()
                .err(),
            Some(ConfigError::MixedArrivalModes)
        );
        assert_eq!(
            Fleet::builder().queue_capacity(8).job(job, PolicySpec::Clipper).build().err(),
            Some(ConfigError::MemberKnobBeforeJob { knob: "queue_capacity" })
        );
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).shed_deadline(true).build().err(),
            Some(ConfigError::ShedRequiresOpenLoop)
        );
        // Queueing knobs on a closed-loop member are rejected, not
        // silently ignored (a closed loop has no queue).
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).queue_capacity(64).build().err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "queue_capacity" })
        );
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).batch_timeout_ms(2.0).build().err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "batch_timeout_ms" })
        );
        assert_eq!(
            Fleet::builder()
                .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(0.0))
                .build()
                .err(),
            Some(ConfigError::BadArrivalRate { rate: 0.0 })
        );
        assert_eq!(
            Fleet::builder()
                .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(20.0))
                .queue_capacity(0)
                .build()
                .err(),
            Some(ConfigError::ZeroQueueCapacity)
        );
    }

    #[test]
    fn builder_rejects_misplaced_slo_knobs() {
        let job = paper_job(1).unwrap();
        let open = || ArrivalPattern::poisson(20.0);
        // SLO-class knobs are open-loop machinery: refused on closed
        // members, not silently ignored.
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).slo_class(SloClass::Gold).build().err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "slo_class" })
        );
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).deadline_ms(40.0).build().err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "deadline_ms" })
        );
        // An explicit deadline without shedding would be a silent no-op.
        assert_eq!(
            Fleet::builder()
                .job_with_arrivals(job, PolicySpec::Clipper, open())
                .deadline_ms(40.0)
                .build()
                .err(),
            Some(ConfigError::DeadlineRequiresShed)
        );
        // Deadline shape is validated before anything else about it.
        for bad in [f64::NAN, 0.0, -5.0] {
            assert_eq!(
                Fleet::builder()
                    .job_with_arrivals(job, PolicySpec::Clipper, open())
                    .shed_deadline(true)
                    .deadline_ms(bad)
                    .build()
                    .err()
                    .map(|e| matches!(e, ConfigError::BadDeadline { .. })),
                Some(true),
                "deadline_ms {bad} must be rejected"
            );
        }
        // The usual member-knob placement rule applies.
        assert_eq!(
            Fleet::builder().slo_class(SloClass::Silver).job(job, PolicySpec::Clipper).build().err(),
            Some(ConfigError::MemberKnobBeforeJob { knob: "slo_class" })
        );
        // The whole-list form shares expand_member_list's count/conflict
        // rules with sm_reservations.
        assert_eq!(
            Fleet::builder()
                .job_with_arrivals(job, PolicySpec::Clipper, open())
                .slo_classes(&[SloClass::Gold, SloClass::BestEffort])
                .build()
                .err(),
            Some(ConfigError::ListCountMismatch { knob: "slo_classes", got: 2, members: 1 })
        );
        assert_eq!(
            Fleet::builder()
                .job_with_arrivals(job, PolicySpec::Clipper, open())
                .slo_class(SloClass::Gold)
                .slo_classes(&[SloClass::Silver])
                .build()
                .err(),
            Some(ConfigError::ListOverridesMemberKnob {
                list: "slo_classes",
                knob: "slo_class"
            })
        );
        // A classed, shedding, explicitly-deadlined open member builds.
        assert!(Fleet::builder()
            .job_with_arrivals(job, PolicySpec::Clipper, open())
            .shed_deadline(true)
            .deadline_ms(40.0)
            .slo_class(SloClass::BestEffort)
            .build()
            .is_ok());
    }

    #[test]
    fn weighted_admission_shrinks_the_lowest_class_first() {
        // Synthetic demand: each (bs, mtl) unit costs 100 MB, so the
        // victim choice is fully visible. Capacity 500 forces exactly
        // one shrink of the 600 MB request.
        let demand = |_i: usize, (bs, mtl): (u32, u32)| (bs * mtl) as f64 * 100.0;
        let requested = [(4, 1), (2, 1)];
        // Unweighted: the greediest member (0) gives back memory.
        let mut clamps = 0u64;
        let pts = admit_window(&demand, 2, &requested, None, 500.0, &mut clamps).unwrap();
        assert_eq!(pts, vec![(2, 1), (2, 1)]);
        assert_eq!(clamps, 1);
        // Gold vs best-effort: the best-effort member shrinks first even
        // though the gold member is greedier.
        let w = [SloClass::Gold.shed_weight(), SloClass::BestEffort.shed_weight()];
        let mut clamps = 0u64;
        let pts = admit_window(&demand, 2, &requested, Some(&w), 500.0, &mut clamps).unwrap();
        assert_eq!(pts, vec![(4, 1), (1, 1)]);
        assert_eq!(clamps, 1);
        // Once best-effort is exhausted at (1, 1), gold does shrink —
        // classes prioritize, they never deadlock admission.
        let mut clamps = 0u64;
        let pts = admit_window(&demand, 2, &requested, Some(&w), 300.0, &mut clamps).unwrap();
        assert_eq!(pts, vec![(2, 1), (1, 1)]);
        assert_eq!(clamps, 2);
        // Equal weights restrict nothing: identical to the unweighted rule.
        let eq = [8.0, 8.0];
        let mut clamps = 0u64;
        let pts = admit_window(&demand, 2, &requested, Some(&eq), 500.0, &mut clamps).unwrap();
        assert_eq!(pts, vec![(2, 1), (2, 1)]);
    }

    #[test]
    fn classed_fleet_reports_per_class_accounting() {
        let job = paper_job(1).unwrap();
        let build = |classed: bool| {
            let mut b = Fleet::builder().windows(6).rounds_per_window(6).seed(9);
            for _ in 0..2 {
                b = b
                    .job_with_arrivals(
                        job,
                        PolicySpec::Static { bs: 1, mtl: 2 },
                        ArrivalPattern::poisson(60.0),
                    )
                    .shed_deadline(true);
            }
            if classed {
                b = b.slo_classes(&[SloClass::Gold, SloClass::BestEffort]);
            }
            b.build().unwrap().run().unwrap()
        };
        let plain = build(false);
        assert!(plain.slo.is_none(), "unclassed outcome must carry no slo report");
        assert!(plain.members.iter().all(|m| m.slo_class.is_none()));
        let classed = build(true);
        let report = classed.slo.as_ref().expect("classed outcome must carry the report");
        assert_eq!(report.class(SloClass::Gold).members, 1);
        assert_eq!(report.class(SloClass::BestEffort).members, 1);
        assert_eq!(report.class(SloClass::Silver).members, 0);
        assert_eq!(classed.members[0].slo_class, Some(SloClass::Gold));
        assert_eq!(classed.members[1].slo_class, Some(SloClass::BestEffort));
        let gold_goodput: f64 = classed
            .members
            .iter()
            .filter(|m| m.slo_class == Some(SloClass::Gold))
            .map(|m| m.goodput)
            .sum();
        assert_eq!(report.class(SloClass::Gold).goodput, gold_goodput);
    }

    #[test]
    fn builder_rejects_partition_misconfiguration() {
        use crate::gpusim::PartitionError;
        let job = paper_job(1).unwrap();
        // Partition knobs on a TimeShare fleet are refused, not ignored.
        assert_eq!(
            Fleet::builder().job(job, PolicySpec::Clipper).sm_reservation(0.5).build().err(),
            Some(ConfigError::KnobRequiresPartition { knob: "sm_reservation" })
        );
        assert_eq!(
            Fleet::builder()
                .job(job, PolicySpec::Clipper)
                .partition_policy(crate::coordinator::policy::DemandPartition::new())
                .build()
                .err(),
            Some(ConfigError::KnobRequiresPartition { knob: "partition_policy" })
        );
        // Over-subscription and invalid fractions are typed errors.
        assert!(matches!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .job(job, PolicySpec::Clipper)
                .sm_reservation(0.8)
                .job(job, PolicySpec::Clipper)
                .sm_reservation(0.8)
                .build()
                .err(),
            Some(ConfigError::BadPartition(PartitionError::Oversubscribed { .. }))
        ));
        assert!(matches!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .job(job, PolicySpec::Clipper)
                .sm_reservation(-0.25)
                .build()
                .err(),
            Some(ConfigError::BadPartition(PartitionError::BadReservation { .. }))
        ));
        // A sub-slice MIG reservation cannot be granted.
        assert!(matches!(
            Fleet::builder()
                .partition_mode(PartitionMode::MigSlices { slices: 7 })
                .job(job, PolicySpec::Clipper)
                .sm_reservation(0.05)
                .build()
                .err(),
            Some(ConfigError::BadPartition(PartitionError::BelowSliceFloor { .. }))
        ));
        // A reservation before any member is the usual knob error.
        assert_eq!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .sm_reservation(0.5)
                .job(job, PolicySpec::Clipper)
                .build()
                .err(),
            Some(ConfigError::MemberKnobBeforeJob { knob: "sm_reservation" })
        );
    }

    #[test]
    fn reservation_list_count_is_checked_not_truncated() {
        // The PR 5 bugfix: a reservation list longer (or shorter, when
        // not 1) than the member count is a typed error, never silently
        // truncated or ignored.
        let job = paper_job(1).unwrap();
        assert_eq!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .job(job, PolicySpec::Clipper)
                .job(job, PolicySpec::Clipper)
                .sm_reservations(&[0.3, 0.3, 0.3])
                .build()
                .err(),
            Some(ConfigError::ListCountMismatch {
                knob: "sm_reservations",
                got: 3,
                members: 2
            })
        );
        // One value broadcasts; one per member assigns in order.
        let out = Fleet::builder()
            .windows(2)
            .rounds_per_window(2)
            .partition_mode(PartitionMode::Mps)
            .job(job, PolicySpec::Static { bs: 1, mtl: 1 })
            .job(job, PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservations(&[0.7, 0.3])
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!((out.grant_trace[0][0] - 0.7).abs() < 1e-12);
        assert!((out.grant_trace[0][1] - 0.3).abs() < 1e-12);
        let out = Fleet::builder()
            .windows(2)
            .rounds_per_window(2)
            .partition_mode(PartitionMode::Mps)
            .job(job, PolicySpec::Static { bs: 1, mtl: 1 })
            .job(job, PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservations(&[0.4])
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!((out.grant_trace[0][0] - 0.4).abs() < 1e-12);
        assert!((out.grant_trace[0][1] - 0.4).abs() < 1e-12);
        // The broadcast still goes through the partition planner: a
        // broadcast that over-subscribes is the usual typed error.
        assert!(matches!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .job(job, PolicySpec::Clipper)
                .job(job, PolicySpec::Clipper)
                .sm_reservations(&[0.8])
                .build()
                .err(),
            Some(ConfigError::BadPartition(_))
        ));
        // Mixing the whole-list form with a per-member reservation would
        // silently overwrite the latter — refused, not applied.
        assert_eq!(
            Fleet::builder()
                .partition_mode(PartitionMode::Mps)
                .job(job, PolicySpec::Clipper)
                .sm_reservation(0.5)
                .job(job, PolicySpec::Clipper)
                .sm_reservations(&[0.2, 0.2])
                .build()
                .err(),
            Some(ConfigError::ListOverridesMemberKnob {
                list: "sm_reservations",
                knob: "sm_reservation"
            })
        );
    }

    #[test]
    fn mig_memory_ceiling_rejects_oversized_models_at_build() {
        use crate::gpusim::PartitionError;
        // inc-v4's bare footprint is ~1.4 GB; a quarter slice of a 4 GB
        // device holds 1 GB. The builder must refuse the configuration
        // with the typed memory error, not let serving OOM later.
        let small = GpuSpec { mem_mb: 4096.0, ..TESLA_P40 };
        let err = Fleet::builder()
            .gpu(small)
            .partition_mode(PartitionMode::MigSlices { slices: 4 })
            .job(paper_job(3).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.25)
            .job(paper_job(5).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .build()
            .err();
        assert!(
            matches!(err, Some(ConfigError::BadPartition(PartitionError::MemoryExceeded {
                index: 0, ..
            }))),
            "{err:?}"
        );
        // The same jobs fit whole-device MIG slices of the real P40.
        assert!(Fleet::builder()
            .partition_mode(PartitionMode::MigSlices { slices: 4 })
            .job(paper_job(3).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.25)
            .job(paper_job(5).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .build()
            .is_ok());
    }

    #[test]
    fn mig_memory_ceiling_clamps_the_operating_point_per_window() {
        // nas-large at (16, 8) demands ~18.8 GB — fine for the whole
        // 24 GB card (no global clamp) but far over the 12.3 GB ceiling
        // of its 1-of-2 MIG slice: the slice admission must shrink the
        // point (batch halved first, then instances shed) and count
        // every step.
        let out = Fleet::builder()
            .windows(4)
            .rounds_per_window(4)
            .seed(3)
            .partition_mode(PartitionMode::MigSlices { slices: 2 })
            .job(paper_job(7).unwrap(), PolicySpec::Static { bs: 16, mtl: 8 })
            .job(paper_job(5).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(out.admission_clamps > 0, "slice ceiling never clamped");
        let big = &out.members[0];
        let slice_ceiling = 0.5 * out.mem_capacity_mb;
        let sim = GpuSim::for_paper_dnn("nas-large", paper_job(7).unwrap().dataset, 0).unwrap();
        let admitted_demand =
            sim.mem_demand_mb(big.steady_bs, big.steady_mtl);
        assert!(
            admitted_demand <= slice_ceiling,
            "admitted point {}x{} demands {admitted_demand:.0} MB > slice ceiling \
             {slice_ceiling:.0} MB",
            big.steady_bs,
            big.steady_mtl
        );
        assert!(
            (big.steady_bs, big.steady_mtl) < (16, 8),
            "requested point served unshrunk"
        );
        // Peak-memory telemetry reflects the demand that actually
        // served (post-clamp ~12.5 GB), not the admitted-then-clamped
        // ~18.8 GB request that was never resident.
        assert!(
            out.peak_mem_mb > 0.0 && out.peak_mem_mb < 13_000.0,
            "peak mem {:.0} MB reports a pre-clamp demand",
            out.peak_mem_mb
        );
    }

    #[test]
    fn mps_fleet_records_grants_and_never_oversubscribes() {
        let out = Fleet::builder()
            .windows(8)
            .rounds_per_window(6)
            .seed(5)
            .partition_mode(PartitionMode::Mps)
            .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 1, mtl: 2 })
            .sm_reservation(0.6)
            .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 1, mtl: 2 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.partition, PartitionMode::Mps);
        assert_eq!(out.grant_trace.len(), 8);
        for grants in &out.grant_trace {
            assert_eq!(grants.len(), 2);
            assert!((grants[0] - 0.6).abs() < 1e-12, "explicit reservation granted verbatim");
            assert!((grants[1] - 0.4).abs() < 1e-12, "default member gets the remainder");
            assert!(grants.iter().sum::<f64>() <= 1.0 + 1e-9);
        }
        // In spatial mode the contention trace is the granted total: <= 1.
        assert!(out.contention_trace.iter().all(|&c| c <= 1.0 + 1e-9));
        assert!(out.peak_contention <= 1.0 + 1e-9);
        for m in &out.members {
            assert!(m.throughput > 0.0);
        }
    }

    #[test]
    fn mig_fleet_quantizes_grants_to_slices() {
        let out = Fleet::builder()
            .windows(4)
            .rounds_per_window(4)
            .seed(5)
            .partition_mode(PartitionMode::MigSlices { slices: 7 })
            .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.5)
            .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.4)
            .build()
            .unwrap()
            .run()
            .unwrap();
        for grants in &out.grant_trace {
            // 0.5 -> 3/7, 0.4 -> 2/7 (rounded DOWN; 2/7 stays unused).
            assert!((grants[0] - 3.0 / 7.0).abs() < 1e-12);
            assert!((grants[1] - 2.0 / 7.0).abs() < 1e-12);
        }
        assert!(out.peak_contention < 1.0);
    }

    #[test]
    fn timeshare_fleet_reports_no_grant_trace() {
        let out = Fleet::builder()
            .windows(4)
            .rounds_per_window(4)
            .seed(5)
            .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 1, mtl: 2 })
            .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 1, mtl: 2 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.partition, PartitionMode::TimeShare);
        assert!(out.grant_trace.is_empty());
    }

    #[test]
    fn hostile_partition_policies_are_sanitized_not_trusted() {
        use crate::coordinator::policy::PartitionPolicy;

        /// Returns a fixed proposal every window, however malformed.
        struct FixedProposal(Vec<f64>);
        impl PartitionPolicy for FixedProposal {
            fn name(&self) -> &'static str {
                "fixed"
            }
            fn rebalance(&mut self, _: &[WindowObservation], _: &[f64]) -> Option<Vec<f64>> {
                Some(self.0.clone())
            }
        }

        let run = |proposal: Vec<f64>, mode: PartitionMode| {
            Fleet::builder()
                .windows(6)
                .rounds_per_window(4)
                .seed(2)
                .partition_mode(mode)
                .partition_policy(FixedProposal(proposal))
                .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
                .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
                .build()
                .unwrap()
                .run()
                .unwrap()
        };

        // Wrong length: rejected every window (counted as clamps), the
        // fleet keeps serving on the original equal split — no panic.
        for bad in [vec![1.0], vec![0.3, 0.3, 0.3], vec![f64::NAN, 0.5]] {
            let out = run(bad, PartitionMode::Mps);
            assert!(out.admission_clamps >= 5, "rejections must be counted");
            for grants in &out.grant_trace {
                assert_eq!(grants.len(), 2);
                assert!((grants[0] - 0.5).abs() < 1e-12, "reservations must be untouched");
            }
        }
        // Over-subscription: also rejected, never granted.
        let out = run(vec![0.9, 0.9], PartitionMode::Mps);
        assert!(out.admission_clamps >= 5);
        assert!(out.contention_trace.iter().all(|&c| c <= 1.0 + 1e-9));

        // A proposal nudging a member below one MIG slice is lifted to
        // the slice floor and accepted — not rejected forever (the
        // rebalance-deadlock regression).
        let out = run(vec![0.8, 0.1], PartitionMode::MigSlices { slices: 7 });
        assert_eq!(out.admission_clamps, 0, "clamped proposal must be grantable");
        let last = out.grant_trace.last().unwrap();
        assert!((last[0] - 5.0 / 7.0).abs() < 1e-12, "0.8 quantizes to 5 slices");
        assert!((last[1] - 1.0 / 7.0).abs() < 1e-12, "0.1 is lifted to one slice");
    }

    #[test]
    fn rebalance_cannot_shrink_a_slice_below_a_model_footprint() {
        use crate::coordinator::policy::PartitionPolicy;

        /// Proposes swapping the two members' slice counts every window.
        struct Swap;
        impl PartitionPolicy for Swap {
            fn name(&self) -> &'static str {
                "swap"
            }
            fn rebalance(&mut self, _: &[WindowObservation], _: &[f64]) -> Option<Vec<f64>> {
                Some(vec![0.25, 0.5])
            }
        }

        // 4 GB card in 4 MIG slices: inc-v4's ~1.4 GB footprint needs 2
        // slices (2 GB ceiling); the swap proposal would leave it 1
        // slice (1 GB) — SM-valid, memory-impossible. It must be
        // rejected and counted, never accepted to OOM the next window.
        let gpu = GpuSpec { mem_mb: 4096.0, ..TESLA_P40 };
        let out = Fleet::builder()
            .gpu(gpu)
            .windows(6)
            .rounds_per_window(4)
            .seed(2)
            .partition_mode(PartitionMode::MigSlices { slices: 4 })
            .partition_policy(Swap)
            .job(paper_job(3).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.5)
            .job(paper_job(5).unwrap(), PolicySpec::Static { bs: 1, mtl: 1 })
            .sm_reservation(0.25)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(out.admission_clamps >= 5, "memory-impossible rebalances must be counted");
        for grants in &out.grant_trace {
            assert!((grants[0] - 0.5).abs() < 1e-12, "inc-v4 must keep its 2 slices");
        }
    }

    #[test]
    fn partition_policy_rebalances_toward_the_loaded_member() {
        use crate::coordinator::policy::DemandPartition;
        // Open-loop MPS fleet: member 0 is overloaded, member 1 idle; the
        // demand rebalancer must shift SM share toward member 0.
        let out = Fleet::builder()
            .windows(16)
            .rounds_per_window(12)
            .seed(3)
            .partition_mode(PartitionMode::Mps)
            .partition_policy(DemandPartition::new())
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(150.0),
            )
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(2.0),
            )
            .build()
            .unwrap()
            .run()
            .unwrap();
        let first = &out.grant_trace[0];
        let last = out.grant_trace.last().unwrap();
        assert!((first[0] - 0.5).abs() < 1e-12, "no reservations -> equal split at w0");
        assert!(
            last[0] > first[0] + 0.05,
            "loaded member's grant never grew: {:.3} -> {:.3}",
            first[0],
            last[0]
        );
        for grants in &out.grant_trace {
            assert!(grants.iter().sum::<f64>() <= 1.0 + 1e-9, "rebalance over-subscribed");
            assert!(grants.iter().all(|&g| g > 0.0));
        }
    }

    #[test]
    fn two_member_fleet_shares_the_gpu() {
        let out = Fleet::builder()
            .windows(16)
            .rounds_per_window(10)
            .seed(11)
            .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
            .job(paper_job(4).unwrap(), PolicySpec::DnnScaler)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.members.len(), 2);
        for m in &out.members {
            assert!(m.throughput > 0.0, "{}: zero throughput", m.dnn);
            assert!((0.0..=1.0).contains(&m.slo_attainment));
            assert_eq!(m.trace.len(), 16);
        }
        assert!(out.peak_mem_mb <= out.mem_capacity_mb);
        assert!(out.peak_mem_mb > 0.0);
        assert!(out.total_throughput > 0.0);
        // Two MT-class jobs at their seeded instance counts must actually
        // contend for SMs (factor > 1 => time-sharing kicked in).
        assert!(out.peak_contention > 1.0, "contention {}", out.peak_contention);
        // The per-window contention trace records the same peak.
        assert_eq!(out.contention_trace.len(), 16);
        let trace_peak = out.contention_trace.iter().cloned().fold(0.0, f64::max);
        assert_eq!(trace_peak, out.peak_contention);
    }

    #[test]
    fn static_members_are_admission_checked() {
        // Two members asking for preposterous static points must be
        // shrunk by admission control rather than OOMing the shared GPU,
        // and the reported steady point must be the *admitted* one, not
        // the policy's request.
        let out = Fleet::builder()
            .windows(4)
            .rounds_per_window(4)
            .seed(3)
            .job(paper_job(7).unwrap(), PolicySpec::Static { bs: 128, mtl: 10 })
            .job(paper_job(3).unwrap(), PolicySpec::Static { bs: 128, mtl: 10 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(out.admission_clamps > 0, "admission must have intervened");
        assert!(out.peak_mem_mb <= out.mem_capacity_mb);
        for m in &out.members {
            assert!(m.throughput > 0.0);
            // 2x (128, 10) demands ~85 GB on a 24 GB card: both members
            // must have been shrunk, and the outcome must say so.
            assert!(
                m.steady_bs < 128,
                "{}: steady bs {} reports the request, not the admitted point",
                m.dnn,
                m.steady_bs
            );
            let last = m.trace.last().unwrap();
            assert_eq!((last.bs, last.mtl), (m.steady_bs, m.steady_mtl));
        }
    }

    #[test]
    fn open_fleet_members_follow_their_own_arrival_rates() {
        // Two identical jobs, one offered 4x the load of the other: with
        // ample capacity each member's throughput must track ITS offered
        // rate — the thing lockstep closed-loop fleets cannot express.
        let out = Fleet::builder()
            .windows(12)
            .rounds_per_window(20)
            .seed(9)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(10.0),
            )
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(40.0),
            )
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.members.len(), 2);
        let slow = &out.members[0];
        let fast = &out.members[1];
        // NB: `arrived` counts are NOT comparable across members — each
        // member's count tracks its own virtual-clock horizon, and the
        // lightly loaded member's clock (which waits on arrivals) runs
        // far ahead. The per-window arrival-rate telemetry below is the
        // meaningful per-member load signal.
        assert!(slow.arrived > 0 && fast.arrived > 0);
        assert_eq!(slow.drops + fast.drops, 0, "unbounded queues never drop");
        assert!(
            fast.throughput > 2.0 * slow.throughput,
            "fast {:.1} inf/s must dwarf slow {:.1} inf/s",
            fast.throughput,
            slow.throughput
        );
        // Arrival telemetry is per member now: the fast member's windows
        // see the high offered rate, and on average 4x the slow one's.
        assert!(fast.trace.iter().any(|r| r.arrival_rate > 20.0));
        let mean_rate = |t: &[WindowRecord]| {
            t.iter().map(|r| r.arrival_rate).sum::<f64>() / t.len() as f64
        };
        assert!(mean_rate(&fast.trace) > 2.0 * mean_rate(&slow.trace));
        assert!(out.total_goodput > 0.0);
    }

    /// One single-member closed-loop device with the given admission
    /// capacity. A few MB of capacity cannot hold any model at (1, 1) —
    /// `admit_window` has nothing left to shrink and OOMs at the
    /// device's first window; a P40-sized capacity serves normally.
    /// Distinct jobs and capacities give each failing device a distinct
    /// error string, so the assertions below can tell WHOSE error
    /// surfaced, not just that one did.
    fn oom_probe_closed(
        paper_id: u32,
        capacity_mb: f64,
        cfg: &RunConfig,
        seed: u64,
    ) -> ClosedDevice<'static> {
        let m = MemberCfg::new(
            paper_job(paper_id).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::closed(),
        );
        ClosedDevice {
            ctx: DeviceCtx::new(capacity_mb, 1.0, Partitioner::timeshare(1), cfg.windows),
            members: vec![new_closed_member(m, cfg, seed).unwrap()],
        }
    }

    /// Open-loop sibling of [`oom_probe_closed`].
    fn oom_probe_open(
        paper_id: u32,
        capacity_mb: f64,
        cfg: &RunConfig,
        seed: u64,
    ) -> OpenDevice<'static> {
        let m = MemberCfg::new(
            paper_job(paper_id).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(40.0),
        );
        OpenDevice::new(
            DeviceCtx::new(capacity_mb, 1.0, Partitioner::timeshare(1), cfg.windows),
            vec![new_open_member(m, cfg, seed, arrival_seed(seed, 0)).unwrap()],
        )
    }

    #[test]
    fn closed_err_runs_surface_the_lowest_device_at_every_thread_count() {
        // Regression (ISSUE 8 satellite): the sharded runners used to
        // surface whichever shard's error was collected first, so the
        // reported failure depended on the thread count. Devices 1 and 2
        // both OOM (with DISTINCT errors); device 0 is healthy. Serial
        // and parallel runs at threads 1, 2 and 8 must all report device
        // 1's own error.
        let cfg = RunConfig::windows(3, 2);
        let alone = run_closed_devices(&cfg, &mut [oom_probe_closed(3, 1.0, &cfg, 7)])
            .expect_err("a few-MB device must OOM");
        assert_eq!(alone.device, 0);

        let run = |threads: Option<usize>| {
            let mut devs = vec![
                oom_probe_closed(1, TESLA_P40.mem_mb, &cfg, 7),
                oom_probe_closed(3, 1.0, &cfg, 7),
                oom_probe_closed(5, 2.0, &cfg, 7),
            ];
            let f = match threads {
                None => run_closed_devices(&cfg, &mut devs),
                Some(t) => run_closed_devices_parallel(&cfg, &mut devs, t),
            }
            .expect_err("two of three devices must OOM");
            (f.device, f.error.to_string())
        };
        let serial = run(None);
        assert_eq!(serial.0, 1, "lowest failing device must surface");
        assert_eq!(serial.1, alone.error.to_string(), "device 1's OWN error must surface");
        for t in [1, 2, 8] {
            assert_eq!(run(Some(t)), serial, "threads={t} drifted from the serial report");
        }
    }

    #[test]
    fn closed_err_runs_rebase_shard_local_indices() {
        // Devices 0 and 2 fail around a healthy device 1. At threads=2
        // the shards are {0, 1} and {2}: BOTH report a failure, and the
        // merge must rebase shard 1's local index 0 to flat index 2,
        // then still pick flat device 0.
        let cfg = RunConfig::windows(3, 2);
        let run = |threads: Option<usize>| {
            let mut devs = vec![
                oom_probe_closed(3, 1.0, &cfg, 7),
                oom_probe_closed(1, TESLA_P40.mem_mb, &cfg, 7),
                oom_probe_closed(5, 2.0, &cfg, 7),
            ];
            let f = match threads {
                None => run_closed_devices(&cfg, &mut devs),
                Some(t) => run_closed_devices_parallel(&cfg, &mut devs, t),
            }
            .expect_err("two of three devices must OOM");
            (f.device, f.error.to_string())
        };
        let serial = run(None);
        assert_eq!(serial.0, 0);
        for t in [1, 2, 8] {
            assert_eq!(run(Some(t)), serial, "threads={t} drifted from the serial report");
        }
    }

    #[test]
    fn open_err_runs_surface_the_lowest_device_at_every_thread_count() {
        // Same regression on the open-loop path: the global calendar
        // (serial) and the per-shard calendars (parallel) must surface
        // the identical lowest-device failure at threads 1, 2 and 8.
        let cfg = RunConfig::windows(3, 4);
        let alone = run_open_devices(&cfg, &mut [oom_probe_open(3, 1.0, &cfg, 7)])
            .expect_err("a few-MB device must OOM");
        assert_eq!(alone.device, 0);

        let run = |threads: Option<usize>| {
            let mut devs = vec![
                oom_probe_open(1, TESLA_P40.mem_mb, &cfg, 7),
                oom_probe_open(3, 1.0, &cfg, 7),
                oom_probe_open(5, 2.0, &cfg, 7),
            ];
            let f = match threads {
                None => run_open_devices(&cfg, &mut devs),
                Some(t) => run_open_devices_parallel(&cfg, &mut devs, t),
            }
            .expect_err("two of three devices must OOM");
            (f.device, f.error.to_string())
        };
        let serial = run(None);
        assert_eq!(serial.0, 1, "lowest failing device must surface");
        assert_eq!(serial.1, alone.error.to_string(), "device 1's OWN error must surface");
        for t in [1, 2, 8] {
            assert_eq!(run(Some(t)), serial, "threads={t} drifted from the serial report");
        }
    }
}
