//! The controller abstraction shared by DNNScaler's scalers and Clipper.
//!
//! A controller sees only windowed p95 latencies and emits operating-point
//! decisions; the runner applies them against whatever device is in use.


/// Which throughput-improvement approach a DNN gets (paper Eq. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Batching,
    MultiTenancy,
}

impl Method {
    pub fn short(&self) -> &'static str {
        match self {
            Method::Batching => "B",
            Method::MultiTenancy => "MT",
        }
    }
}

/// A controller decision after observing one latency window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// Batch size to use next window.
    pub bs: u32,
    /// Number of co-located instances to use next window.
    pub mtl: u32,
    /// Whether the operating point changed (drives launch/terminate
    /// overhead accounting for MT).
    pub changed: bool,
}

/// Latency-window driven knob controller.
pub trait Controller {
    /// Human-readable name for traces/reports.
    fn name(&self) -> &'static str;

    /// Current operating point `(bs, mtl)`.
    fn operating_point(&self) -> (u32, u32);

    /// Observe the p95 of the last window against the (possibly updated)
    /// SLO and decide the next operating point.
    fn observe_window(&mut self, p95_ms: f64, slo_ms: f64) -> Decision;
}

/// Forwarding impl so a `&mut dyn Controller` borrow plugs into the
/// `AsPolicy` adapter without reboxing.
impl<C: Controller + ?Sized> Controller for &mut C {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn operating_point(&self) -> (u32, u32) {
        (**self).operating_point()
    }

    fn observe_window(&mut self, p95_ms: f64, slo_ms: f64) -> Decision {
        (**self).observe_window(p95_ms, slo_ms)
    }
}
