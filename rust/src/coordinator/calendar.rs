//! O(log M) fleet event calendar.
//!
//! The open-loop `Fleet` interleaves its members' batch rounds by
//! next-event time: every step serves the member whose virtual clock is
//! furthest behind. Through PR 3 that pick was a linear scan over all M
//! members — O(M) per step, O(M) steps per window round-robin, so a
//! 256-member fleet paid ~256x more scheduler work per dispatched batch
//! than a single job. This module replaces the scan with a binary-heap
//! calendar keyed by `(next_event_time, member_index)`: push and pop are
//! O(log M), and for finite clocks — every well-formed run; a clock is
//! virtual time — the pick order is **exactly** the scan's: earliest
//! time first, ties broken toward the lower member index. The one
//! intentional divergence is a NaN clock (a device bug upstream): the
//! scan's strict `<` let a NaN member at the lowest index monopolize
//! the pick, while `total_cmp` orders NaN after every finite time.
//!
//! [`LinearScan`] is the pre-calendar implementation, retained behind
//! the same [`NextEventQueue`] interface as the reference for
//! differential tests (same pick order under ties/exhaustion, see
//! `coordinator::engine`) and as the baseline the `fleet_scale` bench
//! measures the calendar's speedup against (the PR's acceptance
//! criterion: >= 5x steps/s at M = 256).
//!
//! Times are compared with [`f64::total_cmp`], so even a NaN clock
//! degrades to a deterministic order (and a NaN-starved fleet still
//! serves its healthy members) instead of a comparator panic mid-run.
//!
//! Since PR 7 a cluster run owns one calendar PER SHARD rather than one
//! global instance: each data-parallel worker interleaves only its own
//! devices' members. The pick rule makes this safe — within a device,
//! member keys and tie-breaks are identical whichever calendar holds
//! them, and members of different devices never couple mid-window — so
//! sharding changes which thread pops an event, never the per-member
//! serve order (see `docs/perf.md`).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// The scheduling interface both implementations share: schedule member
/// `idx`'s next event at time `t`, pop the earliest. A member is
/// scheduled at most once at a time (the fleet pops a member, serves its
/// round, and re-pushes it at its advanced clock).
pub trait NextEventQueue {
    /// Drop every scheduled event (start of a new control window).
    fn clear(&mut self);
    /// Schedule member `idx` at time `t`. `idx` must not currently be
    /// scheduled.
    fn push(&mut self, idx: usize, t: f64);
    /// Remove and return the member with the earliest event time; ties
    /// break toward the lowest index. `None` when nothing is scheduled.
    fn pop(&mut self) -> Option<usize>;
    /// Number of currently scheduled members.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Heap entry ordered ascending by `(t, idx)` via `total_cmp`.
#[derive(Debug, Clone, Copy)]
struct Entry {
    t: f64,
    idx: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t.total_cmp(&other.t).then_with(|| self.idx.cmp(&other.idx))
    }
}

/// Binary-heap event calendar: O(log M) push/pop, identical pick order
/// to [`LinearScan`].
#[derive(Debug, Default)]
pub struct EventCalendar {
    heap: BinaryHeap<Reverse<Entry>>,
}

impl EventCalendar {
    pub fn new() -> Self {
        Self::default()
    }

    /// Calendar with room for `n` members (the fleet size) so steady
    /// per-window reuse never reallocates.
    pub fn with_capacity(n: usize) -> Self {
        EventCalendar { heap: BinaryHeap::with_capacity(n) }
    }
}

impl NextEventQueue for EventCalendar {
    fn clear(&mut self) {
        self.heap.clear();
    }

    fn push(&mut self, idx: usize, t: f64) {
        self.heap.push(Reverse(Entry { t, idx }));
    }

    fn pop(&mut self) -> Option<usize> {
        self.heap.pop().map(|Reverse(e)| e.idx)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// The pre-calendar O(M) next-event scan, bit-for-bit the loop that
/// lived in `Fleet::run_open` (`pick.map_or(true, |p| t[i] < t[p])`:
/// strict `<`, so the first — lowest — index wins a tie). Kept as the
/// reference implementation and the bench baseline; not used on any
/// serving path.
#[derive(Debug, Default)]
pub struct LinearScan {
    times: Vec<f64>,
    active: Vec<bool>,
    scheduled: usize,
}

impl LinearScan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        LinearScan {
            times: Vec::with_capacity(n),
            active: Vec::with_capacity(n),
            scheduled: 0,
        }
    }
}

impl NextEventQueue for LinearScan {
    fn clear(&mut self) {
        self.times.clear();
        self.active.clear();
        self.scheduled = 0;
    }

    fn push(&mut self, idx: usize, t: f64) {
        if idx >= self.times.len() {
            self.times.resize(idx + 1, f64::INFINITY);
            self.active.resize(idx + 1, false);
        }
        debug_assert!(!self.active[idx], "member {idx} scheduled twice");
        self.times[idx] = t;
        self.active[idx] = true;
        self.scheduled += 1;
    }

    fn pop(&mut self) -> Option<usize> {
        let mut pick: Option<usize> = None;
        for i in 0..self.times.len() {
            if !self.active[i] {
                continue;
            }
            if pick.map_or(true, |p| self.times[i] < self.times[p]) {
                pick = Some(i);
            }
        }
        if let Some(k) = pick {
            self.active[k] = false;
            self.scheduled -= 1;
        }
        pick
    }

    fn len(&self) -> usize {
        self.scheduled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Run the same (deterministic) schedule script against both
    /// implementations and assert they pick identically. The script
    /// receives the scheduler, drives it, and returns its observed pop
    /// sequence.
    fn differential(mut script: impl FnMut(&mut dyn NextEventQueue) -> Vec<Option<usize>>) {
        let mut cal = EventCalendar::new();
        let mut lin = LinearScan::new();
        let from_calendar = script(&mut cal);
        let from_scan = script(&mut lin);
        assert_eq!(from_calendar, from_scan, "calendar and linear scan disagree on pick order");
    }

    #[test]
    fn ties_break_toward_the_lowest_index() {
        differential(|q| {
            q.push(2, 1.0);
            q.push(0, 1.0);
            q.push(1, 1.0);
            let pops = vec![q.pop(), q.pop(), q.pop(), q.pop()];
            assert_eq!(pops, vec![Some(0), Some(1), Some(2), None]);
            pops
        });
    }

    #[test]
    fn exhausted_members_simply_stop_being_pushed() {
        differential(|q| {
            let mut pops = Vec::new();
            q.push(0, 0.0);
            q.push(1, 0.5);
            q.push(2, 0.25);
            pops.push(q.pop());
            // Member 0 exhausted (finite trace): not re-pushed.
            pops.push(q.pop());
            q.push(2, 0.75); // advanced past member 1
            pops.push(q.pop());
            pops.push(q.pop());
            pops.push(q.pop());
            assert_eq!(pops, vec![Some(0), Some(2), Some(1), Some(2), None]);
            pops
        });
    }

    #[test]
    fn prop_random_schedules_pick_identically() {
        // Random pop/re-push schedules with deliberately quantized times
        // (so exact ties are common) and uneven round budgets (members
        // drop out at different points) must produce the same pick
        // sequence from both implementations — the O(log M) refactor
        // cannot change the global serving order.
        for seed in 0..50u64 {
            differential(|q| {
                let mut rng = Rng::new(0xD1FF ^ seed);
                let m = 1 + rng.below(12);
                let mut budget: Vec<u32> = (0..m).map(|_| 1 + rng.below(6) as u32).collect();
                let mut clock: Vec<f64> = (0..m).map(|_| rng.below(4) as f64 * 0.125).collect();
                for (i, &c) in clock.iter().enumerate() {
                    q.push(i, c);
                }
                let mut pops = Vec::new();
                loop {
                    let got = q.pop();
                    pops.push(got);
                    let Some(k) = got else { break };
                    budget[k] -= 1;
                    // Quantized advance: ties with other members recur.
                    clock[k] += (1 + rng.below(3)) as f64 * 0.125;
                    if budget[k] > 0 {
                        q.push(k, clock[k]);
                    }
                }
                pops
            });
        }
    }

    #[test]
    fn nan_times_do_not_panic_and_sort_last() {
        let mut cal = EventCalendar::new();
        cal.push(0, f64::NAN);
        cal.push(1, 5.0);
        assert_eq!(cal.pop(), Some(1));
        assert_eq!(cal.pop(), Some(0));
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn clear_resets_both_implementations() {
        let mut cal = EventCalendar::with_capacity(4);
        let mut lin = LinearScan::with_capacity(4);
        for q in [&mut cal as &mut dyn NextEventQueue, &mut lin] {
            q.push(0, 1.0);
            q.push(1, 2.0);
            assert_eq!(q.len(), 2);
            q.clear();
            assert!(q.is_empty());
            assert_eq!(q.pop(), None);
            q.push(1, 0.5);
            assert_eq!(q.pop(), Some(1));
        }
    }
}
