//! Windowed tail-latency monitor.
//!
//! The paper defines tail latency as the 95th percentile of the inference
//! latency distribution and has the Scaler act on windows of recent
//! batches (`LatencyList` in Algorithm 1). This module provides the
//! sliding window plus exact percentile computation.

/// Fixed-capacity sliding window of latency samples with percentile
/// queries.
#[derive(Debug, Clone)]
pub struct LatencyWindow {
    samples: Vec<f64>,
    capacity: usize,
    next: usize,
    filled: bool,
    /// Reused scratch for percentile selection (§Perf: avoids an alloc +
    /// full sort per control decision).
    scratch: Vec<f64>,
}

impl LatencyWindow {
    /// Window of `capacity` most-recent samples (capacity >= 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "window capacity must be >= 1");
        LatencyWindow {
            samples: Vec::with_capacity(capacity),
            capacity,
            next: 0,
            filled: false,
            scratch: Vec::with_capacity(capacity),
        }
    }

    /// Record one latency sample (ms).
    pub fn record(&mut self, latency_ms: f64) {
        if self.samples.len() < self.capacity {
            self.samples.push(latency_ms);
            if self.samples.len() == self.capacity {
                self.filled = true;
            }
        } else {
            self.samples[self.next] = latency_ms;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    /// Drop all samples (used when the operating point changes so stale
    /// latencies from the previous knob don't pollute the next decision).
    pub fn reset(&mut self) {
        self.samples.clear();
        self.next = 0;
        self.filled = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Exact percentile (nearest-rank) of the current window; `None` when
    /// empty. `q` in [0, 1]. O(n) via quickselect on a reused scratch
    /// buffer (was O(n log n) with an allocation; see EXPERIMENTS.md
    /// §Perf). Samples are ordered with [`f64::total_cmp`], so a NaN
    /// sample (a misbehaving device) sorts above every real latency and
    /// degrades the percentile to NaN instead of panicking mid-run.
    pub fn percentile(&mut self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let n = self.samples.len();
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.samples);
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        let (_, v, _) = self.scratch.select_nth_unstable_by(rank - 1, |a, b| a.total_cmp(b));
        Some(*v)
    }

    /// The paper's tail latency: p95.
    pub fn p95(&mut self) -> Option<f64> {
        self.percentile(0.95)
    }

    /// Mean of the window.
    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
        }
    }

    /// Maximum of the window (Algorithm 1 uses `max(LatencyList)`).
    pub fn max(&self) -> Option<f64> {
        self.samples.iter().cloned().fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut w = LatencyWindow::new(100);
        for i in 1..=100 {
            w.record(i as f64);
        }
        assert_eq!(w.p95(), Some(95.0));
        assert_eq!(w.percentile(0.5), Some(50.0));
        assert_eq!(w.percentile(1.0), Some(100.0));
        assert_eq!(w.percentile(0.0), Some(1.0)); // clamped to rank 1
    }

    #[test]
    fn window_slides() {
        let mut w = LatencyWindow::new(3);
        for v in [1.0, 2.0, 3.0, 10.0] {
            w.record(v);
        }
        // Oldest (1.0) evicted: window = {10, 2, 3}.
        assert_eq!(w.len(), 3);
        assert_eq!(w.max(), Some(10.0));
        assert_eq!(w.percentile(0.34), Some(3.0));
    }

    #[test]
    fn empty_and_reset() {
        let mut w = LatencyWindow::new(4);
        assert!(w.is_empty());
        assert_eq!(w.p95(), None);
        assert_eq!(w.mean(), None);
        assert_eq!(w.max(), None);
        w.record(5.0);
        assert_eq!(w.mean(), Some(5.0));
        w.reset();
        assert!(w.is_empty());
        assert_eq!(w.p95(), None);
    }

    #[test]
    fn single_sample_all_percentiles() {
        let mut w = LatencyWindow::new(8);
        w.record(42.0);
        assert_eq!(w.p95(), Some(42.0));
        assert_eq!(w.mean(), Some(42.0));
    }

    #[test]
    #[should_panic]
    fn zero_capacity_panics() {
        let _ = LatencyWindow::new(0);
    }

    #[test]
    fn nan_sample_cannot_panic_percentile() {
        // Regression: percentile used `partial_cmp(..).unwrap()`, so one
        // NaN latency from a misbehaving device panicked the whole run.
        // With total_cmp, NaN sorts above every real sample: low
        // percentiles stay meaningful, the top rank degrades to NaN.
        let mut w = LatencyWindow::new(8);
        for v in [1.0, f64::NAN, 3.0] {
            w.record(v);
        }
        assert_eq!(w.percentile(0.5), Some(3.0)); // rank 2 of [1, 3, NaN]
        assert!(w.percentile(1.0).unwrap().is_nan());
        assert!(w.p95().unwrap().is_nan());
        // A NaN-free window is unaffected.
        w.reset();
        w.record(2.0);
        assert_eq!(w.p95(), Some(2.0));
    }
}
