//! Canonical JSON snapshots of serving outcomes — the golden-fixture
//! substrate.
//!
//! `tests/golden.rs` runs seeded closed-loop scenarios, serializes their
//! outcomes through this module, and diffs the bytes against fixtures
//! checked in under `tests/fixtures/`. Any refactor that changes a
//! number — device RNG consumption order, window accounting, admission
//! decisions — shows up as fixture drift instead of rotting silently.
//!
//! The encoding is deliberately boring and deterministic:
//!
//! * objects serialize through [`crate::json`], whose maps are BTreeMaps
//!   (sorted keys) and whose `f64` formatting is Rust's shortest
//!   round-trip representation — stable bytes for identical numbers;
//! * the raw per-request latency vector is folded into a count + weighted
//!   sum digest (thousands of floats would bloat fixtures without adding
//!   diagnostic power: any change that perturbs one latency also
//!   perturbs the digest and the window trace).
//!
//! These bytes are also the data-parallel determinism contract (PR 7):
//! `tests/parallel.rs` renders `ClusterOutcome`s served at different
//! worker-thread counts through this module and asserts byte equality
//! against the serial engine.

use crate::json::Json;

use super::cluster::ClusterOutcome;
use super::fleet::FleetOutcome;
use super::session::{JobOutcome, WindowRecord};
use super::slo::{SloClass, SloReport};

use std::collections::BTreeMap;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn window_to_json(r: &WindowRecord) -> Json {
    obj(vec![
        ("window", num(r.window as f64)),
        ("bs", num(r.bs as f64)),
        ("mtl", num(r.mtl as f64)),
        ("slo_ms", num(r.slo_ms)),
        ("p95_ms", num(r.p95_ms)),
        ("mean_ms", num(r.mean_ms)),
        ("throughput", num(r.throughput)),
        ("duration_s", num(r.duration_s)),
        ("power_w", num(r.power_w)),
        ("queue_peak", num(r.queue_peak as f64)),
        ("arrival_rate", num(r.arrival_rate)),
        ("drops", num(r.drops as f64)),
        ("drops_deadline", num(r.drops_deadline as f64)),
    ])
}

/// Snapshot one job outcome (summary + full window trace + latency
/// digest) as a deterministic JSON value.
pub fn job_outcome_to_json(o: &JobOutcome) -> Json {
    let lat_count: f64 = o.latencies.iter().map(|(_, w)| *w).sum();
    let lat_weighted_ms: f64 = o.latencies.iter().map(|(l, w)| l * w).sum();
    let mut fields = vec![
        ("job_id", num(o.job_id as f64)),
        ("dnn", Json::Str(o.dnn.clone())),
        ("controller", Json::Str(o.controller.clone())),
        (
            "method",
            o.method.map_or(Json::Null, |m| Json::Str(format!("{m:?}"))),
        ),
        ("steady_bs", num(o.steady_bs as f64)),
        ("steady_mtl", num(o.steady_mtl as f64)),
        ("throughput", num(o.throughput)),
        ("p95_ms", num(o.p95_ms)),
        ("slo_attainment", num(o.slo_attainment)),
        ("steady_attainment", num(o.steady_attainment)),
        ("power_w", num(o.power_w)),
        ("goodput", num(o.goodput)),
        ("arrived", num(o.arrived as f64)),
        ("drops", num(o.drops as f64)),
        ("dropped_deadline", num(o.dropped_deadline as f64)),
        ("queue_peak", num(o.queue_peak as f64)),
        ("latency_count", num(lat_count)),
        ("latency_weighted_sum_ms", num(lat_weighted_ms)),
        ("trace", Json::Arr(o.trace.iter().map(window_to_json).collect())),
    ];
    // Crash losses only exist under cluster fault injection; omitting
    // the key otherwise keeps every pre-faults snapshot byte-identical.
    if o.dropped_failure > 0 {
        fields.push(("dropped_failure", num(o.dropped_failure as f64)));
    }
    obj(fields)
}

/// Per-class accounting, keyed by class name. Present in fleet/cluster
/// snapshots only when the run carried SLO classes.
fn slo_report_to_json(r: &SloReport) -> Json {
    obj(SloClass::ALL
        .iter()
        .map(|&c| {
            let s = r.class(c);
            (
                c.name(),
                obj(vec![
                    ("members", num(s.members as f64)),
                    ("goodput", num(s.goodput)),
                    ("shed", num(s.shed as f64)),
                ]),
            )
        })
        .collect())
}

/// Snapshot a fleet outcome (per-member snapshots + shared-GPU telemetry)
/// as a deterministic JSON value.
pub fn fleet_outcome_to_json(o: &FleetOutcome) -> Json {
    let mut fields = vec![
        ("partition", Json::Str(o.partition.to_string())),
        ("total_throughput", num(o.total_throughput)),
        ("total_goodput", num(o.total_goodput)),
        ("peak_mem_mb", num(o.peak_mem_mb)),
        ("mem_capacity_mb", num(o.mem_capacity_mb)),
        ("peak_contention", num(o.peak_contention)),
        ("admission_clamps", num(o.admission_clamps as f64)),
        (
            "contention_trace",
            Json::Arr(o.contention_trace.iter().map(|&c| num(c)).collect()),
        ),
        (
            "grant_trace",
            Json::Arr(
                o.grant_trace
                    .iter()
                    .map(|g| Json::Arr(g.iter().map(|&v| num(v)).collect()))
                    .collect(),
            ),
        ),
        (
            "members",
            Json::Arr(o.members.iter().map(job_outcome_to_json).collect()),
        ),
    ];
    // SLO classes only exist when some member was classed; omitting the
    // key otherwise keeps every unclassed snapshot byte-identical.
    if let Some(r) = &o.slo {
        fields.push(("slo", slo_report_to_json(r)));
    }
    obj(fields)
}

/// Snapshot a cluster outcome: placement metadata, the assignment, and
/// one full fleet snapshot per device (device descriptor included, so a
/// drifting perf fraction or memory ceiling is fixture-visible too).
pub fn cluster_outcome_to_json(o: &ClusterOutcome) -> Json {
    let mut fields = vec![
        ("placement", Json::Str(o.placement.clone())),
        (
            "assignment",
            Json::Arr(o.assignment.iter().map(|&d| num(d as f64)).collect()),
        ),
        ("total_throughput", num(o.total_throughput)),
        ("total_goodput", num(o.total_goodput)),
        (
            "devices",
            Json::Arr(
                o.devices
                    .iter()
                    .map(|d| {
                        obj(vec![
                            ("name", Json::Str(d.device.name.clone())),
                            ("gpu", Json::Str(d.device.spec.name.to_string())),
                            ("perf_fraction", num(d.device.perf_fraction)),
                            ("mem_mb", num(d.device.mem_mb)),
                            ("physical", num(d.device.physical as f64)),
                            (
                                "jobs",
                                Json::Arr(d.jobs.iter().map(|&j| num(j as f64)).collect()),
                            ),
                            ("fleet", fleet_outcome_to_json(&d.fleet)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    // Dynamics telemetry only exists on the dynamic path; omitting the
    // key entirely keeps static-run snapshots byte-identical to the
    // fixtures blessed before dynamics existed.
    if let Some(dy) = &o.dynamics {
        let mut dyn_fields = vec![
            ("launches", num(dy.launches as f64)),
            ("failed_launches", num(dy.failed_launches as f64)),
            ("retires", num(dy.retires as f64)),
            ("migrations", num(dy.migrations as f64)),
            ("migration_stall_ms", num(dy.migration_stall_ms)),
            ("rejected_proposals", num(dy.rejected_proposals as f64)),
            ("scale_ups", num(dy.scale_ups as f64)),
            ("scale_downs", num(dy.scale_downs as f64)),
            (
                "pool_trace",
                Json::Arr(dy.pool_trace.iter().map(|&n| num(n as f64)).collect()),
            ),
            ("device_hours", num(dy.device_hours)),
            ("cost_usd", num(dy.cost_usd)),
            ("cost_per_goodput", dy.cost_per_goodput.map_or(Json::Null, num)),
        ];
        // Both fault-era keys are conditional for the same reason the
        // dynamics key itself is: snapshots blessed before fault
        // injection existed must not drift.
        if dy.deferred_launches > 0 {
            dyn_fields.push(("deferred_launches", num(dy.deferred_launches as f64)));
        }
        if let Some(fo) = &dy.faults {
            dyn_fields.push((
                "faults",
                obj(vec![
                    ("crashes", num(fo.crashes as f64)),
                    ("degrades", num(fo.degrades as f64)),
                    ("repairs", num(fo.repairs as f64)),
                    ("failovers", num(fo.failovers as f64)),
                    ("failover_stall_ms", num(fo.failover_stall_ms)),
                    ("dropped_failure", num(fo.dropped_failure as f64)),
                    ("deferred_jobs", num(fo.deferred_jobs as f64)),
                    (
                        "pool_health",
                        Json::Arr(fo.pool_health.iter().map(|&n| num(n as f64)).collect()),
                    ),
                ]),
            ));
        }
        fields.push(("dynamics", obj(dyn_fields)));
    }
    // The cluster-wide class report mirrors the per-device ones and is
    // conditional for the identical byte-identity reason.
    if let Some(r) = &o.slo {
        fields.push(("slo", slo_report_to_json(r)));
    }
    obj(fields)
}

/// Render a snapshot with a trailing newline (fixture file contents).
pub fn render(v: &Json) -> String {
    let mut s = crate::json::write(v);
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;
    use crate::coordinator::session::{PolicySpec, RunConfig, ServingSession};
    use crate::gpusim::GpuSim;

    fn run(seed: u64) -> crate::coordinator::session::JobOutcome {
        let job = paper_job(1).unwrap();
        let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
        ServingSession::builder()
            .config(RunConfig::windows(4, 4))
            .job(job)
            .device(sim)
            .policy(PolicySpec::Static { bs: 2, mtl: 1 })
            .build()
            .unwrap()
            .run()
            .unwrap()
    }

    #[test]
    fn snapshot_is_deterministic_and_roundtrips() {
        let a = render(&job_outcome_to_json(&run(9)));
        let b = render(&job_outcome_to_json(&run(9)));
        assert_eq!(a, b, "identical runs must produce identical bytes");
        // Valid JSON with the expected top-level fields.
        let v = crate::json::parse(a.trim()).unwrap();
        assert_eq!(v.get("dnn").unwrap().as_str(), Some("inc-v1"));
        assert_eq!(v.get("trace").unwrap().as_arr().unwrap().len(), 4);
        assert!(v.get("throughput").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn different_seeds_produce_different_snapshots() {
        assert_ne!(
            render(&job_outcome_to_json(&run(9))),
            render(&job_outcome_to_json(&run(10))),
            "the snapshot must be sensitive to the numbers it guards"
        );
    }
}
