//! Fault injection and recovery for the cluster engine: device crashes,
//! temporary performance degradation, and repair.
//!
//! Warehouse-scale inference serves through infrastructure events — GPU
//! ECC stalls, thermal throttling, outright card deaths — yet a
//! simulator whose devices are immortal never exercises the recovery
//! paths a production controller depends on. This module supplies the
//! fault model the dynamics runner executes:
//!
//! * **[`FaultEvent::Crash`]** — the device goes dark at the start of
//!   its window. Queued (in-flight) requests on its members are lost and
//!   accounted to `dropped_failure`; the members themselves are failed
//!   over onto the surviving active devices (most-free-fit, charged
//!   [`model_load_ms`](super::dynamics::model_load_ms) like any
//!   migration), and members that fit nowhere wait in a pending queue
//!   with capped exponential backoff, re-attempted at later window
//!   barriers.
//! * **[`FaultEvent::Degrade`]** — thermal throttle / ECC slowdown: the
//!   device's effective `perf_fraction` is scaled by `factor` for
//!   `for_windows` windows, executing members inside a reduced SM grant
//!   on the granted perf model.
//! * **[`FaultEvent::Repair`]** — a crashed device returns to service
//!   and is eligible for placement again from its window on.
//!
//! Schedules are validated at `build()` (typed
//! [`ConfigError::BadFaults`]) by replaying them window by window,
//! exactly as churn schedules are. A stochastic mode
//! ([`ClusterBuilder::stochastic_faults`](super::cluster::ClusterBuilder::stochastic_faults))
//! draws per-device MTBF/MTTR exponential crash/repair sequences from
//! the run seed at build time, so fault campaigns stay byte-reproducible
//! across runs, thread counts, and the differential reference executor.
//!
//! All fault decisions are taken serially at the window barrier (like
//! churn, migration, and autoscaling), so the sharded parallel serving
//! path stays snapshot-byte-identical at every thread count. Fault-free
//! runs never touch this module and keep their exact pre-fault snapshot
//! bytes. See `docs/faults.md`.

use crate::rng::Rng;

use super::session::ConfigError;

/// Backoff cap (in windows) for jobs waiting in the pending queue: the
/// retry interval doubles on every failed placement attempt up to this
/// many windows.
pub const MAX_BACKOFF_WINDOWS: usize = 8;

/// One fault, keyed by control-window index and pool device index
/// (build-time pool order — MIG slices count as devices; devices rented
/// later by an autoscaler cannot be targeted by a schedule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    /// `device` dies at the start of `window`: queued work is lost
    /// (`dropped_failure`), residents fail over or wait with backoff.
    Crash { device: usize, window: usize },
    /// `device` runs at `factor` of its normal SM capacity for
    /// `for_windows` windows starting at `window` (thermal throttle /
    /// ECC slowdown). `factor` must lie strictly inside (0, 1).
    Degrade { device: usize, window: usize, factor: f64, for_windows: usize },
    /// A crashed `device` returns to service at the start of `window`.
    Repair { device: usize, window: usize },
}

impl FaultEvent {
    pub(crate) fn window(&self) -> usize {
        match self {
            FaultEvent::Crash { window, .. }
            | FaultEvent::Degrade { window, .. }
            | FaultEvent::Repair { window, .. } => *window,
        }
    }

    pub(crate) fn device(&self) -> usize {
        match self {
            FaultEvent::Crash { device, .. }
            | FaultEvent::Degrade { device, .. }
            | FaultEvent::Repair { device, .. } => *device,
        }
    }
}

/// An ordered schedule of [`FaultEvent`]s. Events fire at the start of
/// their window, grouped by window in insertion order — before churn,
/// so a launch at a crash's window never lands on the dead card.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    pub(crate) events: Vec<FaultEvent>,
    /// When false, a crash strands ALL of the victim's members (no
    /// re-placement, no retries) — the "no recovery" baseline the e2e
    /// acceptance test compares failover against.
    pub(crate) failover: bool,
}

impl Default for FaultSchedule {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultSchedule {
    pub fn new() -> Self {
        FaultSchedule { events: Vec::new(), failover: true }
    }

    /// Crash `device` at the start of `window`.
    pub fn crash(mut self, device: usize, window: usize) -> Self {
        self.events.push(FaultEvent::Crash { device, window });
        self
    }

    /// Run `device` at `factor` of its SM capacity for `for_windows`
    /// windows starting at `window`.
    pub fn degrade(
        mut self,
        device: usize,
        window: usize,
        factor: f64,
        for_windows: usize,
    ) -> Self {
        self.events.push(FaultEvent::Degrade { device, window, factor, for_windows });
        self
    }

    /// Return a crashed `device` to service at the start of `window`.
    pub fn repair(mut self, device: usize, window: usize) -> Self {
        self.events.push(FaultEvent::Repair { device, window });
        self
    }

    /// Disable (or re-enable) failover: with `false`, crashed devices'
    /// members are stranded for the rest of the run instead of being
    /// re-placed. Injection and `dropped_failure` accounting still run.
    pub fn failover(mut self, enabled: bool) -> Self {
        self.failover = enabled;
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Append another schedule's events (build-time merge of an explicit
    /// schedule with materialized stochastic faults).
    pub(crate) fn extend(&mut self, events: Vec<FaultEvent>) {
        self.events.extend(events);
    }

    /// Build-time validation: every event inside the run and the device
    /// pool, degrade parameters sane, and the crash/repair state machine
    /// consistent — replayed window by window against a per-device
    /// up/down flag exactly as the runtime will apply it. Typed
    /// [`ConfigError::BadFaults`] otherwise.
    pub(crate) fn validate(&self, windows: usize, devices: usize) -> Result<(), ConfigError> {
        let bad = |reason: String| Err(ConfigError::BadFaults { reason });
        let mut down = vec![false; devices];
        for w in 0..self.events.iter().map(|e| e.window() + 1).max().unwrap_or(0) {
            for e in self.events.iter().filter(|e| e.window() == w) {
                if e.window() >= windows {
                    return bad(format!(
                        "event at window {} but the run has only {windows} window(s)",
                        e.window()
                    ));
                }
                if e.device() >= devices {
                    return bad(format!(
                        "event targets device {} but the pool has only {devices} device(s)",
                        e.device()
                    ));
                }
                match *e {
                    FaultEvent::Crash { device, window } => {
                        if down[device] {
                            return bad(format!(
                                "crash of device {device} at window {window}: it is \
                                 already down (double crash)"
                            ));
                        }
                        down[device] = true;
                    }
                    FaultEvent::Degrade { device, window, factor, for_windows } => {
                        if !(factor.is_finite() && factor > 0.0 && factor < 1.0) {
                            return bad(format!(
                                "degrade of device {device} at window {window}: factor \
                                 {factor} must lie strictly inside (0, 1)"
                            ));
                        }
                        if for_windows == 0 {
                            return bad(format!(
                                "degrade of device {device} at window {window}: \
                                 for_windows must be >= 1"
                            ));
                        }
                        if down[device] {
                            return bad(format!(
                                "degrade of device {device} at window {window}: the \
                                 device is down (repair it first)"
                            ));
                        }
                    }
                    FaultEvent::Repair { device, window } => {
                        if !down[device] {
                            return bad(format!(
                                "repair of device {device} at window {window}: it is \
                                 not down (never crashed, or already repaired)"
                            ));
                        }
                        down[device] = false;
                    }
                }
            }
        }
        Ok(())
    }
}

/// Materialize a stochastic fault campaign: per device, alternate
/// exponential time-to-failure (mean `mtbf_windows`) and time-to-repair
/// (mean `mttr_windows`) draws from an RNG derived from the run seed,
/// rounded down to window indices (consecutive events forced onto
/// distinct windows so the replayed state machine stays consistent). A
/// repair landing past the run's end is dropped — the device stays down.
/// Purely a function of `(seed, devices, windows, mtbf, mttr)`, so the
/// campaign is byte-reproducible everywhere the schedule is replayed.
pub(crate) fn materialize_stochastic(
    seed: u64,
    devices: usize,
    windows: usize,
    mtbf_windows: f64,
    mttr_windows: f64,
) -> Vec<FaultEvent> {
    let mut events = Vec::new();
    for device in 0..devices {
        let mut rng =
            Rng::new(seed.wrapping_mul(0x2545_F491_4F6C_DD1D).wrapping_add(device as u64));
        let mut t = 0.0f64;
        let mut last_w: Option<usize> = None;
        loop {
            t += rng.exponential(1.0 / mtbf_windows);
            let mut cw = t.floor() as usize;
            if let Some(lw) = last_w {
                cw = cw.max(lw + 1);
            }
            if cw >= windows {
                break;
            }
            events.push(FaultEvent::Crash { device, window: cw });
            last_w = Some(cw);
            t = t.max(cw as f64);
            t += rng.exponential(1.0 / mttr_windows);
            let rw = (t.floor() as usize).max(cw + 1);
            if rw >= windows {
                break; // down for the rest of the run
            }
            events.push(FaultEvent::Repair { device, window: rw });
            last_w = Some(rw);
            t = t.max(rw as f64);
        }
    }
    events
}

/// Telemetry of a faulty run, reported as `DynamicsOutcome::faults`
/// (absent — and absent from snapshots — unless fault injection was
/// configured, so fault-free runs keep their exact pre-fault bytes).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultsOutcome {
    /// Crash events applied.
    pub crashes: u64,
    /// Degrade events applied.
    pub degrades: u64,
    /// Repair events applied.
    pub repairs: u64,
    /// Jobs successfully re-placed off a crashed device (immediately or
    /// after waiting in the pending queue).
    pub failovers: u64,
    /// Total virtual-clock stall charged for failover re-placements (ms).
    pub failover_stall_ms: f64,
    /// In-flight (queued) requests lost to crashes; included in the
    /// conservation audit alongside drops and deadline sheds.
    pub dropped_failure: u64,
    /// Placement deferrals: every time a job entered (or stayed in) the
    /// pending queue because nothing could hold it.
    pub deferred_jobs: u64,
    /// Healthy (non-crashed) pool devices at each window, after that
    /// window's fault events.
    pub pool_health: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_events_in_order() {
        let s = FaultSchedule::new().crash(1, 2).degrade(0, 1, 0.5, 3).repair(1, 4);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
        assert_eq!(s.events[0], FaultEvent::Crash { device: 1, window: 2 });
        assert_eq!(s.events[2], FaultEvent::Repair { device: 1, window: 4 });
        assert!(s.failover);
        assert!(!s.failover(false).failover);
    }

    #[test]
    fn validate_accepts_a_sane_schedule() {
        let s = FaultSchedule::new()
            .crash(0, 0) // crash at window 0 is legal
            .repair(0, 2)
            .degrade(1, 1, 0.5, 4)
            .crash(0, 5);
        assert!(s.validate(6, 2).is_ok());
    }

    #[test]
    fn validate_rejects_out_of_range_window_and_device() {
        let s = FaultSchedule::new().crash(0, 9);
        assert!(matches!(s.validate(4, 2), Err(ConfigError::BadFaults { .. })));
        let s = FaultSchedule::new().crash(5, 1);
        assert!(matches!(s.validate(4, 2), Err(ConfigError::BadFaults { .. })));
    }

    #[test]
    fn validate_rejects_double_crash() {
        let s = FaultSchedule::new().crash(0, 1).crash(0, 3);
        assert!(matches!(s.validate(6, 2), Err(ConfigError::BadFaults { .. })));
        // ... but crash -> repair -> crash is fine.
        let s = FaultSchedule::new().crash(0, 1).repair(0, 2).crash(0, 3);
        assert!(s.validate(6, 2).is_ok());
    }

    #[test]
    fn validate_rejects_repair_of_healthy_device() {
        let s = FaultSchedule::new().repair(0, 2);
        assert!(matches!(s.validate(4, 1), Err(ConfigError::BadFaults { .. })));
        let s = FaultSchedule::new().crash(0, 1).repair(0, 2).repair(0, 3);
        assert!(matches!(s.validate(6, 1), Err(ConfigError::BadFaults { .. })));
    }

    #[test]
    fn validate_rejects_bad_degrades() {
        for factor in [0.0, 1.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            let s = FaultSchedule::new().degrade(0, 1, factor, 2);
            assert!(
                matches!(s.validate(4, 1), Err(ConfigError::BadFaults { .. })),
                "factor {factor} must be rejected"
            );
        }
        let s = FaultSchedule::new().degrade(0, 1, 0.5, 0);
        assert!(matches!(s.validate(4, 1), Err(ConfigError::BadFaults { .. })));
        // Degrading a dead device is meaningless.
        let s = FaultSchedule::new().crash(0, 1).degrade(0, 2, 0.5, 2);
        assert!(matches!(s.validate(4, 1), Err(ConfigError::BadFaults { .. })));
    }

    #[test]
    fn validate_replays_by_window_not_insertion_order() {
        // Inserted "repair then crash" but the windows order them
        // crash-first, so the replay accepts the schedule.
        let s = FaultSchedule::new().repair(0, 3).crash(0, 1);
        assert!(s.validate(4, 1).is_ok());
    }

    #[test]
    fn stochastic_campaign_is_deterministic_and_valid() {
        for seed in 0..50u64 {
            let a = materialize_stochastic(seed, 3, 16, 4.0, 2.0);
            let b = materialize_stochastic(seed, 3, 16, 4.0, 2.0);
            assert_eq!(a, b, "seed {seed}: materialization must be reproducible");
            let mut s = FaultSchedule::new();
            s.extend(a);
            assert!(
                s.validate(16, 3).is_ok(),
                "seed {seed}: materialized schedule must validate: {:?}",
                s.validate(16, 3)
            );
        }
    }

    #[test]
    fn stochastic_campaign_alternates_per_device() {
        let events = materialize_stochastic(7, 2, 64, 3.0, 1.5);
        assert!(!events.is_empty(), "64 windows at MTBF 3 should see failures");
        for d in 0..2 {
            let mut down = false;
            let mut last = None;
            for e in events.iter().filter(|e| e.device() == d) {
                if let Some(lw) = last {
                    assert!(e.window() > lw, "strictly increasing windows per device");
                }
                last = Some(e.window());
                match e {
                    FaultEvent::Crash { .. } => {
                        assert!(!down, "crash of a down device");
                        down = true;
                    }
                    FaultEvent::Repair { .. } => {
                        assert!(down, "repair of an up device");
                        down = false;
                    }
                    FaultEvent::Degrade { .. } => panic!("stochastic mode emits no degrades"),
                }
            }
        }
    }

    #[test]
    fn stochastic_rates_scale_with_mtbf() {
        let frequent = materialize_stochastic(11, 4, 128, 2.0, 1.0).len();
        let rare = materialize_stochastic(11, 4, 128, 50.0, 1.0).len();
        assert!(
            frequent > rare,
            "MTBF 2 ({frequent} events) must out-fail MTBF 50 ({rare} events)"
        );
    }
}
