//! Warehouse-scale cluster dynamics: job churn, live migration, and
//! price-aware autoscaling — the layer that turns the static
//! [`Cluster`](super::cluster::Cluster) into a living fleet.
//!
//! The paper's DNNScaler tunes batch size and instance count for a
//! *fixed* set of co-located DNNs; a warehouse-scale inference service
//! sees jobs launch and retire all day, re-places them as load shifts,
//! and pays per device-hour. "No DNN Left Behind" frames inference
//! multi-tenancy as exactly this cloud-economics problem: the metric
//! that matters is cost per goodput, not raw throughput. This module
//! adds three window-boundary control loops on top of the unchanged
//! per-round serving engine:
//!
//! * **Job churn** ([`ChurnSchedule`], [`JobEvent`]) — launches and
//!   retirements keyed by control-window index (the cluster's control
//!   tick; member virtual clocks are per-member, so the window is the
//!   only globally meaningful time). A launched job pays its model-load
//!   overhead as a virtual-clock stall, so the arrivals that land during
//!   the load become first-window backlog and inflate its early
//!   latencies — the same mechanism profiling overhead uses.
//! * **Live migration** ([`PlacementPolicy`], [`PeriodicReplace`]) —
//!   the window-boundary analogue of `PartitionPolicy`: every window the
//!   policy may propose a new job-to-device assignment (re-using any
//!   [`Placement`] heuristic); each moved job is charged a migration
//!   stall ([`model_load_ms`] of its footprint — the weights must be
//!   loaded on the destination) and the move is counted in
//!   [`DynamicsOutcome::migrations`]. Proposals are sanitized like any
//!   custom placer's output: wrong length, unknown devices, or memory
//!   over-commit reject the whole proposal (counted, never applied).
//! * **Price-aware autoscaling** ([`Autoscaler`],
//!   [`ThresholdAutoscaler`]) — grows or shrinks the active device pool
//!   against the `$ / device-hour` on each
//!   [`DeviceDesc`](super::cluster::DeviceDesc) (see
//!   [`price_per_hour`]). Shrinking evacuates the victim's jobs (a
//!   forced migration, charged like any other) and never proceeds when
//!   the survivors cannot hold the evacuees' model footprints. The run
//!   reports accumulated device-hours, dollars, and
//!   [`DynamicsOutcome::cost_per_goodput`].
//!
//! A fourth control loop shares the same barrier: fault injection
//! ([`faults`](super::faults)) — device crashes (queued work lost to
//! `dropped_failure`, residents failed over through the same placement
//! machinery or parked in a pending queue with capped exponential
//! backoff), temporary performance degradation, and repair. Fault
//! decisions are serial at the barrier, so faulty runs stay
//! byte-identical at every thread count (see `docs/faults.md`).
//!
//! Dynamics run only when requested: a churn-free, migration-free,
//! autoscale-free cluster takes the static [`fleet::run_open_devices`]
//! path untouched and its `ClusterOutcome` snapshot stays byte-identical
//! (`dynamics: None` is simply not serialized).
//!
//! [`fleet::run_open_devices`]: super::fleet

use crate::device::DeviceError;
use crate::gpusim::{GpuSpec, PartitionMode};
use crate::workload::ArrivalPattern;

use super::calendar::{EventCalendar, NextEventQueue};
use super::cluster::{
    merge_slo_reports, whole_desc, Assignment, ClusterOutcome, DeviceDesc, DeviceOutcome,
    Placement, PlacementJob,
};
use super::engine::{SmShare, WindowAccum};
use super::faults::{FaultEvent, FaultSchedule, FaultsOutcome, MAX_BACKOFF_WINDOWS};
use super::fleet::{
    admit_window, arrival_seed, finish_fleet, new_open_member, open_member_outcome,
    shard_count, validate_member_cfg, DeviceCtx, DeviceFailure, MemberCfg, OpenMember,
    Partitioner,
};
use super::job::JobSpec;
use super::policy::WindowObservation;
use super::session::{ConfigError, JobOutcome, PolicySpec, RunConfig};
use super::slo::SloClass;

use std::fmt;

/// `$ / device-hour` list price of a catalogued GPU — the catalogue the
/// autoscaler's cost accounting runs against (on-demand cloud pricing
/// ballpark; override per device with `ClusterBuilder::prices`). A MIG
/// slice exposed as a virtual device costs its grant's share of the
/// card.
pub fn price_per_hour(spec: &GpuSpec) -> f64 {
    match spec.name {
        "Tesla P40" => 1.20,
        "Tesla T4" => 0.53,
        "Tesla P4" => 0.60,
        // Uncatalogued hardware: price like the calibration card.
        _ => 1.20,
    }
}

/// Model-(re)load stall in ms charged to a launched or migrated job:
/// the same fixed-cost-plus-PCIe-transfer shape as
/// `GpuSim::launch_overhead_ms`, evaluated on the job's bare model
/// footprint (the destination device must load the weights before the
/// first batch can run).
pub fn model_load_ms(footprint_mb: f64) -> f64 {
    2000.0 + 2.0 * footprint_mb
}

/// One churn event, keyed by control-window index.
pub enum JobEvent<'a> {
    /// A new job enters the cluster at the start of `window`. It is
    /// placed on the feasible active device with the most free footprint
    /// memory and charged [`model_load_ms`] of its footprint as a
    /// virtual-clock stall (first-window backlog). If no active device
    /// can hold its footprint the launch fails (counted, not served).
    Launch {
        window: usize,
        job: JobSpec,
        policy: PolicySpec<'a>,
        arrivals: ArrivalPattern,
    },
    /// The first live job with paper id `job_id` leaves the cluster at
    /// the start of `window`; its outcome is finalized with whatever it
    /// served up to that point.
    Retire { window: usize, job_id: u32 },
}

impl fmt::Debug for JobEvent<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobEvent::Launch { window, job, .. } => f
                .debug_struct("Launch")
                .field("window", window)
                .field("job", &job.id)
                .field("dnn", &job.dnn)
                .finish(),
            JobEvent::Retire { window, job_id } => f
                .debug_struct("Retire")
                .field("window", window)
                .field("job_id", job_id)
                .finish(),
        }
    }
}

impl JobEvent<'_> {
    pub(crate) fn window(&self) -> usize {
        match self {
            JobEvent::Launch { window, .. } | JobEvent::Retire { window, .. } => *window,
        }
    }
}

/// An ordered schedule of [`JobEvent`]s. Events fire at the start of
/// their window, grouped by window in insertion order.
#[derive(Debug, Default)]
pub struct ChurnSchedule<'a> {
    pub(crate) events: Vec<JobEvent<'a>>,
}

impl<'a> ChurnSchedule<'a> {
    pub fn new() -> Self {
        ChurnSchedule { events: Vec::new() }
    }

    /// Launch `job` (with its policy and open-loop arrivals) at the
    /// start of `window`.
    pub fn launch(
        mut self,
        window: usize,
        job: &JobSpec,
        policy: PolicySpec<'a>,
        arrivals: ArrivalPattern,
    ) -> Self {
        self.events.push(JobEvent::Launch { window, job: *job, policy, arrivals });
        self
    }

    /// Retire the (first live) job with paper id `job_id` at the start
    /// of `window`.
    pub fn retire(mut self, window: usize, job_id: u32) -> Self {
        self.events.push(JobEvent::Retire { window, job_id });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Build-time validation: every event window inside the run, every
    /// launch a valid open-loop member, every retire matched by an
    /// initial job or an earlier launch that is still live at its
    /// window. Typed [`ConfigError::BadChurn`] otherwise.
    pub(crate) fn validate(
        &self,
        windows: usize,
        initial_ids: &[u32],
    ) -> Result<(), ConfigError> {
        let bad = |reason: String| Err(ConfigError::BadChurn { reason });
        // Replay the schedule window by window against the live id
        // multiset, exactly as the runtime will apply it.
        let mut live: Vec<u32> = initial_ids.to_vec();
        for w in 0..windows.max(
            self.events.iter().map(|e| e.window() + 1).max().unwrap_or(0),
        ) {
            for e in self.events.iter().filter(|e| e.window() == w) {
                if e.window() >= windows {
                    return bad(format!(
                        "event at window {} but the run has only {windows} window(s)",
                        e.window()
                    ));
                }
                match e {
                    JobEvent::Launch { job, arrivals, .. } => {
                        if arrivals.is_closed() {
                            return bad(format!(
                                "launch of job {} is closed-loop; churned jobs need an \
                                 open-loop arrival process",
                                job.id
                            ));
                        }
                        // Same member validation the builder applies to
                        // initial jobs (unknown DNN, bad rates, ...).
                        // The real policy spec is only borrowed here, so
                        // a throwaway static stand-in fills the slot;
                        // resolve_policy handles the real spec at launch.
                        let probe = MemberCfg::new(
                            job,
                            PolicySpec::Static { bs: 1, mtl: 1 },
                            arrivals.clone(),
                        );
                        validate_member_cfg(&probe)?;
                        live.push(job.id);
                    }
                    JobEvent::Retire { window, job_id } => {
                        let Some(pos) = live.iter().position(|id| id == job_id) else {
                            return bad(format!(
                                "retire of job {job_id} at window {window}: no such job \
                                 is live (not an initial job or an earlier launch)"
                            ));
                        };
                        live.remove(pos);
                    }
                }
            }
        }
        Ok(())
    }
}

/// A live re-placement strategy: the window-boundary analogue of
/// `PartitionPolicy`, deciding *which device each job runs on* instead
/// of how one device's SMs are split.
///
/// Called at every window boundary with the live jobs (stable global-job
/// order), the currently *active* devices (pool order), the current
/// assignment into that device list, and the previous window's
/// observations (index-aligned with `jobs`). Return `None` to keep the
/// current assignment, or `Some(assignment)` to migrate — the proposal
/// is validated like any custom placer's output and rejected wholesale
/// (counted in [`DynamicsOutcome::rejected_proposals`]) if it is
/// malformed or over-commits memory.
pub trait PlacementPolicy {
    fn name(&self) -> &'static str;

    fn replace(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
        current: &[usize],
        obs: &[WindowObservation],
    ) -> Option<Vec<usize>>;
}

/// Re-run a [`Placement`] heuristic every `every` windows and migrate to
/// its assignment when it differs from the current one — the baseline
/// migration policy (placement heuristics are already demand-aware; the
/// period bounds migration churn).
#[derive(Debug)]
pub struct PeriodicReplace<P> {
    inner: P,
    every: usize,
    ticks: usize,
}

impl<P: Placement> PeriodicReplace<P> {
    /// `every` is clamped to at least 1 (re-place every window).
    pub fn new(inner: P, every: usize) -> Self {
        PeriodicReplace { inner, every: every.max(1), ticks: 0 }
    }
}

impl<P: Placement> PlacementPolicy for PeriodicReplace<P> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn replace(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
        current: &[usize],
        _obs: &[WindowObservation],
    ) -> Option<Vec<usize>> {
        self.ticks += 1;
        if self.ticks % self.every != 0 || jobs.is_empty() {
            return None;
        }
        let proposed = self.inner.place(jobs, devices).ok()?.device_of;
        if proposed == current {
            None
        } else {
            Some(proposed)
        }
    }
}

/// What the autoscaler sees of the pool at a window boundary (the
/// previous window's aggregate telemetry).
#[derive(Debug)]
pub struct PoolObservation<'x> {
    /// The window about to start.
    pub window: usize,
    /// Devices currently powered on (and billed).
    pub active_devices: usize,
    /// Jobs currently live.
    pub live_jobs: usize,
    /// Mean combined SM pressure across *active* devices last window
    /// (idle-but-billed devices contribute 0; > 1 on a device means its
    /// members time-slice an oversubscribed card).
    pub mean_pressure: f64,
    /// Peak single-device SM pressure last window.
    pub max_pressure: f64,
    /// Requests left queued across all live jobs at the boundary.
    pub queue_depth: usize,
    /// Requests dropped or shed across all live jobs last window.
    pub drops: u64,
    /// Queued requests per SLO class at the boundary, in
    /// [`SloClass::index`] order (gold, silver, best-effort). All zero
    /// when no live job carries a class — a class-aware autoscaler can
    /// then fall back to the aggregate `queue_depth`.
    pub class_queue: [usize; 3],
    /// The full device pool, `active[i]` flagging the powered-on ones.
    pub devices: &'x [DeviceDesc],
    pub active: &'x [bool],
}

/// The autoscaler's verdict for the next window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    Hold,
    /// Power on one more device (re-activating a parked one, or renting
    /// a new instance of the pool's template card).
    Grow,
    /// Evacuate and power off one device (refused by the runtime when
    /// the survivors cannot hold the evacuated model footprints).
    Shrink,
}

/// An elasticity strategy: one verdict per window boundary.
pub trait Autoscaler {
    fn name(&self) -> &'static str;

    fn scale(&mut self, obs: &PoolObservation<'_>) -> ScaleAction;
}

/// Threshold autoscaling baseline: grow when mean SM pressure exceeds
/// `grow_above`, shrink when it falls below `shrink_below`, always
/// keeping the pool inside `[min_devices, max_devices]`. The classic
/// reactive policy every smarter autoscaler must beat.
#[derive(Debug, Clone)]
pub struct ThresholdAutoscaler {
    pub grow_above: f64,
    pub shrink_below: f64,
    pub min_devices: usize,
    pub max_devices: usize,
}

impl ThresholdAutoscaler {
    /// Default thresholds (grow above 0.85, shrink below 0.30) over the
    /// given pool bounds. `min_devices` is clamped to at least 1.
    pub fn new(min_devices: usize, max_devices: usize) -> Self {
        let min = min_devices.max(1);
        ThresholdAutoscaler {
            grow_above: 0.85,
            shrink_below: 0.30,
            min_devices: min,
            max_devices: max_devices.max(min),
        }
    }
}

impl Autoscaler for ThresholdAutoscaler {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn scale(&mut self, obs: &PoolObservation<'_>) -> ScaleAction {
        if obs.active_devices < self.min_devices {
            return ScaleAction::Grow;
        }
        if obs.mean_pressure > self.grow_above && obs.active_devices < self.max_devices {
            return ScaleAction::Grow;
        }
        if obs.mean_pressure < self.shrink_below && obs.active_devices > self.min_devices {
            return ScaleAction::Shrink;
        }
        ScaleAction::Hold
    }
}

/// Telemetry of one dynamic cluster run, reported as
/// `ClusterOutcome::dynamics` (absent — and absent from snapshots — on
/// static runs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DynamicsOutcome {
    /// Jobs launched by the churn schedule (successfully placed).
    pub launches: u64,
    /// Launches refused because no active device could hold the model
    /// footprint (the job never serves).
    pub failed_launches: u64,
    /// Jobs retired by the churn schedule.
    pub retires: u64,
    /// Individual job moves, both policy-proposed and shrink-forced.
    pub migrations: u64,
    /// Total virtual-clock stall charged for migrations (ms).
    pub migration_stall_ms: f64,
    /// Placement-policy proposals rejected by validation.
    pub rejected_proposals: u64,
    /// Devices powered on by the autoscaler.
    pub scale_ups: u64,
    /// Devices evacuated and powered off by the autoscaler.
    pub scale_downs: u64,
    /// Active device count at each window (after scaling).
    pub pool_trace: Vec<usize>,
    /// Billed device-hours: active devices integrated over served
    /// virtual time.
    pub device_hours: f64,
    /// Billed cost: per-device `$ / device-hour` integrated likewise.
    pub cost_usd: f64,
    /// `cost_usd` per unit of cluster goodput ($ per SLO-met
    /// inference/s) — the metric the autoscaler optimizes. `None` when
    /// the run produced no goodput at all.
    pub cost_per_goodput: Option<f64>,
    /// Launches deferred into the pending queue because no active
    /// device had room *at their window* (they retry with capped
    /// backoff — distinct from `failed_launches`, whose footprint no
    /// pool device could ever hold).
    pub deferred_launches: u64,
    /// Fault-injection telemetry: `Some` exactly when the run was
    /// built with a fault schedule (fault-free snapshots never carry
    /// the key and stay byte-identical).
    pub faults: Option<FaultsOutcome>,
}

/// The dynamic knobs a cluster was built with (all optional; the
/// builder normalizes "nothing requested" to no `DynamicsCfg` at all,
/// which keeps the static path byte-identical).
pub(crate) struct DynamicsCfg<'a> {
    pub(crate) churn: ChurnSchedule<'a>,
    pub(crate) policy: Option<Box<dyn PlacementPolicy + 'a>>,
    pub(crate) autoscaler: Option<Box<dyn Autoscaler + 'a>>,
    pub(crate) faults: Option<FaultSchedule>,
}

/// One live job: its engine member plus the placement-facing metadata
/// that must survive the member's `MemberCfg` being consumed.
/// Crate-visible (with its fields) for the `coordinator::testkit`
/// reference executor, which drives the same live-job state through a
/// deliberately naive window loop.
pub(crate) struct Live<'a> {
    /// Global job index (seed derivation, outcome ordering).
    pub(crate) job_idx: usize,
    /// Pool device index currently hosting the job.
    pub(crate) device: usize,
    pub(crate) pjob: PlacementJob,
    pub(crate) m: OpenMember<'a>,
    pub(crate) win: WindowAccum,
    pub(crate) last_obs: Option<WindowObservation>,
}

/// Why a job sits in the pending queue instead of serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum PendingKind {
    /// A churned launch that found no active device with room at its
    /// window: it waits with backoff instead of failing outright.
    Launch,
    /// A crash victim with no feasible failover destination (or
    /// failover disabled: it never retries and finalizes at run end
    /// with whatever it served before the crash).
    Failover,
}

/// A job waiting for capacity, re-attempted at window barriers with
/// capped exponential backoff (cap: [`MAX_BACKOFF_WINDOWS`]). Its
/// member exists — virtual clock parked — so a deferred launch keeps
/// the fleet-identical seed derivation of its global job index.
pub(crate) struct Pending<'a> {
    pub(crate) live: Live<'a>,
    pub(crate) kind: PendingKind,
    /// First window at which to re-attempt placement (`usize::MAX`:
    /// never — failover disabled).
    pub(crate) next_retry: usize,
    /// Current backoff in windows; doubles per failed retry, capped.
    pub(crate) backoff: usize,
}

/// Free footprint memory per pool device given the current residents.
pub(crate) fn free_mb(descs: &[DeviceDesc], lives: &[Live<'_>]) -> Vec<f64> {
    let mut free: Vec<f64> = descs.iter().map(|d| d.mem_mb).collect();
    for l in lives {
        free[l.device] -= l.pjob.mem_floor_mb;
    }
    free
}

/// The active device with the most free memory that fits `need_mb`
/// (ties break toward the lower index); `None` when nothing fits.
pub(crate) fn most_free_fit(free: &[f64], active: &[bool], need_mb: f64) -> Option<usize> {
    (0..free.len())
        .filter(|&d| active[d] && free[d] >= need_mb)
        .max_by(|&a, &b| free[a].total_cmp(&free[b]).then(b.cmp(&a)))
}

/// Serve a churning, migrating, autoscaling cluster. Mirrors
/// `fleet::run_open_devices` — same per-window admission, SM-share
/// planning, and global event calendar — but rebuilds the membership
/// plan every window, because churn, migration, and scaling may have
/// changed who runs where.
///
/// `threads > 1` parallelizes ONLY step 5 (the event loop): each
/// device's members serve on a per-device calendar, devices sharded
/// across scoped workers, and the scope join is the window barrier.
/// Steps 0-4 (faults, churn, pending retry, migration, autoscaling),
/// 6 (window close), and 7 (billing) stay serial and ordered —
/// dynamics and fault decisions see exactly the state the serial
/// engine would, so snapshots stay byte-identical at every thread
/// count.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_dynamic<'a>(
    cfg: &RunConfig,
    seed: u64,
    mut descs: Vec<DeviceDesc>,
    jobs: Vec<MemberCfg<'a>>,
    placement: String,
    assignment: Assignment,
    dynamics: DynamicsCfg<'a>,
    threads: usize,
) -> Result<ClusterOutcome, DeviceError> {
    let DynamicsCfg { churn, mut policy, mut autoscaler, faults } = dynamics;
    let parallel = threads > 1;
    let mut dyn_out = DynamicsOutcome::default();

    // Group churn events by firing window, preserving insertion order.
    let mut events_at: Vec<Vec<JobEvent<'a>>> = (0..cfg.windows).map(|_| Vec::new()).collect();
    for e in churn.events {
        let w = e.window();
        events_at[w].push(e);
    }

    // Fault schedule, likewise grouped by firing window. `fo` fills
    // unconditionally (the branches cost nothing on fault-free runs)
    // but is attached to the outcome only when faults were configured.
    let have_faults = faults.is_some();
    let failover_enabled = faults.as_ref().map_or(true, |f| f.failover);
    let mut fault_at: Vec<Vec<FaultEvent>> = (0..cfg.windows).map(|_| Vec::new()).collect();
    if let Some(f) = faults {
        for e in f.events {
            let w = e.window();
            fault_at[w].push(e);
        }
    }
    let mut fo = FaultsOutcome::default();

    // Device pool: per-device serving contexts (telemetry lives here)
    // plus the active flags the autoscaler flips. Grown devices clone
    // the pool's template card (device 0).
    let template = descs[0].spec.clone();
    let mut next_physical = descs.iter().map(|d| d.physical + 1).max().unwrap_or(0);
    let mut ctxs: Vec<DeviceCtx<'a>> = descs
        .iter()
        .map(|d| {
            DeviceCtx::new(d.mem_mb, d.perf_fraction, Partitioner::timeshare(0), cfg.windows)
        })
        .collect();
    let mut active = vec![true; descs.len()];
    // `active` means powered on AND healthy; `crashed` separates fault
    // outage from autoscaler parking so Grow never revives a dead card.
    let mut crashed = vec![false; descs.len()];
    // Per-device degrade state: (perf scale factor, windows remaining).
    let mut degrade: Vec<(f64, usize)> = vec![(1.0, 0); descs.len()];
    // Jobs waiting for capacity (deferred launches, stranded crash
    // victims), re-attempted at barriers with capped backoff.
    let mut pending: Vec<Pending<'a>> = Vec::new();

    // Live members. Global job index j keeps the fleet-identical seed
    // derivation (`seed + j`, `arrival_seed(seed, j)`) whatever device
    // a job lands on — or later migrates to.
    let mut lives: Vec<Live<'a>> = Vec::new();
    let mut ended: Vec<(usize, usize, JobOutcome)> = Vec::new();
    let mut next_job_idx = 0usize;
    for (m, &d) in jobs.into_iter().zip(&assignment.device_of) {
        let j = next_job_idx;
        next_job_idx += 1;
        let pjob = PlacementJob::from_cfg(&m);
        lives.push(Live {
            job_idx: j,
            device: d,
            pjob,
            m: new_open_member(m, cfg, seed + j as u64, arrival_seed(seed, j))?,
            win: WindowAccum::new(),
            last_obs: None,
        });
    }

    let mut calendar = EventCalendar::with_capacity(lives.len());
    let mut remaining: Vec<usize> = Vec::new();
    // Flat slot -> live index, plus the per-slot serving plan, rebuilt
    // every window (membership is no longer static).
    let mut flat: Vec<usize> = Vec::new();
    let mut plan: Vec<((u32, u32), SmShare, f64)> = Vec::new();
    // Flat slot -> pool device index (error attribution: a failing
    // run must surface the lowest failing device, whatever the thread
    // count).
    let mut slot_device: Vec<usize> = Vec::new();
    // Per-device `(start, len)` spans over `flat` / `plan` — planning
    // visits devices in pool order, so each device's slots are
    // contiguous. The parallel path serves one span per work unit.
    let mut spans: Vec<(usize, usize)> = Vec::new();
    // Span index -> pool device index, aligned with `spans`.
    let mut span_device: Vec<usize> = Vec::new();
    // Billed virtual time: the furthest-ahead member clock, monotone.
    let mut elapsed_s = 0.0f64;
    // Last window's pool pressure per device (0 while idle).
    let mut pressures: Vec<f64> = vec![0.0; descs.len()];

    for w in 0..cfg.windows {
        // -- 0. Faults: crash / degrade / repair at the barrier, before
        //       churn so launches never land on a dead card. --
        for e in std::mem::take(&mut fault_at[w]) {
            match e {
                FaultEvent::Crash { device, .. } => {
                    crashed[device] = true;
                    active[device] = false;
                    fo.crashes += 1;
                    // Evacuate residents: queued requests die with the
                    // card; the member itself fails over (weights
                    // reload on the destination) or parks pending.
                    let mut li = 0;
                    while li < lives.len() {
                        if lives[li].device != device {
                            li += 1;
                            continue;
                        }
                        fo.dropped_failure += lives[li].m.lp.fail_queue();
                        let need = lives[li].pjob.mem_floor_mb;
                        let dest = if failover_enabled {
                            let free = free_mb(&descs, &lives);
                            most_free_fit(&free, &active, need)
                        } else {
                            None
                        };
                        match dest {
                            Some(d) => {
                                let stall = model_load_ms(need);
                                let l = &mut lives[li];
                                l.m.lp.stall_ms(stall);
                                l.device = d;
                                fo.failovers += 1;
                                fo.failover_stall_ms += stall;
                                li += 1;
                            }
                            None => {
                                let live = lives.remove(li);
                                pending.push(Pending {
                                    live,
                                    kind: PendingKind::Failover,
                                    next_retry: if failover_enabled {
                                        w + 1
                                    } else {
                                        usize::MAX
                                    },
                                    backoff: 1,
                                });
                                fo.deferred_jobs += 1;
                            }
                        }
                    }
                }
                FaultEvent::Degrade { device, factor, for_windows, .. } => {
                    degrade[device] = (factor, for_windows);
                    fo.degrades += 1;
                }
                FaultEvent::Repair { device, .. } => {
                    crashed[device] = false;
                    active[device] = true;
                    fo.repairs += 1;
                }
            }
        }

        // -- 1. Churn: retire first-match live jobs, launch new ones. --
        for e in std::mem::take(&mut events_at[w]) {
            match e {
                JobEvent::Retire { job_id, .. } => {
                    // validate() guaranteed a live match exists.
                    if let Some(pos) = lives.iter().position(|l| l.m.job.id == job_id) {
                        let l = lives.remove(pos);
                        ended.push((l.job_idx, l.device, open_member_outcome(l.m)));
                        dyn_out.retires += 1;
                    }
                }
                JobEvent::Launch { job, policy: pol, arrivals, .. } => {
                    let j = next_job_idx;
                    next_job_idx += 1;
                    let cfg_m = MemberCfg::new(&job, pol, arrivals);
                    let pjob = PlacementJob::from_cfg(&cfg_m);
                    let free = free_mb(&descs, &lives);
                    let Some(d) = most_free_fit(&free, &active, pjob.mem_floor_mb) else {
                        if descs.iter().all(|dd| dd.mem_mb < pjob.mem_floor_mb) {
                            // No pool device could EVER hold the
                            // footprint: permanently infeasible.
                            dyn_out.failed_launches += 1;
                            continue;
                        }
                        // Merely no room right now: park the member
                        // (virtual clock at zero) and retry with
                        // backoff. The model-load stall is charged at
                        // actual placement.
                        let m = new_open_member(
                            cfg_m,
                            cfg,
                            seed + j as u64,
                            arrival_seed(seed, j),
                        )?;
                        pending.push(Pending {
                            live: Live {
                                job_idx: j,
                                device: usize::MAX,
                                pjob,
                                m,
                                win: WindowAccum::new(),
                                last_obs: None,
                            },
                            kind: PendingKind::Launch,
                            next_retry: w + 1,
                            backoff: 1,
                        });
                        dyn_out.deferred_launches += 1;
                        fo.deferred_jobs += 1;
                        continue;
                    };
                    let mut m =
                        new_open_member(cfg_m, cfg, seed + j as u64, arrival_seed(seed, j))?;
                    // Model load: arrivals during it become the job's
                    // first-window backlog.
                    m.lp.stall_ms(model_load_ms(pjob.mem_floor_mb));
                    lives.push(Live {
                        job_idx: j,
                        device: d,
                        pjob,
                        m,
                        win: WindowAccum::new(),
                        last_obs: None,
                    });
                    dyn_out.launches += 1;
                }
            }
        }

        // -- 2. Pending retry: deferred launches and stranded crash
        //       victims due this window re-attempt placement; misses
        //       double their backoff (capped). --
        let mut pi = 0;
        while pi < pending.len() {
            if pending[pi].next_retry > w {
                pi += 1;
                continue;
            }
            let need = pending[pi].live.pjob.mem_floor_mb;
            let free = free_mb(&descs, &lives);
            match most_free_fit(&free, &active, need) {
                Some(d) => {
                    let p = pending.remove(pi);
                    let mut live = p.live;
                    let stall = model_load_ms(need);
                    live.m.lp.stall_ms(stall);
                    live.device = d;
                    match p.kind {
                        PendingKind::Launch => dyn_out.launches += 1,
                        PendingKind::Failover => {
                            fo.failovers += 1;
                            fo.failover_stall_ms += stall;
                        }
                    }
                    lives.push(live);
                }
                None => {
                    let p = &mut pending[pi];
                    p.backoff = (p.backoff * 2).min(MAX_BACKOFF_WINDOWS);
                    p.next_retry = w + p.backoff;
                    pi += 1;
                }
            }
        }

        // -- 3. Live migration: the policy may re-place the survivors. --
        if let Some(pol) = policy.as_mut() {
            // The policy sees only the active slice of the pool.
            let active_idx: Vec<usize> = (0..descs.len()).filter(|&d| active[d]).collect();
            let active_descs: Vec<DeviceDesc> =
                active_idx.iter().map(|&d| descs[d].clone()).collect();
            let pjobs: Vec<PlacementJob> = lives.iter().map(|l| l.pjob.clone()).collect();
            let current: Vec<usize> = lives
                .iter()
                .map(|l| {
                    active_idx.iter().position(|&d| d == l.device).unwrap_or(0)
                })
                .collect();
            let obs: Vec<WindowObservation> = lives
                .iter()
                .map(|l| l.last_obs.unwrap_or_else(|| blank_obs(w)))
                .collect();
            if let Some(proposal) = pol.replace(&pjobs, &active_descs, &current, &obs) {
                let a = Assignment { device_of: proposal };
                if a.validate(&pjobs, &active_descs).is_ok() {
                    for (l, &to_active) in lives.iter_mut().zip(&a.device_of) {
                        let to = active_idx[to_active];
                        if to != l.device {
                            let stall = model_load_ms(l.pjob.mem_floor_mb);
                            l.m.lp.stall_ms(stall);
                            l.device = to;
                            dyn_out.migrations += 1;
                            dyn_out.migration_stall_ms += stall;
                        }
                    }
                } else {
                    dyn_out.rejected_proposals += 1;
                }
            }
        }

        // -- 4. Autoscaling on last window's pressure. --
        if let Some(scaler) = autoscaler.as_mut() {
            let n_active = active.iter().filter(|&&a| a).count();
            let (sum_p, max_p) = (0..descs.len()).filter(|&d| active[d]).fold(
                (0.0f64, 0.0f64),
                |(s, mx), d| (s + pressures[d], mx.max(pressures[d])),
            );
            // Decide inside a block so the observation's borrows of the
            // pool end before the arms mutate it.
            let action = {
                let obs = PoolObservation {
                    window: w,
                    active_devices: n_active,
                    live_jobs: lives.len(),
                    mean_pressure: if n_active > 0 { sum_p / n_active as f64 } else { 0.0 },
                    max_pressure: max_p,
                    queue_depth: lives.iter().map(|l| l.m.lp.queue_len()).sum(),
                    drops: lives
                        .iter()
                        .filter_map(|l| l.last_obs.as_ref())
                        .map(|o| o.drops + o.drops_deadline)
                        .sum(),
                    class_queue: {
                        let mut q = [0usize; 3];
                        for l in &lives {
                            if let Some(c) = l.m.slo_class {
                                q[c.index()] += l.m.lp.queue_len();
                            }
                        }
                        q
                    },
                    devices: &descs,
                    active: &active,
                };
                scaler.scale(&obs)
            };
            match action {
                ScaleAction::Hold => {}
                ScaleAction::Grow => {
                    // Re-activate the lowest-index parked device —
                    // never a crashed one — else rent a fresh template
                    // card.
                    if let Some(d) = (0..descs.len()).find(|&d| !active[d] && !crashed[d]) {
                        active[d] = true;
                    } else {
                        let desc = whole_desc(template.clone(), next_physical);
                        next_physical += 1;
                        ctxs.push(DeviceCtx::new(
                            desc.mem_mb,
                            desc.perf_fraction,
                            Partitioner::timeshare(0),
                            cfg.windows,
                        ));
                        descs.push(desc);
                        active.push(true);
                        crashed.push(false);
                        degrade.push((1.0, 0));
                        pressures.push(0.0);
                    }
                    dyn_out.scale_ups += 1;
                }
                ScaleAction::Shrink => {
                    // Victim: the active device hosting the fewest jobs
                    // (ties toward the higher index — drain newest
                    // first). Evacuation must fit or the shrink is off.
                    let victim = (0..descs.len()).filter(|&d| active[d]).min_by_key(|&d| {
                        (lives.iter().filter(|l| l.device == d).count(), usize::MAX - d)
                    });
                    if let Some(v) = victim {
                        if try_evacuate(v, &descs, &active, &mut lives, &mut dyn_out) {
                            active[v] = false;
                            dyn_out.scale_downs += 1;
                        }
                    }
                }
            }
        }
        dyn_out.pool_trace.push(active.iter().filter(|&&a| a).count());
        fo.pool_health.push((0..descs.len()).filter(|&d| !crashed[d]).count());

        // -- 5. Serve the window: per-device admission + shares, then
        //       one global event loop (run_open_devices, membership
        //       edition). --
        calendar.clear();
        flat.clear();
        plan.clear();
        slot_device.clear();
        spans.clear();
        span_device.clear();
        for p in pressures.iter_mut() {
            *p = 0.0;
        }
        // Stable per-window grouping: devices in pool order, members in
        // live order (insertion order — initial jobs then launches).
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); descs.len()];
        for (li, l) in lives.iter().enumerate() {
            groups[l.device].push(li);
        }
        for d in 0..descs.len() {
            if groups[d].is_empty() {
                continue;
            }
            let ctx = &mut ctxs[d];
            let members = &groups[d];
            let requested: Vec<(u32, u32)> = members
                .iter()
                .map(|&li| lives[li].m.policy.operating_point())
                .collect();
            // Admission weights rebuild every window: churn, migration,
            // and failover change who shares the device, so a cached
            // per-device weight vector would go stale. None when no
            // resident is classed keeps the unclassed path literal.
            let weights: Option<Vec<f64>> = members
                .iter()
                .any(|&li| lives[li].m.slo_class.is_some())
                .then(|| {
                    members
                        .iter()
                        .map(|&li| lives[li].m.slo_class.map_or(1.0, SloClass::shed_weight))
                        .collect()
                });
            let pts = admit_window(
                &|i, (bs, mtl)| lives[members[i]].m.sim.mem_demand_mb(bs, mtl),
                members.len(),
                &requested,
                weights.as_deref(),
                ctx.mem_capacity_mb,
                &mut ctx.admission_clamps,
            )?;
            // Degradation scales the granted perf model: the members
            // temporarily see a smaller SM grant, exactly like a MIG
            // slice. Healthy devices keep g == perf_fraction bit-exact
            // (x * 1.0 == x), so fault-free runs stay byte-identical.
            let g = ctx.perf_fraction * degrade[d].0;
            let shr = ctx.parts.window_shares(
                || {
                    members
                        .iter()
                        .zip(&pts)
                        .map(|(&li, &(bs, mtl))| {
                            let sim = &lives[li].m.sim;
                            if g >= 1.0 {
                                sim.sm_utilization(bs, mtl)
                            } else {
                                sim.sm_utilization_granted(bs, mtl, g)
                            }
                        })
                        .sum()
                },
                members.len(),
                g,
                &mut ctx.peak_contention,
                &mut ctx.contention_trace,
                &mut ctx.grant_trace,
            )?;
            pressures[d] = ctx.contention_trace.last().copied().unwrap_or(0.0);
            let resident: f64 = members
                .iter()
                .zip(&pts)
                .map(|(&li, &(bs, mtl))| lives[li].m.sim.mem_demand_mb(bs, mtl))
                .sum();
            ctx.peak_mem_mb = ctx.peak_mem_mb.max(resident);
            let span_start = flat.len();
            for ((&li, &pt), sh) in members.iter().zip(&pts).zip(shr) {
                let l = &mut lives[li];
                let slo = l.m.schedule.at(w);
                l.win.begin(&l.m.lp);
                let f = flat.len();
                flat.push(li);
                plan.push((pt, sh, slo));
                slot_device.push(d);
                if remaining.len() <= f {
                    remaining.push(0);
                }
                remaining[f] = cfg.rounds_per_window;
                if !parallel {
                    calendar.push(f, l.m.lp.now_s);
                }
            }
            spans.push((span_start, flat.len() - span_start));
            span_device.push(d);
        }

        if parallel {
            serve_spans_parallel(cfg, &mut lives, &flat, &plan, &spans, &span_device, threads)
                .map_err(|f| f.error)?;
        } else {
            // Serving failures go per-device: a failing device's stale
            // calendar entries drain unserved while the others finish
            // the window, and the lowest failing device index's error
            // surfaces — exactly what the sharded path reports, so the
            // error a run returns is thread-count-independent.
            let mut failed: Vec<Option<DeviceError>> = vec![None; descs.len()];
            while let Some(f) = calendar.pop() {
                let d = slot_device[f];
                if failed[d].is_some() {
                    continue;
                }
                remaining[f] -= 1;
                let l = &mut lives[flat[f]];
                let (pt, sh, slo) = plan[f];
                match l.m.lp.serve_round(pt, slo, sh, &mut l.m.sim, &mut l.win) {
                    Ok(more) => {
                        if more && remaining[f] > 0 {
                            calendar.push(f, l.m.lp.now_s);
                        }
                    }
                    Err(e) => failed[d] = Some(e),
                }
            }
            if let Some(e) = failed.into_iter().flatten().next() {
                return Err(e);
            }
        }

        // -- 6. Close the window per member (same sequence as the
        //       static loop) and record the boundary observations. --
        for (f, &li) in flat.iter().enumerate() {
            let l = &mut lives[li];
            let (pt, _, slo) = plan[f];
            l.m.admitted = pt;
            let (record, obs) = l.win.finish(w, slo, pt, &l.m.lp);
            l.m.acc.absorb(w, slo, l.win.latencies());
            l.m.latencies.extend(l.win.latencies().iter().map(|&lat| (lat, 1.0)));
            l.m.trace.push(record);
            l.m.policy.observe(&obs);
            l.last_obs = Some(obs);
        }

        // -- 7. Bill the window: active devices * advanced virtual time.
        let now_max = lives.iter().map(|l| l.m.lp.now_s).fold(elapsed_s, f64::max);
        let span_h = (now_max - elapsed_s) / 3600.0;
        elapsed_s = now_max;
        for d in 0..descs.len() {
            if active[d] {
                dyn_out.device_hours += span_h;
                dyn_out.cost_usd += descs[d].price_per_hour * span_h;
            }
        }

        // Degrade timers tick per served window; an expired timer
        // restores full speed (an event at window w covers windows
        // w .. w + for_windows - 1).
        for dg in degrade.iter_mut() {
            if dg.1 > 0 {
                dg.1 -= 1;
                if dg.1 == 0 {
                    dg.0 = 1.0;
                }
            }
        }
    }

    // Jobs still pending at run end: deferred launches never served
    // (dropped from the outcomes like permanently infeasible ones);
    // stranded crash victims finalize with whatever they served before
    // their device died.
    for p in pending {
        match p.kind {
            PendingKind::Launch => dyn_out.failed_launches += 1,
            PendingKind::Failover => {
                ended.push((p.live.job_idx, p.live.device, open_member_outcome(p.live.m)));
            }
        }
    }

    // Survivors finish with the run.
    for l in lives {
        ended.push((l.job_idx, l.device, open_member_outcome(l.m)));
    }
    ended.sort_by_key(|&(j, _, _)| j);

    // Final device-of-job assignment over every job that ever served
    // (launched jobs append after the initial ones; failed launches
    // never enter).
    let device_of: Vec<usize> = ended.iter().map(|&(_, d, _)| d).collect();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); descs.len()];
    let mut outs: Vec<Vec<JobOutcome>> = (0..descs.len()).map(|_| Vec::new()).collect();
    for (j, d, out) in ended {
        groups[d].push(j);
        outs[d].push(out);
    }
    let devices: Vec<DeviceOutcome> = descs
        .iter()
        .zip(groups)
        .zip(ctxs.into_iter().zip(outs))
        .map(|((desc, group), (ctx, members))| DeviceOutcome {
            device: desc.clone(),
            jobs: group,
            fleet: finish_fleet(members, ctx, PartitionMode::TimeShare),
        })
        .collect();
    let total_throughput = devices.iter().map(|d| d.fleet.total_throughput).sum();
    let total_goodput: f64 = devices.iter().map(|d| d.fleet.total_goodput).sum();
    dyn_out.cost_per_goodput =
        (total_goodput > 0.0).then(|| dyn_out.cost_usd / total_goodput);
    if have_faults {
        dyn_out.faults = Some(fo);
    }
    let slo = merge_slo_reports(&devices);
    let out = ClusterOutcome {
        devices,
        placement,
        assignment: device_of,
        total_throughput,
        total_goodput,
        dynamics: Some(dyn_out),
        slo,
    };
    debug_assert!(out.audit().is_ok(), "dynamic run broke conservation: {:?}", out.audit());
    Ok(out)
}

/// Serve one window's event loops data-parallel: one work unit per
/// device span (disjoint `&mut Live` sets gathered through an
/// option-take over the live list), units sharded contiguously across
/// scoped workers. Joining the scope is the window barrier — step 5
/// (window close) and the next boundary's dynamics never observe a
/// half-served window.
///
/// On error runs every shard reports its first failing span; spans are
/// in pool-device order, so the minimum span index across shards is the
/// lowest failing device — the same failure the serial calendar path
/// surfaces, at every thread count (`span_device` maps it back to the
/// pool device index).
pub(crate) fn serve_spans_parallel<'a>(
    cfg: &RunConfig,
    lives: &mut [Live<'a>],
    flat: &[usize],
    plan: &[((u32, u32), SmShare, f64)],
    spans: &[(usize, usize)],
    span_device: &[usize],
    threads: usize,
) -> Result<(), DeviceFailure> {
    // Hand out disjoint mutable borrows: every live index appears in at
    // most one span, so each take() succeeds exactly once per window.
    let mut slots: Vec<Option<&mut Live<'a>>> = lives.iter_mut().map(Some).collect();
    let mut units: Vec<(Vec<&mut Live<'a>>, &[((u32, u32), SmShare, f64)])> = spans
        .iter()
        .map(|&(start, len)| {
            let members: Vec<&mut Live<'a>> = flat[start..start + len]
                .iter()
                .map(|&li| slots[li].take().expect("live job served once per window"))
                .collect();
            (members, &plan[start..start + len])
        })
        .collect();
    let fail = |span: usize, error: DeviceError| DeviceFailure {
        device: span_device[span],
        error,
    };
    let shards = shard_count(threads, units.len());
    if shards <= 1 {
        for (u, (members, plan)) in units.iter_mut().enumerate() {
            serve_device_span(cfg, members, plan).map_err(|e| fail(u, e))?;
        }
        return Ok(());
    }
    let chunk = units.len().div_ceil(shards);
    // Each shard's first failing span (spans serve in order within a
    // shard), reported with its shard-local index.
    let results: Vec<Result<(), (usize, DeviceError)>> = std::thread::scope(|s| {
        let handles: Vec<_> = units
            .chunks_mut(chunk)
            .map(|shard| {
                s.spawn(move || -> Result<(), (usize, DeviceError)> {
                    for (u, (members, plan)) in shard.iter_mut().enumerate() {
                        serve_device_span(cfg, members, plan).map_err(|e| (u, e))?;
                    }
                    Ok(())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("dynamics shard worker panicked")).collect()
    });
    results
        .into_iter()
        .enumerate()
        .filter_map(|(sh, r)| r.err().map(|(u, e)| (sh * chunk + u, e)))
        .min_by_key(|&(span, _)| span)
        .map_or(Ok(()), |(span, e)| Err(fail(span, e)))
}

/// One device's event loop for one window, on a per-device calendar.
/// Local member index == within-device flat order, so ties break exactly
/// as the global serial calendar breaks them — every member serves the
/// identical round sequence.
fn serve_device_span(
    cfg: &RunConfig,
    members: &mut [&mut Live<'_>],
    plan: &[((u32, u32), SmShare, f64)],
) -> Result<(), DeviceError> {
    let mut calendar = EventCalendar::with_capacity(members.len());
    let mut remaining = vec![cfg.rounds_per_window; members.len()];
    for (k, l) in members.iter().enumerate() {
        calendar.push(k, l.m.lp.now_s);
    }
    while let Some(k) = calendar.pop() {
        remaining[k] -= 1;
        let l = &mut *members[k];
        let (pt, sh, slo) = plan[k];
        let more = l.m.lp.serve_round(pt, slo, sh, &mut l.m.sim, &mut l.win)?;
        if more && remaining[k] > 0 {
            calendar.push(k, l.m.lp.now_s);
        }
    }
    Ok(())
}

/// A neutral observation for jobs that have not served a window yet
/// (launched this very boundary).
pub(crate) fn blank_obs(window: usize) -> WindowObservation {
    WindowObservation {
        window,
        slo_ms: 0.0,
        p95_ms: 0.0,
        mean_ms: 0.0,
        throughput: 0.0,
        power_w: 0.0,
        sm_util: 0.0,
        queue_depth: 0,
        arrival_rate: 0.0,
        drops: 0,
        drops_deadline: 0,
    }
}

/// Move every job off device `victim` onto the remaining active
/// devices, most-free-fit per job in live order, charging each move as
/// a migration. All-or-nothing: when any evacuee does not fit, nothing
/// moves and the shrink is refused (`false`) — the pool can never
/// shrink below its live jobs' memory demand.
pub(crate) fn try_evacuate(
    victim: usize,
    descs: &[DeviceDesc],
    active: &[bool],
    lives: &mut [Live<'_>],
    dyn_out: &mut DynamicsOutcome,
) -> bool {
    let mut free = free_mb(descs, lives);
    let mut moves: Vec<(usize, usize)> = Vec::new();
    for (li, l) in lives.iter().enumerate() {
        if l.device != victim {
            continue;
        }
        let fits = (0..descs.len())
            .filter(|&d| active[d] && d != victim && free[d] >= l.pjob.mem_floor_mb)
            .max_by(|&a, &b| free[a].total_cmp(&free[b]).then(b.cmp(&a)));
        let Some(d) = fits else {
            return false;
        };
        free[d] -= l.pjob.mem_floor_mb;
        moves.push((li, d));
    }
    for (li, d) in moves {
        let stall = model_load_ms(lives[li].pjob.mem_floor_mb);
        lives[li].m.lp.stall_ms(stall);
        lives[li].device = d;
        dyn_out.migrations += 1;
        dyn_out.migration_stall_ms += stall;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;
    use crate::gpusim::{TESLA_P4, TESLA_P40, TESLA_T4};

    #[test]
    fn live_jobs_are_send_for_span_workers() {
        // serve_spans_parallel moves `&mut Live` sets onto scoped
        // worker threads; keep that a compile-time guarantee.
        fn assert_send<T: Send>() {}
        assert_send::<Live<'static>>();
    }

    #[test]
    fn price_catalogue_covers_the_gpus() {
        assert_eq!(price_per_hour(&TESLA_P40), 1.20);
        assert_eq!(price_per_hour(&TESLA_T4), 0.53);
        assert_eq!(price_per_hour(&TESLA_P4), 0.60);
    }

    #[test]
    fn model_load_grows_with_footprint() {
        assert_eq!(model_load_ms(0.0), 2000.0);
        assert!(model_load_ms(1000.0) > model_load_ms(100.0));
    }

    #[test]
    fn churn_schedule_validation() {
        let job = paper_job(1).unwrap();
        // Window out of range.
        let s = ChurnSchedule::new().retire(9, job.id);
        assert!(matches!(
            s.validate(4, &[job.id]),
            Err(ConfigError::BadChurn { .. })
        ));
        // Retire of a job that is never live.
        let s = ChurnSchedule::new().retire(1, 999);
        assert!(matches!(s.validate(4, &[job.id]), Err(ConfigError::BadChurn { .. })));
        // Retire of an earlier launch is fine; a second retire of the
        // same id is not.
        let launch_ok = |s: ChurnSchedule| {
            s.launch(
                1,
                job,
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(10.0),
            )
        };
        let s = launch_ok(ChurnSchedule::new()).retire(2, job.id).retire(3, job.id);
        assert!(s.validate(6, &[]).is_err());
        let s = launch_ok(ChurnSchedule::new()).retire(2, job.id);
        assert!(s.validate(6, &[]).is_ok());
        // Closed-loop launches are refused.
        let s = ChurnSchedule::new().launch(
            1,
            job,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::closed(),
        );
        assert!(matches!(s.validate(4, &[]), Err(ConfigError::BadChurn { .. })));
        // Unknown DNNs are caught at build time, not at launch time.
        let mut bogus = *job;
        bogus.dnn = "vgg16";
        let s = ChurnSchedule::new().launch(
            1,
            &bogus,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(10.0),
        );
        assert_eq!(s.validate(4, &[]), Err(ConfigError::UnknownDnn { dnn: "vgg16".into() }));
    }

    #[test]
    fn threshold_autoscaler_respects_bounds() {
        let descs = vec![whole_desc(TESLA_P40, 0)];
        let active = vec![true];
        let mut s = ThresholdAutoscaler::new(1, 3);
        let obs = |pressure: f64, n: usize| PoolObservation {
            window: 1,
            active_devices: n,
            live_jobs: 2,
            mean_pressure: pressure,
            max_pressure: pressure,
            queue_depth: 0,
            drops: 0,
            class_queue: [0; 3],
            devices: &descs,
            active: &active,
        };
        assert_eq!(s.scale(&obs(2.0, 1)), ScaleAction::Grow);
        assert_eq!(s.scale(&obs(2.0, 3)), ScaleAction::Hold, "at max: must not grow");
        assert_eq!(s.scale(&obs(0.1, 1)), ScaleAction::Hold, "at min: must not shrink");
        assert_eq!(s.scale(&obs(0.1, 2)), ScaleAction::Shrink);
        assert_eq!(s.scale(&obs(0.5, 2)), ScaleAction::Hold);
        assert_eq!(s.scale(&obs(0.0, 0)), ScaleAction::Grow, "below min: grow back");
    }

    #[test]
    fn periodic_replace_fires_on_period_and_skips_no_ops() {
        use crate::coordinator::cluster::RoundRobin;
        let job = paper_job(1).unwrap();
        let pjob = PlacementJob {
            spec: *job,
            mem_floor_mb: 100.0,
            sm_demand: 0.2,
            mean_rate: 10.0,
            burstiness: 1.0,
        };
        let descs = vec![whole_desc(TESLA_P40, 0), whole_desc(TESLA_P40, 1)];
        let jobs = vec![pjob.clone(), pjob];
        let mut p = PeriodicReplace::new(RoundRobin::new(), 2);
        assert_eq!(p.name(), "rr");
        // Window 1: off-period. Window 2: proposes rr = [0, 1]; current
        // already matches -> None. Window 4: current differs -> Some.
        assert_eq!(p.replace(&jobs, &descs, &[0, 1], &[]), None);
        assert_eq!(p.replace(&jobs, &descs, &[0, 1], &[]), None);
        assert_eq!(p.replace(&jobs, &descs, &[0, 1], &[]), None);
        assert_eq!(p.replace(&jobs, &descs, &[1, 1], &[]), Some(vec![0, 1]));
    }
}
