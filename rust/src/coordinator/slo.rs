//! SLO-class serving and the paper's combined-knob search (§4.6).
//!
//! Two halves, one subsystem:
//!
//! * **Service classes.** [`SloClass`] (Gold / Silver / BestEffort)
//!   attaches to a fleet or cluster member. Each class carries a
//!   *deadline multiplier* (`shed_scale`) applied to the member's
//!   effective shedding deadline — best-effort work is shed at half the
//!   deadline, silver at three quarters, gold at the full deadline — and
//!   a *shedding weight* (`shed_weight`) used by memory-overload
//!   admission: when the device must shrink someone, the lowest-weight
//!   classes shrink first (best-effort before silver before gold).
//!   Gold's multiplier is exactly 1.0 and its weight ties with the
//!   unclassed default, so an all-gold (or unclassed) run is
//!   byte-identical to a run with no classes at all. Per-class goodput
//!   and shed totals aggregate into an [`SloReport`] that appears in
//!   snapshots only when at least one member is classed.
//!
//! * **Combined knob search.** [`CombinedPolicy`] implements the paper's
//!   joint Batching + Multi-Tenancy search as one policy: per window it
//!   scores candidate `(batch_size, instances)` moves against observed
//!   p95-vs-deadline headroom and picks the feasible move maximizing
//!   projected (class-weighted) goodput, learning each knob's marginal
//!   throughput gain from realized moves. [`ClassPartition`] adds the
//!   third knob the paper didn't have — per-member SM partition share —
//!   as a [`PartitionPolicy`] whose demand waterfill is class-weighted.
//!   With partitioning off the pair degrades to the paper's two-knob
//!   search, and with one knob ceiling at 1 to the single-knob scalers.
//!
//! Determinism contract: every decision here is a pure function of the
//! observation stream (fixed candidate order, `total_cmp` argmax, no
//! RNG), so classed runs stay byte-identical across thread counts just
//! like unclassed ones. See `docs/slo.md`.

use std::fmt;

use crate::gpusim::MIN_GRANT;

use super::policy::{Action, PartitionPolicy, Policy, WindowObservation};

/// Per-member service class: how important this member's requests are
/// when the device is overloaded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// Full deadline, sheds last, heaviest admission weight.
    Gold,
    /// 0.75x deadline, sheds after best-effort.
    Silver,
    /// 0.5x deadline, first to shed and first to shrink under pressure.
    BestEffort,
}

impl SloClass {
    /// Every class, in shedding-priority order (last to shed first).
    pub const ALL: [SloClass; 3] = [SloClass::Gold, SloClass::Silver, SloClass::BestEffort];

    /// Multiplier applied to the member's effective shedding deadline.
    /// Gold is exactly 1.0 so an all-gold pool is bit-identical to an
    /// unclassed one (`x * 1.0 == x` for every finite f64).
    pub fn shed_scale(self) -> f64 {
        match self {
            SloClass::Gold => 1.0,
            SloClass::Silver => 0.75,
            SloClass::BestEffort => 0.5,
        }
    }

    /// Admission weight: under memory pressure, members of the lowest
    /// weight present shrink first. Unclassed members weigh the same as
    /// gold, so mixing unclassed and gold members changes nothing.
    pub fn shed_weight(self) -> f64 {
        match self {
            SloClass::Gold => 8.0,
            SloClass::Silver => 4.0,
            SloClass::BestEffort => 1.0,
        }
    }

    /// Stable index (Gold 0, Silver 1, BestEffort 2) for per-class
    /// accumulator arrays.
    pub fn index(self) -> usize {
        match self {
            SloClass::Gold => 0,
            SloClass::Silver => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Short letter used by the CLI (`--slo-class g,s,b`) and the fuzz
    /// corpus canon (`slo=g`).
    pub fn letter(self) -> &'static str {
        match self {
            SloClass::Gold => "g",
            SloClass::Silver => "s",
            SloClass::BestEffort => "b",
        }
    }

    /// Human-readable name for reports.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Gold => "gold",
            SloClass::Silver => "silver",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// Parse a CLI/corpus token. Accepts the letter or the full name
    /// (`g`/`gold`, `s`/`silver`, `b`/`be`/`besteffort`/`best-effort`).
    pub fn parse(s: &str) -> Result<SloClass, ParseSloClassError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "g" | "gold" => Ok(SloClass::Gold),
            "s" | "silver" => Ok(SloClass::Silver),
            "b" | "be" | "besteffort" | "best-effort" => Ok(SloClass::BestEffort),
            _ => Err(ParseSloClassError { token: s.to_string() }),
        }
    }
}

impl fmt::Display for SloClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why an SLO-class token was rejected: names the offending token so a
/// typo like `--slo-class g,x` fails loudly at the CLI boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseSloClassError {
    pub token: String,
}

impl fmt::Display for ParseSloClassError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown SLO class {:?} (expected g/gold, s/silver, or b/best-effort)",
            self.token
        )
    }
}

impl std::error::Error for ParseSloClassError {}

/// Per-class outcome totals for one class.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassStat {
    /// Members carrying this class.
    pub members: usize,
    /// Summed goodput (inf/s meeting the SLO) across those members.
    pub goodput: f64,
    /// Summed deadline-shed request count across those members.
    pub shed: u64,
}

/// Per-class aggregation over a fleet or cluster outcome. Built only
/// when at least one member carries a class, so unclassed snapshots do
/// not change by a single byte.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SloReport {
    /// Indexed by [`SloClass::index`]; classes with no members stay zero.
    pub per_class: [ClassStat; 3],
}

impl SloReport {
    /// Aggregate `(class, goodput, shed)` member rows; `None` when no
    /// member is classed (the snapshot key must then be absent).
    pub fn from_members<I>(members: I) -> Option<SloReport>
    where
        I: IntoIterator<Item = (Option<SloClass>, f64, u64)>,
    {
        let mut any = false;
        let mut report = SloReport::default();
        for (class, goodput, shed) in members {
            let Some(c) = class else { continue };
            any = true;
            let stat = &mut report.per_class[c.index()];
            stat.members += 1;
            stat.goodput += goodput;
            stat.shed += shed;
        }
        any.then_some(report)
    }

    /// Totals for one class.
    pub fn class(&self, c: SloClass) -> ClassStat {
        self.per_class[c.index()]
    }

    /// Fold another report into this one (cluster = sum of fleets).
    pub fn merge(&mut self, other: &SloReport) {
        for (a, b) in self.per_class.iter_mut().zip(&other.per_class) {
            a.members += b.members;
            a.goodput += b.goodput;
            a.shed += b.shed;
        }
    }

    /// True when no class has any member (merge target convenience).
    pub fn is_empty(&self) -> bool {
        self.per_class.iter().all(|s| s.members == 0)
    }
}

/// The paper's combined Batching + Multi-Tenancy search (§4.6) as one
/// first-class [`Policy`].
///
/// Where `BatchScaler` and `MtScaler` each turn one knob and `Clipper`
/// turns batch size alone, `CombinedPolicy` searches the joint
/// `(batch_size, instances)` space. Each window it:
///
/// 1. updates EWMAs of offered arrival rate and served throughput, and
///    *learns* each knob's marginal gain from the last realized move
///    (throughput ratio after a bs doubling / an added instance);
/// 2. computes the p95-vs-deadline headroom;
/// 3. enumerates candidate moves in a fixed order — hold, double bs,
///    halve bs, add an instance, drop an instance — projecting each
///    candidate's throughput (learned gains) and tail latency (knob
///    latency multipliers);
/// 4. when the tail already violates the deadline, takes the shrink move
///    that keeps the most projected throughput; when demand outruns
///    capacity and headroom allows, takes the feasible growth move
///    maximizing projected (class-weighted) goodput; after sustained
///    calm, gives back the cheapest knob.
///
/// The member's class weight is a constant factor in the score, so it
/// never flips a single-member argmax — `resolve_policy` builds the
/// policy with weight 1.0 — but it is part of the scoring contract so a
/// fleet-level arbiter comparing scores *across* members weighs gold
/// above best-effort. All arithmetic is deterministic: fixed candidate
/// order, `total_cmp`, no randomness.
#[derive(Debug, Clone)]
pub struct CombinedPolicy {
    bs: u32,
    mtl: u32,
    max_bs: u32,
    max_mtl: u32,
    /// Class weight, a constant score factor (see type docs).
    weight: f64,
    /// EWMA of the offered arrival rate (requests/s).
    rate_ewma: f64,
    /// EWMA of the served throughput (capacity proxy).
    serve_ewma: f64,
    /// Learned throughput multiplier of one bs doubling, clamped to
    /// [1.0, 2.0] (doubling bs can at best double throughput).
    gain_bs: f64,
    /// Learned throughput multiplier of one added instance, clamped to
    /// [1.0, 2.0].
    gain_mt: f64,
    /// Operating point during the window just observed (for learning).
    last_point: (u32, u32),
    /// Throughput of the window before that, at `last_point`'s
    /// predecessor.
    prev_thr: f64,
    last_depth: usize,
    /// Consecutive calm windows (empty queue, comfortable tail).
    calm: u32,
}

/// Projected p95 multiplier of doubling the batch size (batch latency
/// grows close to linearly in bs past the saturation knee, but queueing
/// delay per request halves; 1.7 is the conservative fit).
const LAT_BS: f64 = 1.7;
/// Projected p95 multiplier of co-locating one more instance (SM
/// contention, sublinear: instances time-slice).
const LAT_MT: f64 = 1.25;

impl CombinedPolicy {
    /// Combined search up to the given knob ceilings, weight 1.0.
    pub fn new(max_bs: u32, max_mtl: u32) -> Self {
        Self::with_weight(max_bs, max_mtl, 1.0)
    }

    /// Combined search with an explicit class weight (see type docs).
    pub fn with_weight(max_bs: u32, max_mtl: u32, weight: f64) -> Self {
        assert!(max_bs >= 1 && max_mtl >= 1, "knob ceilings must be >= 1");
        assert!(weight.is_finite() && weight > 0.0, "weight must be positive");
        CombinedPolicy {
            bs: 1,
            mtl: 1,
            max_bs,
            max_mtl,
            weight,
            rate_ewma: 0.0,
            serve_ewma: 0.0,
            // Optimistic priors: batching starts believed slightly more
            // efficient than multi-tenancy (the paper's Fig. 1 shape);
            // realized moves correct both within a few windows.
            gain_bs: 1.6,
            gain_mt: 1.4,
            last_point: (1, 1),
            prev_thr: 0.0,
            last_depth: 0,
            calm: 0,
        }
    }

    /// Update the learned gain for the knob the last move turned, from
    /// the realized throughput ratio across the move.
    fn learn(&mut self, thr_now: f64) {
        const BETA: f64 = 0.5;
        let (pbs, pmtl) = self.last_point;
        if self.prev_thr > 0.0 && thr_now > 0.0 {
            let realized = thr_now / self.prev_thr;
            if self.bs > pbs && self.mtl == pmtl {
                self.gain_bs = (BETA * realized + (1.0 - BETA) * self.gain_bs).clamp(1.0, 2.0);
            } else if self.bs < pbs && self.mtl == pmtl {
                // Shrink realizes the inverse ratio.
                let inv = (1.0 / realized).clamp(1.0, 2.0);
                self.gain_bs = (BETA * inv + (1.0 - BETA) * self.gain_bs).clamp(1.0, 2.0);
            } else if self.mtl > pmtl && self.bs == pbs {
                self.gain_mt = (BETA * realized + (1.0 - BETA) * self.gain_mt).clamp(1.0, 2.0);
            } else if self.mtl < pmtl && self.bs == pbs {
                let inv = (1.0 / realized).clamp(1.0, 2.0);
                self.gain_mt = (BETA * inv + (1.0 - BETA) * self.gain_mt).clamp(1.0, 2.0);
            }
        }
    }

    fn set(&mut self, bs: u32, mtl: u32) -> Action {
        self.calm = 0;
        if (bs, mtl) == (self.bs, self.mtl) {
            return Action::Hold;
        }
        self.bs = bs;
        self.mtl = mtl;
        Action::SetPoint { bs, mtl }
    }
}

impl Policy for CombinedPolicy {
    fn name(&self) -> &'static str {
        "combined"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.bs, self.mtl)
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        const BETA: f64 = 0.5;
        if obs.window == 0 {
            self.rate_ewma = obs.arrival_rate;
            self.serve_ewma = obs.throughput;
        } else {
            self.rate_ewma = BETA * obs.arrival_rate + (1.0 - BETA) * self.rate_ewma;
            self.serve_ewma = BETA * obs.throughput + (1.0 - BETA) * self.serve_ewma;
        }
        self.learn(obs.throughput);
        self.prev_thr = obs.throughput;
        self.last_point = (self.bs, self.mtl);

        let growing = obs.queue_depth > self.last_depth;
        self.last_depth = obs.queue_depth;
        let deadline = obs.slo_ms;
        let p95 = obs.p95_ms.max(1e-3);

        // Tail already violates the deadline: shrink the knob that keeps
        // the most projected throughput (score = weight * thr / gain of
        // the knob given back). Fixed order bs-then-mtl; strict `>` so
        // ties shrink bs (the cheaper move — no relaunch).
        if p95 > deadline {
            let thr = obs.throughput.max(1e-9);
            let mut best: Option<((u32, u32), f64)> = None;
            if self.bs > 1 {
                best = Some((((self.bs / 2).max(1), self.mtl), self.weight * thr / self.gain_bs));
            }
            if self.mtl > 1 {
                let score = self.weight * thr / self.gain_mt;
                if best.as_ref().map_or(true, |(_, s)| score > *s) {
                    best = Some(((self.bs, self.mtl - 1), score));
                }
            }
            return match best {
                Some(((bs, mtl), _)) => self.set(bs, mtl),
                None => Action::Hold, // already at (1,1): nothing to give back
            };
        }

        // Demand signals (same proactive triad as QueuePolicy): backlog,
        // drops of any kind, or offered rate outrunning service while
        // the queue grows.
        let batch = (self.bs as usize) * (self.mtl as usize);
        let backlog = obs.queue_depth > 2 * batch;
        let starved = obs.drops > 0 || obs.drops_deadline > 0;
        let demand = growing && self.rate_ewma > self.serve_ewma * 1.05;
        if backlog || starved || demand {
            // Grow: among the candidate moves whose projected tail still
            // fits the deadline, take the one maximizing projected
            // class-weighted goodput (projected throughput; the
            // feasibility gate is the goodput filter). Fixed candidate
            // order: double bs, then add an instance; strict `>` keeps
            // the argmax deterministic and bs-first on ties.
            let thr = obs.throughput.max(1e-9);
            let mut best: Option<((u32, u32), f64)> = None;
            if self.bs * 2 <= self.max_bs && p95 * LAT_BS <= deadline {
                best = Some(((self.bs * 2, self.mtl), self.weight * thr * self.gain_bs));
            }
            if self.mtl + 1 <= self.max_mtl && p95 * LAT_MT <= deadline {
                let score = self.weight * thr * self.gain_mt;
                if best.as_ref().map_or(true, |(_, s)| score > *s) {
                    best = Some(((self.bs, self.mtl + 1), score));
                }
            }
            if let Some(((bs, mtl), _)) = best {
                return self.set(bs, mtl);
            }
            // No feasible growth: capacity is deadline-bound. If even the
            // cheaper latency move is infeasible because bs is carrying
            // the tail, trade bs for an instance (same throughput order,
            // lower projected tail) — the combined move neither
            // single-knob scaler can make.
            if self.bs > 1 && self.mtl + 1 <= self.max_mtl && self.gain_mt >= self.gain_bs {
                return self.set((self.bs / 2).max(1), self.mtl + 1);
            }
            self.calm = 0;
            return Action::Hold;
        }

        // Calm decay: after two comfortable windows give back the knob
        // whose learned gain is smallest (loses the least throughput).
        if obs.queue_depth == 0 && p95 <= 0.5 * deadline {
            self.calm += 1;
            if self.calm >= 2 && (self.bs > 1 || self.mtl > 1) {
                let shrink_bs = self.bs > 1 && (self.mtl == 1 || self.gain_bs <= self.gain_mt);
                return if shrink_bs {
                    self.set((self.bs / 2).max(1), self.mtl)
                } else {
                    self.set(self.bs, self.mtl - 1)
                };
            }
        } else {
            self.calm = 0;
        }
        Action::Hold
    }
}

/// Class-weighted SM partition rebalancer — the §4.6 third knob, made
/// class-aware. Identical demand model to
/// [`DemandPartition`](super::policy::DemandPartition) (EWMA of arrival
/// rate + backlog + drop pressure, floor-pinned waterfill, hold below a
/// drift threshold), except each member's pressure is multiplied by its
/// class shed-weight: under contention gold pulls SM share away from
/// best-effort at equal offered load. With every member unclassed (all
/// weights 1.0) it reduces exactly to the demand-only rebalancer.
#[derive(Debug, Clone)]
pub struct ClassPartition {
    /// Per-member class weight (1.0 for unclassed members).
    weights: Vec<f64>,
    /// Smoothed weighted demand score per member.
    score: Vec<f64>,
    /// Minimum share any member can be squeezed to.
    floor: f64,
    /// Smoothing step toward the weighted-demand target, 0..1.
    gain: f64,
}

impl ClassPartition {
    /// Weighted rebalancer for the given per-member classes (index
    /// aligned with the fleet's members; `None` = unclassed, weight 1).
    pub fn new(classes: &[Option<SloClass>]) -> Self {
        let weights =
            classes.iter().map(|c| c.map_or(1.0, SloClass::shed_weight)).collect();
        ClassPartition {
            weights,
            score: Vec::new(),
            floor: MIN_GRANT.max(0.05),
            gain: 0.3,
        }
    }
}

impl PartitionPolicy for ClassPartition {
    fn name(&self) -> &'static str {
        "class-share"
    }

    fn rebalance(&mut self, obs: &[WindowObservation], current: &[f64]) -> Option<Vec<f64>> {
        if obs.len() != current.len() || obs.is_empty() || obs.len() != self.weights.len() {
            return None;
        }
        if self.score.len() != obs.len() {
            self.score = vec![1.0; obs.len()];
        }
        const BETA: f64 = 0.5;
        for ((s, o), w) in self.score.iter_mut().zip(obs).zip(&self.weights) {
            let pressure = o.arrival_rate
                + o.queue_depth as f64
                + 10.0 * (o.drops + o.drops_deadline) as f64;
            *s = BETA * (w * pressure.max(1e-3)) + (1.0 - BETA) * *s;
        }
        let n = current.len() as f64;
        // Floor-pinned waterfill toward the weighted-demand split (see
        // DemandPartition for the unweighted derivation).
        let mut target = vec![0.0; current.len()];
        if self.floor * n > 1.0 {
            target.fill(1.0 / n);
        } else {
            let mut pinned = vec![false; current.len()];
            loop {
                let pinned_mass = pinned.iter().filter(|&&p| p).count() as f64 * self.floor;
                let free_score: f64 = self
                    .score
                    .iter()
                    .zip(&pinned)
                    .filter(|(_, &p)| !p)
                    .map(|(s, _)| *s)
                    .sum();
                let mut changed = false;
                for i in 0..current.len() {
                    if pinned[i] {
                        target[i] = self.floor;
                        continue;
                    }
                    target[i] = self.score[i] / free_score * (1.0 - pinned_mass);
                    if target[i] < self.floor {
                        pinned[i] = true;
                        changed = true;
                    }
                }
                if !changed {
                    break;
                }
            }
        }
        let mut next: Vec<f64> = current
            .iter()
            .zip(&target)
            .map(|(c, t)| c + self.gain * (t - c))
            .collect();
        let nsum: f64 = next.iter().sum();
        if nsum > 1.0 {
            for v in &mut next {
                *v /= nsum;
            }
        }
        let drift: f64 =
            next.iter().zip(current).map(|(a, b)| (a - b).abs()).sum::<f64>() / n;
        if drift < 0.005 {
            None
        } else {
            Some(next)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_parse_accepts_letters_and_names() {
        for (tok, want) in [
            ("g", SloClass::Gold),
            ("gold", SloClass::Gold),
            (" S ", SloClass::Silver),
            ("silver", SloClass::Silver),
            ("b", SloClass::BestEffort),
            ("be", SloClass::BestEffort),
            ("best-effort", SloClass::BestEffort),
            ("BestEffort", SloClass::BestEffort),
        ] {
            assert_eq!(SloClass::parse(tok), Ok(want), "{tok:?}");
        }
        let err = SloClass::parse("platinum").unwrap_err();
        assert!(err.to_string().contains("platinum"), "{err}");
    }

    #[test]
    fn class_constants_order_the_tiers() {
        // Deadlines tighten and weights drop monotonically down-tier;
        // gold's multiplier is exactly 1.0 (the byte-identity anchor).
        assert_eq!(SloClass::Gold.shed_scale(), 1.0);
        assert!(SloClass::Gold.shed_scale() > SloClass::Silver.shed_scale());
        assert!(SloClass::Silver.shed_scale() > SloClass::BestEffort.shed_scale());
        assert!(SloClass::Gold.shed_weight() > SloClass::Silver.shed_weight());
        assert!(SloClass::Silver.shed_weight() > SloClass::BestEffort.shed_weight());
        for (i, c) in SloClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(SloClass::parse(c.letter()), Ok(*c));
            assert_eq!(SloClass::parse(c.name()), Ok(*c));
        }
    }

    #[test]
    fn slo_report_absent_without_classes() {
        assert_eq!(SloReport::from_members([(None, 10.0, 3), (None, 5.0, 0)]), None);
        let r = SloReport::from_members([
            (Some(SloClass::Gold), 10.0, 1),
            (None, 99.0, 99),
            (Some(SloClass::Gold), 2.5, 0),
            (Some(SloClass::BestEffort), 1.0, 7),
        ])
        .unwrap();
        assert_eq!(r.class(SloClass::Gold).members, 2);
        assert_eq!(r.class(SloClass::Gold).goodput, 12.5);
        assert_eq!(r.class(SloClass::Gold).shed, 1);
        assert_eq!(r.class(SloClass::Silver), ClassStat::default());
        assert_eq!(r.class(SloClass::BestEffort).shed, 7);
        let mut merged = SloReport::default();
        assert!(merged.is_empty());
        merged.merge(&r);
        merged.merge(&r);
        assert_eq!(merged.class(SloClass::Gold).goodput, 25.0);
        assert!(!merged.is_empty());
    }

    fn overload_obs(window: usize, p95: f64) -> WindowObservation {
        WindowObservation {
            window,
            slo_ms: 100.0,
            p95_ms: p95,
            mean_ms: p95 * 0.6,
            throughput: 50.0,
            power_w: 0.0,
            sm_util: 0.0,
            queue_depth: 40 + 5 * window,
            arrival_rate: 400.0,
            drops: 2,
            drops_deadline: 1,
        }
    }

    #[test]
    fn combined_policy_grows_both_knobs_under_overload() {
        let mut p = CombinedPolicy::new(128, 10);
        assert_eq!(p.name(), "combined");
        assert_eq!(p.operating_point(), (1, 1));
        for w in 0..12 {
            p.observe(&overload_obs(w, 30.0));
        }
        let (bs, mtl) = p.operating_point();
        assert!(bs > 1, "overload with headroom must grow bs (got bs={bs})");
        assert!(mtl > 1, "overload with headroom must grow mtl (got mtl={mtl})");
    }

    #[test]
    fn combined_policy_shrinks_on_deadline_violation() {
        let mut p = CombinedPolicy::new(128, 10);
        for w in 0..6 {
            p.observe(&overload_obs(w, 30.0));
        }
        let before = p.operating_point();
        assert!(before > (1, 1));
        let a = p.observe(&overload_obs(6, 250.0)); // 2.5x the deadline
        assert!(matches!(a, Action::SetPoint { .. }), "violation must shrink, got {a:?}");
        let after = p.operating_point();
        assert!(
            after.0 < before.0 || after.1 < before.1,
            "shrink must give back a knob: {before:?} -> {after:?}"
        );
    }

    #[test]
    fn combined_policy_respects_ceilings_and_holds_at_floor() {
        let mut p = CombinedPolicy::new(2, 2);
        for w in 0..30 {
            p.observe(&overload_obs(w, 30.0));
            let (bs, mtl) = p.operating_point();
            assert!(bs <= 2 && mtl <= 2, "({bs},{mtl}) escaped the ceilings");
        }
        // At (1,1) a violation has nothing to give back: hold, not panic.
        let mut q = CombinedPolicy::new(128, 10);
        assert_eq!(q.observe(&overload_obs(0, 500.0)), Action::Hold);
        assert_eq!(q.operating_point(), (1, 1));
    }

    #[test]
    fn combined_policy_decays_after_calm() {
        let mut p = CombinedPolicy::new(128, 10);
        for w in 0..8 {
            p.observe(&overload_obs(w, 30.0));
        }
        let grown = p.operating_point();
        assert!(grown > (1, 1));
        for w in 8..60 {
            let mut o = overload_obs(w, 10.0);
            o.queue_depth = 0;
            o.arrival_rate = 1.0;
            o.throughput = 1.0;
            o.drops = 0;
            o.drops_deadline = 0;
            p.observe(&o);
        }
        assert_eq!(p.operating_point(), (1, 1), "calm must decay back to the floor");
    }

    #[test]
    fn combined_policy_is_deterministic() {
        let run = || {
            let mut p = CombinedPolicy::new(128, 10);
            let mut points = Vec::new();
            for w in 0..40 {
                let p95 = if w % 7 == 6 { 180.0 } else { 25.0 + w as f64 };
                p.observe(&overload_obs(w, p95));
                points.push(p.operating_point());
            }
            points
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn class_partition_weighs_gold_over_best_effort() {
        let classes = [Some(SloClass::Gold), Some(SloClass::BestEffort)];
        let mut p = ClassPartition::new(&classes);
        assert_eq!(p.name(), "class-share");
        let mut res = vec![0.5, 0.5];
        // Identical offered load on both members: only the class weight
        // differs, so gold must end with the larger share.
        for w in 0..12 {
            let o = overload_obs(w, 30.0);
            if let Some(next) = p.rebalance(&[o, o], &res) {
                res = next;
            }
        }
        assert!(res[0] > res[1], "gold {} must out-share best-effort {}", res[0], res[1]);
        assert!(res[1] >= 0.04, "best-effort squeezed below its floor: {}", res[1]);
        assert!(res.iter().sum::<f64>() <= 1.0 + 1e-9);
    }

    #[test]
    fn class_partition_unclassed_matches_demand_partition() {
        use super::super::policy::DemandPartition;
        let mut weighted = ClassPartition::new(&[None, None]);
        let mut plain = DemandPartition::new();
        let mut a = vec![0.5, 0.5];
        let mut b = vec![0.5, 0.5];
        for w in 0..10 {
            let hot = overload_obs(w, 30.0);
            let mut cold = overload_obs(w, 5.0);
            cold.arrival_rate = 1.0;
            cold.queue_depth = 0;
            cold.drops = 0;
            cold.drops_deadline = 0;
            if let Some(next) = weighted.rebalance(&[hot, cold], &a) {
                a = next;
            }
            if let Some(next) = plain.rebalance(&[hot, cold], &b) {
                b = next;
            }
            assert_eq!(a, b, "window {w}: all-unclassed must mirror demand-share");
        }
    }

    #[test]
    fn class_partition_holds_on_bad_input() {
        let mut p = ClassPartition::new(&[None, None]);
        assert!(p.rebalance(&[overload_obs(0, 10.0)], &[0.5, 0.5]).is_none());
        assert!(p.rebalance(&[], &[]).is_none());
    }
}
