//! The `Policy` abstraction: what a serving-control algorithm sees and
//! what it may do.
//!
//! The legacy [`Controller`] trait observes only `(p95, SLO)` — enough
//! for the paper's closed-loop evaluation, but blind to everything an
//! open-loop server knows: queue depth, offered arrival rate, drops,
//! power, SM utilization. `Policy` generalizes it: each control window
//! the session hands the policy a typed [`WindowObservation`] and gets a
//! typed [`Action`] back. DNNScaler's two scalers, Clipper, and the
//! static-knob baseline are all `Policy` implementations, so ablations
//! and new algorithms plug into `ServingSession`/`Fleet` uniformly.
//!
//! [`Controller`]: super::controller::Controller

use crate::gpusim::MIN_GRANT;

use super::controller::{Controller, Decision};

/// Everything the serving loop measured over one control window.
///
/// Closed-loop sessions leave the queue fields at zero (there is no
/// queue); open-loop sessions report sojourn latencies (queueing delay
/// included), the offered arrival rate, and drop counts.
#[derive(Debug, Clone, Copy)]
pub struct WindowObservation {
    /// Window index, `0..windows`.
    pub window: usize,
    /// SLO in effect during the window (ms).
    pub slo_ms: f64,
    /// p95 of per-request latency over the window (ms).
    pub p95_ms: f64,
    /// Mean per-request latency over the window (ms).
    pub mean_ms: f64,
    /// Requests completed per second of window wall time.
    pub throughput: f64,
    /// Mean board power over the window (W); 0 when unknown.
    pub power_w: f64,
    /// Mean SM utilization over the window, 0..1; 0 when unknown.
    pub sm_util: f64,
    /// Pending requests left in the queue at the window boundary.
    pub queue_depth: usize,
    /// Offered arrival rate over the window (requests/s); 0 closed-loop.
    pub arrival_rate: f64,
    /// Requests dropped (bounded queue overflow) during the window.
    pub drops: u64,
    /// Requests shed (queueing delay blew the SLO deadline) during the
    /// window; 0 unless deadline shedding is enabled.
    pub drops_deadline: u64,
}

/// A policy's verdict for the next window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the current operating point.
    Hold,
    /// Move to a new operating point; the session charges instance-launch
    /// overhead when `mtl` grows.
    SetPoint { bs: u32, mtl: u32 },
}

impl Action {
    /// Lift a legacy [`Decision`] into an `Action`.
    pub fn from_decision(d: Decision) -> Action {
        if d.changed {
            Action::SetPoint { bs: d.bs, mtl: d.mtl }
        } else {
            Action::Hold
        }
    }
}

/// A window-driven serving-control algorithm.
///
/// `Send` is a supertrait so boxed policies can ride inside per-device
/// serving state when the cluster shards its device event loops across
/// worker threads (`Cluster::threads`). A policy only ever runs on one
/// thread at a time (each device's window loop owns its members), so no
/// `Sync` is required — but the state must be allowed to *move*.
pub trait Policy: Send {
    /// Human-readable name for traces/reports.
    fn name(&self) -> &'static str;

    /// Current operating point `(bs, mtl)`.
    fn operating_point(&self) -> (u32, u32);

    /// Observe one control window and decide the next operating point.
    fn observe(&mut self, obs: &WindowObservation) -> Action;
}

/// Adapter giving any legacy [`Controller`] the `Policy` interface (it
/// sees only the `p95_ms`/`slo_ms` fields of the observation).
pub struct AsPolicy<C>(pub C);

impl<C: Controller + Send> Policy for AsPolicy<C> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn operating_point(&self) -> (u32, u32) {
        self.0.operating_point()
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        Action::from_decision(self.0.observe_window(obs.p95_ms, obs.slo_ms))
    }
}

/// Static-knob baseline: serve at a fixed `(bs, mtl)` forever. The
/// no-control lower bound every adaptive policy must beat, and the
/// building block for sweep-style experiments through the serving loop.
#[derive(Debug, Clone, Copy)]
pub struct StaticPolicy {
    bs: u32,
    mtl: u32,
}

impl StaticPolicy {
    pub fn new(bs: u32, mtl: u32) -> Self {
        assert!(bs >= 1 && mtl >= 1, "operating point must be >= (1,1)");
        StaticPolicy { bs, mtl }
    }
}

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.bs, self.mtl)
    }

    fn observe(&mut self, _obs: &WindowObservation) -> Action {
        Action::Hold
    }
}

/// Queue-aware proactive instance scaler (D-STACK-style demand
/// estimation). Where the paper's scalers wait for p95 to move,
/// `QueuePolicy` watches the *demand side* of the open loop — queue
/// depth, offered arrival rate, drop counts — and adds an instance
/// before the tail latency has degraded; capacity decays again only
/// after sustained calm. Batch size stays fixed (instances are the knob,
/// as in the paper's Multi-Tenancy mode). Intended for open-loop
/// serving: in a closed loop every demand signal reads zero and the
/// policy only ever reacts to outright SLO violations.
#[derive(Debug, Clone)]
pub struct QueuePolicy {
    bs: u32,
    mtl: u32,
    max_mtl: u32,
    /// EWMA of the offered arrival rate (requests/s).
    rate_ewma: f64,
    /// EWMA of the served throughput — the capacity proxy at the current
    /// operating point.
    serve_ewma: f64,
    last_depth: usize,
    /// Consecutive calm windows (empty queue, no drops, comfortable p95).
    calm: u32,
}

impl QueuePolicy {
    /// Instance scaling at batch size 1 (the paper's MT configuration).
    pub fn new(max_mtl: u32) -> Self {
        Self::with_batch(1, max_mtl)
    }

    /// Instance scaling at a fixed batch size per instance.
    pub fn with_batch(bs: u32, max_mtl: u32) -> Self {
        assert!(bs >= 1 && max_mtl >= 1, "operating point must be >= (1,1)");
        QueuePolicy {
            bs,
            mtl: 1,
            max_mtl,
            rate_ewma: 0.0,
            serve_ewma: 0.0,
            last_depth: 0,
            calm: 0,
        }
    }

    fn grow(&mut self) -> Action {
        self.calm = 0;
        if self.mtl < self.max_mtl {
            self.mtl += 1;
            Action::SetPoint { bs: self.bs, mtl: self.mtl }
        } else {
            Action::Hold
        }
    }
}

impl Policy for QueuePolicy {
    fn name(&self) -> &'static str {
        "queue-aware"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.bs, self.mtl)
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        const BETA: f64 = 0.5;
        if obs.window == 0 {
            self.rate_ewma = obs.arrival_rate;
            self.serve_ewma = obs.throughput;
        } else {
            self.rate_ewma = BETA * obs.arrival_rate + (1.0 - BETA) * self.rate_ewma;
            self.serve_ewma = BETA * obs.throughput + (1.0 - BETA) * self.serve_ewma;
        }
        let growing = obs.queue_depth > self.last_depth;
        self.last_depth = obs.queue_depth;
        let batch = (self.bs as usize) * (self.mtl as usize);

        // Proactive signals — all fire before p95 has to move:
        // a backlog deeper than two full batches, any kind of drop, or
        // offered demand outrunning the measured service rate while the
        // queue is still growing.
        let backlog = obs.queue_depth > 2 * batch;
        let starved = obs.drops > 0 || obs.drops_deadline > 0;
        let demand = growing && self.rate_ewma > self.serve_ewma * 1.1;
        if backlog || starved || demand {
            return self.grow();
        }
        // Reactive guard (the late signal the proactive path exists to
        // pre-empt): the tail has already crossed the SLO.
        if obs.p95_ms > obs.slo_ms {
            return self.grow();
        }
        // Decay only after sustained calm, one instance at a time. (Any
        // window with drops or sheds already returned via `starved`, so
        // only the backlog and tail need re-checking here.)
        if obs.queue_depth == 0 && obs.p95_ms <= 0.5 * obs.slo_ms {
            self.calm += 1;
            if self.calm >= 2 && self.mtl > 1 {
                self.calm = 0;
                self.mtl -= 1;
                return Action::SetPoint { bs: self.bs, mtl: self.mtl };
            }
        } else {
            self.calm = 0;
        }
        Action::Hold
    }
}

/// A fleet-level SM-partition rebalancer: observes every member's window
/// and may move SM reservations between them at the window boundary.
///
/// Where a [`Policy`] turns one member's observation into that member's
/// `(bs, mtl)`, a `PartitionPolicy` arbitrates the *device* — the §4.6
/// third knob (partition share) alongside batch size and instances. The
/// fleet sanitizes whatever is returned: wrong-length or non-finite
/// vectors are rejected outright, values are lifted to the mode's
/// smallest grantable share (one MIG slice / `MIN_GRANT`), and the
/// result passes the same `plan_grants` validation used at build time —
/// a rebalance that still over-subscribes is rejected (and counted as
/// an admission clamp), never silently granted.
///
/// `Send` for the same reason as [`Policy`]: the partitioner (and its
/// boxed rebalancer) lives inside per-device state that may move to a
/// worker thread when the cluster serves data-parallel.
pub trait PartitionPolicy: Send {
    /// Human-readable name for traces/reports.
    fn name(&self) -> &'static str;

    /// Observe one window of every member (index-aligned with `current`
    /// reservations) and propose new reservations, or `None` to hold.
    fn rebalance(&mut self, obs: &[WindowObservation], current: &[f64]) -> Option<Vec<f64>>;
}

/// Demand-weighted SM rebalancer: shifts reservation toward members
/// whose offered load (arrival rate, queue backlog, drops) outruns their
/// served throughput, with an EWMA so one bursty window does not thrash
/// the partition layout. Every member keeps a floor share so a starved
/// member can still drain and be seen recovering.
#[derive(Debug, Clone)]
pub struct DemandPartition {
    /// Smoothed demand score per member (lazily sized on first window).
    score: Vec<f64>,
    /// Minimum share any member can be squeezed to.
    floor: f64,
    /// Smoothing step toward the demand-proportional target, 0..1.
    gain: f64,
}

impl DemandPartition {
    pub fn new() -> Self {
        Self::with_params(MIN_GRANT.max(0.05), 0.3)
    }

    /// `floor`: smallest share a member may hold; `gain`: fraction of the
    /// gap toward the demand-proportional split applied per window.
    pub fn with_params(floor: f64, gain: f64) -> Self {
        assert!((0.0..0.5).contains(&floor), "floor must be in [0, 0.5)");
        assert!((0.0..=1.0).contains(&gain), "gain must be in [0, 1]");
        DemandPartition { score: Vec::new(), floor, gain }
    }
}

impl Default for DemandPartition {
    fn default() -> Self {
        Self::new()
    }
}

impl PartitionPolicy for DemandPartition {
    fn name(&self) -> &'static str {
        "demand-share"
    }

    fn rebalance(&mut self, obs: &[WindowObservation], current: &[f64]) -> Option<Vec<f64>> {
        if obs.len() != current.len() || obs.is_empty() {
            return None;
        }
        if self.score.len() != obs.len() {
            self.score = vec![1.0; obs.len()];
        }
        const BETA: f64 = 0.5;
        for (s, o) in self.score.iter_mut().zip(obs) {
            // Demand proxy: offered rate plus backlog/drop pressure,
            // floored so an idle member keeps a nonzero score.
            let pressure = o.arrival_rate
                + o.queue_depth as f64
                + 10.0 * (o.drops + o.drops_deadline) as f64;
            *s = BETA * pressure.max(1e-3) + (1.0 - BETA) * *s;
        }
        let n = current.len() as f64;
        // Demand-proportional split with the floor enforced exactly:
        // members whose proportional share would fall below the floor
        // are pinned AT the floor and the remaining mass is re-split
        // among the rest (bounded waterfill, at most one pass per
        // member). An infeasible floor (floor * n > 1) degrades to an
        // equal split rather than an over-subscribed target.
        let mut target = vec![0.0; current.len()];
        if self.floor * n > 1.0 {
            target.fill(1.0 / n);
        } else {
            let mut pinned = vec![false; current.len()];
            loop {
                let pinned_mass =
                    pinned.iter().filter(|&&p| p).count() as f64 * self.floor;
                let free_score: f64 = self
                    .score
                    .iter()
                    .zip(&pinned)
                    .filter(|(_, &p)| !p)
                    .map(|(s, _)| *s)
                    .sum();
                let mut changed = false;
                for i in 0..current.len() {
                    if pinned[i] {
                        target[i] = self.floor;
                        continue;
                    }
                    target[i] = self.score[i] / free_score * (1.0 - pinned_mass);
                    if target[i] < self.floor {
                        pinned[i] = true;
                        changed = true;
                    }
                }
                if !changed {
                    break;
                }
            }
        }
        let mut next: Vec<f64> = current
            .iter()
            .zip(&target)
            .map(|(c, t)| c + self.gain * (t - c))
            .collect();
        // Defensive renormalization (floating error only; plan_grants
        // re-validates downstream anyway).
        let nsum: f64 = next.iter().sum();
        if nsum > 1.0 {
            for v in &mut next {
                *v /= nsum;
            }
        }
        let drift: f64 =
            next.iter().zip(current).map(|(a, b)| (a - b).abs()).sum::<f64>() / n;
        if drift < 0.005 {
            None
        } else {
            Some(next)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::clipper::Clipper;
    use crate::coordinator::scaler_batching::BatchScaler;

    fn obs(p95: f64, slo: f64) -> WindowObservation {
        WindowObservation {
            window: 0,
            slo_ms: slo,
            p95_ms: p95,
            mean_ms: p95,
            throughput: 0.0,
            power_w: 0.0,
            sm_util: 0.0,
            queue_depth: 0,
            arrival_rate: 0.0,
            drops: 0,
            drops_deadline: 0,
        }
    }

    #[test]
    fn static_policy_never_moves() {
        let mut p = StaticPolicy::new(8, 2);
        assert_eq!(p.operating_point(), (8, 2));
        for i in 0..50 {
            let a = p.observe(&obs(if i % 2 == 0 { 1.0 } else { 1e9 }, 100.0));
            assert_eq!(a, Action::Hold);
            assert_eq!(p.operating_point(), (8, 2));
        }
        assert_eq!(p.name(), "static");
    }

    #[test]
    #[should_panic]
    fn static_policy_rejects_zero_knob() {
        let _ = StaticPolicy::new(0, 1);
    }

    #[test]
    fn as_policy_mirrors_controller() {
        let mut c = Clipper::new();
        let mut p = AsPolicy(Clipper::new());
        for i in 0..30 {
            let p95 = if i % 5 == 4 { 1e6 } else { 0.0 };
            let d = c.observe_window(p95, 100.0);
            let a = p.observe(&obs(p95, 100.0));
            assert_eq!(a, Action::from_decision(d));
            assert_eq!(Policy::operating_point(&p), Controller::operating_point(&c));
        }
        assert_eq!(Policy::name(&p), "clipper");
    }

    #[test]
    fn scalers_implement_policy_directly() {
        // BatchScaler as a Policy converges the same way it does as a
        // Controller (it reads only p95/slo from the observation).
        let mut p: Box<dyn Policy> = Box::new(BatchScaler::new());
        for _ in 0..30 {
            let (bs, _) = p.operating_point();
            let lat = 2.0 * bs as f64; // SLO 100 -> knee at 50
            p.observe(&obs(lat, 100.0));
        }
        let (bs, mtl) = p.operating_point();
        assert!((43..=50).contains(&bs), "bs {bs}");
        assert_eq!(mtl, 1);
    }

    #[test]
    fn action_from_decision() {
        let hold = Decision { bs: 4, mtl: 1, changed: false };
        let moved = Decision { bs: 8, mtl: 2, changed: true };
        assert_eq!(Action::from_decision(hold), Action::Hold);
        assert_eq!(Action::from_decision(moved), Action::SetPoint { bs: 8, mtl: 2 });
    }

    /// Demand-side observation: deep/rising queue but a perfectly healthy
    /// tail (the situation reactive scalers sleep through).
    fn demand_obs(window: usize, depth: usize) -> WindowObservation {
        WindowObservation {
            window,
            slo_ms: 100.0,
            p95_ms: 20.0, // far below the SLO: no reactive signal at all
            mean_ms: 10.0,
            throughput: 50.0,
            power_w: 0.0,
            sm_util: 0.0,
            queue_depth: depth,
            arrival_rate: 200.0,
            drops: 0,
            drops_deadline: 0,
        }
    }

    #[test]
    fn queue_policy_scales_up_before_p95_moves() {
        let mut p = QueuePolicy::new(10);
        assert_eq!(p.operating_point(), (1, 1));
        assert_eq!(p.name(), "queue-aware");
        for w in 0..4 {
            let a = p.observe(&demand_obs(w, 10 + 10 * w));
            assert!(
                matches!(a, Action::SetPoint { .. }),
                "window {w}: backlog must trigger proactive scale-up, got {a:?}"
            );
        }
        assert!(p.operating_point().1 >= 4, "mtl {}", p.operating_point().1);
    }

    #[test]
    fn queue_policy_grows_on_drops_and_respects_the_ceiling() {
        let mut p = QueuePolicy::new(3);
        for w in 0..10 {
            let mut o = demand_obs(w, 0);
            o.drops = 5; // overflow: capacity is clearly short
            p.observe(&o);
            assert!(p.operating_point().1 <= 3);
        }
        assert_eq!(p.operating_point(), (1, 3));
    }

    #[test]
    fn queue_policy_decays_after_sustained_calm() {
        let mut p = QueuePolicy::new(10);
        for w in 0..5 {
            p.observe(&demand_obs(w, 100));
        }
        let peak = p.operating_point().1;
        assert!(peak >= 5);
        // Calm: empty queue, tiny tail, no drops -> decay back to 1.
        for w in 5..50 {
            let mut o = demand_obs(w, 0);
            o.arrival_rate = 1.0;
            o.throughput = 1.0;
            o.p95_ms = 5.0;
            p.observe(&o);
        }
        assert_eq!(p.operating_point().1, 1);
    }

    #[test]
    fn queue_policy_reactive_guard_still_fires() {
        // Even with zero demand signals, an SLO violation scales up.
        let mut p = QueuePolicy::new(10);
        let mut o = demand_obs(0, 0);
        o.arrival_rate = 0.0;
        o.throughput = 0.0;
        o.p95_ms = 500.0; // 5x the SLO
        assert_eq!(p.observe(&o), Action::SetPoint { bs: 1, mtl: 2 });
    }

    #[test]
    fn demand_partition_shifts_share_toward_the_loaded_member() {
        let mut p = DemandPartition::new();
        assert_eq!(p.name(), "demand-share");
        let mut res = vec![0.5, 0.5];
        // Member 0 is slammed (high rate, deep queue); member 1 is idle.
        for w in 0..12 {
            let hot = demand_obs(w, 200);
            let mut cold = demand_obs(w, 0);
            cold.arrival_rate = 0.5;
            if let Some(next) = p.rebalance(&[hot, cold], &res) {
                res = next;
            }
        }
        assert!(res[0] > 0.7, "hot member share {} never grew", res[0]);
        assert!(res[1] >= 0.04, "cold member squeezed below its floor: {}", res[1]);
        assert!(res.iter().sum::<f64>() <= 1.0 + 1e-9);
        assert!(res.iter().all(|r| *r > 0.0));
    }

    #[test]
    fn demand_partition_holds_on_balanced_load_and_bad_input() {
        let mut p = DemandPartition::new();
        let res = vec![0.5, 0.5];
        // Perfectly symmetric load: after the EWMA settles, targets equal
        // current and the policy holds instead of thrashing.
        let mut held = false;
        for w in 0..10 {
            let o = demand_obs(w, 10);
            if p.rebalance(&[o, o], &res).is_none() {
                held = true;
            }
        }
        assert!(held, "symmetric load must eventually hold");
        // Length mismatch is a hold, not a panic.
        assert!(p.rebalance(&[demand_obs(0, 1)], &res).is_none());
        assert!(p.rebalance(&[], &[]).is_none());
    }
}
