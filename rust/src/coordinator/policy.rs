//! The `Policy` abstraction: what a serving-control algorithm sees and
//! what it may do.
//!
//! The legacy [`Controller`] trait observes only `(p95, SLO)` — enough
//! for the paper's closed-loop evaluation, but blind to everything an
//! open-loop server knows: queue depth, offered arrival rate, drops,
//! power, SM utilization. `Policy` generalizes it: each control window
//! the session hands the policy a typed [`WindowObservation`] and gets a
//! typed [`Action`] back. DNNScaler's two scalers, Clipper, and the
//! static-knob baseline are all `Policy` implementations, so ablations
//! and new algorithms plug into `ServingSession`/`Fleet` uniformly.
//!
//! [`Controller`]: super::controller::Controller

use super::controller::{Controller, Decision};

/// Everything the serving loop measured over one control window.
///
/// Closed-loop sessions leave the queue fields at zero (there is no
/// queue); open-loop sessions report sojourn latencies (queueing delay
/// included), the offered arrival rate, and drop counts.
#[derive(Debug, Clone, Copy)]
pub struct WindowObservation {
    /// Window index, `0..windows`.
    pub window: usize,
    /// SLO in effect during the window (ms).
    pub slo_ms: f64,
    /// p95 of per-request latency over the window (ms).
    pub p95_ms: f64,
    /// Mean per-request latency over the window (ms).
    pub mean_ms: f64,
    /// Requests completed per second of window wall time.
    pub throughput: f64,
    /// Mean board power over the window (W); 0 when unknown.
    pub power_w: f64,
    /// Mean SM utilization over the window, 0..1; 0 when unknown.
    pub sm_util: f64,
    /// Pending requests left in the queue at the window boundary.
    pub queue_depth: usize,
    /// Offered arrival rate over the window (requests/s); 0 closed-loop.
    pub arrival_rate: f64,
    /// Requests dropped (bounded queue overflow) during the window.
    pub drops: u64,
}

/// A policy's verdict for the next window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Keep the current operating point.
    Hold,
    /// Move to a new operating point; the session charges instance-launch
    /// overhead when `mtl` grows.
    SetPoint { bs: u32, mtl: u32 },
}

impl Action {
    /// Lift a legacy [`Decision`] into an `Action`.
    pub fn from_decision(d: Decision) -> Action {
        if d.changed {
            Action::SetPoint { bs: d.bs, mtl: d.mtl }
        } else {
            Action::Hold
        }
    }
}

/// A window-driven serving-control algorithm.
pub trait Policy {
    /// Human-readable name for traces/reports.
    fn name(&self) -> &'static str;

    /// Current operating point `(bs, mtl)`.
    fn operating_point(&self) -> (u32, u32);

    /// Observe one control window and decide the next operating point.
    fn observe(&mut self, obs: &WindowObservation) -> Action;
}

/// Adapter giving any legacy [`Controller`] the `Policy` interface (it
/// sees only the `p95_ms`/`slo_ms` fields of the observation).
pub struct AsPolicy<C>(pub C);

impl<C: Controller> Policy for AsPolicy<C> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn operating_point(&self) -> (u32, u32) {
        self.0.operating_point()
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        Action::from_decision(self.0.observe_window(obs.p95_ms, obs.slo_ms))
    }
}

/// Static-knob baseline: serve at a fixed `(bs, mtl)` forever. The
/// no-control lower bound every adaptive policy must beat, and the
/// building block for sweep-style experiments through the serving loop.
#[derive(Debug, Clone, Copy)]
pub struct StaticPolicy {
    bs: u32,
    mtl: u32,
}

impl StaticPolicy {
    pub fn new(bs: u32, mtl: u32) -> Self {
        assert!(bs >= 1 && mtl >= 1, "operating point must be >= (1,1)");
        StaticPolicy { bs, mtl }
    }
}

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.bs, self.mtl)
    }

    fn observe(&mut self, _obs: &WindowObservation) -> Action {
        Action::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::clipper::Clipper;
    use crate::coordinator::scaler_batching::BatchScaler;

    fn obs(p95: f64, slo: f64) -> WindowObservation {
        WindowObservation {
            window: 0,
            slo_ms: slo,
            p95_ms: p95,
            mean_ms: p95,
            throughput: 0.0,
            power_w: 0.0,
            sm_util: 0.0,
            queue_depth: 0,
            arrival_rate: 0.0,
            drops: 0,
        }
    }

    #[test]
    fn static_policy_never_moves() {
        let mut p = StaticPolicy::new(8, 2);
        assert_eq!(p.operating_point(), (8, 2));
        for i in 0..50 {
            let a = p.observe(&obs(if i % 2 == 0 { 1.0 } else { 1e9 }, 100.0));
            assert_eq!(a, Action::Hold);
            assert_eq!(p.operating_point(), (8, 2));
        }
        assert_eq!(p.name(), "static");
    }

    #[test]
    #[should_panic]
    fn static_policy_rejects_zero_knob() {
        let _ = StaticPolicy::new(0, 1);
    }

    #[test]
    fn as_policy_mirrors_controller() {
        let mut c = Clipper::new();
        let mut p = AsPolicy(Clipper::new());
        for i in 0..30 {
            let p95 = if i % 5 == 4 { 1e6 } else { 0.0 };
            let d = c.observe_window(p95, 100.0);
            let a = p.observe(&obs(p95, 100.0));
            assert_eq!(a, Action::from_decision(d));
            assert_eq!(Policy::operating_point(&p), Controller::operating_point(&c));
        }
        assert_eq!(Policy::name(&p), "clipper");
    }

    #[test]
    fn scalers_implement_policy_directly() {
        // BatchScaler as a Policy converges the same way it does as a
        // Controller (it reads only p95/slo from the observation).
        let mut p: Box<dyn Policy> = Box::new(BatchScaler::new());
        for _ in 0..30 {
            let (bs, _) = p.operating_point();
            let lat = 2.0 * bs as f64; // SLO 100 -> knee at 50
            p.observe(&obs(lat, 100.0));
        }
        let (bs, mtl) = p.operating_point();
        assert!((43..=50).contains(&bs), "bs {bs}");
        assert_eq!(mtl, 1);
    }

    #[test]
    fn action_from_decision() {
        let hold = Decision { bs: 4, mtl: 1, changed: false };
        let moved = Decision { bs: 8, mtl: 2, changed: true };
        assert_eq!(Action::from_decision(hold), Action::Hold);
        assert_eq!(Action::from_decision(moved), Action::SetPoint { bs: 8, mtl: 2 });
    }
}
