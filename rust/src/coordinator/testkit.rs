//! Whole-cluster differential fuzzing: a seeded scenario generator, a
//! deliberately naive reference executor, and a proptest-style shrinker.
//!
//! The fast engine has accumulated seven PRs of optimizations — the
//! O(log M) [`EventCalendar`], zero-alloc ring queues, recycled window
//! accumulators, sharded device threads — each differentially tested
//! *locally* (calendar-vs-LinearScan, ring-vs-VecDeque, parallel
//! byte-identity) but never cross-checked *end to end*. This module
//! closes that gap:
//!
//! * [`Scenario`] — a small, serializable gene describing one randomized
//!   cluster run: device mixes (P40/P4/T4, MIG 2/4 slices), partition
//!   modes and SM reservations, placements, open/closed arrivals
//!   (Poisson/uniform/bursty/trace), queue caps, shedding deadlines, and
//!   optional churn + migration + autoscale schedules. Scenarios lower
//!   through the SAME public builders the fast path uses, so a scenario
//!   IS what runs — nothing is mocked.
//! * [`run_reference`] — re-serves the identical validated configuration
//!   with straightforward logic: no calendar (an O(M) min-scan picks the
//!   next member), no recycled accumulators (a fresh [`WindowAccum`] per
//!   member per window), device-outer loops, single-threaded. Planning
//!   arithmetic (admission, SM shares, slice clamps) is shared with the
//!   fast path on purpose: the fuzzer hunts for *orchestration* bugs —
//!   event ordering, state recycling, sharding — not for a second
//!   opinion on float formulas.
//! * [`check_scenario`] — runs both executors, requires byte-identical
//!   snapshots ([`super::snapshot::render`]) and a clean
//!   [`ClusterOutcome::audit`] on BOTH outcomes (always, not just in
//!   debug builds), and reports the first differing JSON paths.
//! * [`shrink`] — on mismatch, greedily simplifies the scenario
//!   (drop devices, drop jobs, drop dynamics, truncate windows/rounds,
//!   simplify arrivals and policies, clear knobs) to a minimal still-
//!   failing counterexample, printable as a ready-to-commit regression
//!   case via [`to_canon`] and replayable via [`from_canon`]
//!   (`rust/tests/fuzz_corpus/`).
//!
//! Injected-bug detection is exercised through [`Mutation`]: a test-only
//! hook that corrupts the FAST outcome after the run, standing in for a
//! real engine bug. `docs/testing.md` maps where this sits in the repo's
//! correctness stack.
//!
//! [`EventCalendar`]: super::calendar::EventCalendar

use crate::device::DeviceError;
use crate::gpusim::{GpuSpec, PartitionMode, TESLA_P4, TESLA_P40, TESLA_T4};
use crate::json::{self, Json};
use crate::rng::Rng;
use crate::workload::ArrivalPattern;

use super::cluster::{
    fold_device_outcomes, merge_slo_reports, timeshare_ctx, whole_desc, Assignment, BestFit,
    Cluster, ClusterOutcome, DeviceOutcome, InterferenceAware, PlacementJob, RoundRobin,
};
use super::dynamics::{
    blank_obs, free_mb, model_load_ms, most_free_fit, try_evacuate, ChurnSchedule, DynamicsCfg,
    DynamicsOutcome, JobEvent, Live, Pending, PendingKind, PeriodicReplace, PoolObservation,
    ScaleAction, ThresholdAutoscaler,
};
use super::engine::{SmShare, WindowAccum};
use super::faults::{FaultEvent, FaultSchedule, FaultsOutcome, MAX_BACKOFF_WINDOWS};
use super::fleet::{
    admit_window, arrival_seed, clamp_to_slice_ceilings, closed_member_outcome, finish_fleet,
    new_closed_member, new_open_member, open_member_outcome, plan_open_device_window, DeviceCtx,
    Fleet, FleetBuilder, Member, MemberCfg, OpenDevice, Partitioner,
};
use super::job::paper_job;
use super::policy::{Action, WindowObservation};
use super::session::{
    serve_closed_window, ConfigError, JobOutcome, PolicySpec, RunConfig,
};
use super::slo::SloClass;
use super::snapshot::{cluster_outcome_to_json, render};

/// Scenario classes the generator cycles through (`case % NUM_CLASSES`):
/// closed TimeShare fleet, MPS fleet, MIG fleet, closed cluster, open
/// cluster, open cluster with churn + migration + autoscaling, open
/// cluster with fault injection (crashes, degrades, repairs, MTBF mode)
/// interleaved with churn and autoscaling, and open cluster with SLO
/// classes (class-weighted shedding/admission and per-class accounting).
pub const NUM_CLASSES: usize = 8;

/// Human-readable name of a generator class.
pub fn class_name(class: usize) -> &'static str {
    match class % NUM_CLASSES {
        0 => "fleet/closed/timeshare",
        1 => "fleet/mps",
        2 => "fleet/mig",
        3 => "cluster/closed",
        4 => "cluster/open",
        5 => "cluster/dynamics",
        6 => "cluster/faults",
        _ => "cluster/slo",
    }
}

// ---------------------------------------------------------------------------
// Scenario genes
// ---------------------------------------------------------------------------

/// A catalogued GPU by name — the generator's device vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuName {
    P40,
    P4,
    T4,
}

impl GpuName {
    pub fn spec(self) -> GpuSpec {
        match self {
            GpuName::P40 => TESLA_P40,
            GpuName::P4 => TESLA_P4,
            GpuName::T4 => TESLA_T4,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            GpuName::P40 => "p40",
            GpuName::P4 => "p4",
            GpuName::T4 => "t4",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "p40" => Some(GpuName::P40),
            "p4" => Some(GpuName::P4),
            "t4" => Some(GpuName::T4),
            _ => None,
        }
    }
}

/// How a fleet divides its GPU's SMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionGene {
    TimeShare,
    Mps,
    Mig { slices: u32 },
}

impl PartitionGene {
    fn mode(self) -> PartitionMode {
        match self {
            PartitionGene::TimeShare => PartitionMode::TimeShare,
            PartitionGene::Mps => PartitionMode::Mps,
            PartitionGene::Mig { slices } => PartitionMode::MigSlices { slices },
        }
    }
}

/// Which placement heuristic assigns cluster jobs to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementGene {
    RoundRobin,
    BestFit,
    Interference,
}

impl PlacementGene {
    fn tag(self) -> &'static str {
        match self {
            PlacementGene::RoundRobin => "rr",
            PlacementGene::BestFit => "bestfit",
            PlacementGene::Interference => "interference",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" => Some(PlacementGene::RoundRobin),
            "bestfit" => Some(PlacementGene::BestFit),
            "interference" => Some(PlacementGene::Interference),
            _ => None,
        }
    }
}

/// A job's serving policy (the deterministic subset — DNNScaler's
/// self-profiling works too but adds profiling windows to every case,
/// so the generator sticks to the cheap controllers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyGene {
    Static { bs: u32, mtl: u32 },
    Clipper,
    QueueAware,
}

impl PolicyGene {
    fn spec(self) -> PolicySpec<'static> {
        match self {
            PolicyGene::Static { bs, mtl } => PolicySpec::Static { bs, mtl },
            PolicyGene::Clipper => PolicySpec::Clipper,
            PolicyGene::QueueAware => PolicySpec::QueueAware,
        }
    }
}

/// A job's arrival process. `Trace` lowers to `count` synthetic
/// timestamps at fixed spacing `1/rate` — enough to exercise the
/// finite-trace drain paths without serializing raw timestamp lists.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalGene {
    Closed,
    Poisson { rate: f64 },
    Uniform { rate: f64 },
    Bursty { rate: f64, factor: f64, period_s: f64, burst_s: f64 },
    Trace { count: usize, rate: f64 },
}

impl ArrivalGene {
    pub fn is_closed(self) -> bool {
        matches!(self, ArrivalGene::Closed)
    }

    fn pattern(self) -> ArrivalPattern {
        match self {
            ArrivalGene::Closed => ArrivalPattern::closed(),
            ArrivalGene::Poisson { rate } => ArrivalPattern::poisson(rate),
            ArrivalGene::Uniform { rate } => ArrivalPattern::uniform(rate),
            ArrivalGene::Bursty { rate, factor, period_s, burst_s } => {
                ArrivalPattern::bursty(rate, factor, period_s, burst_s)
            }
            ArrivalGene::Trace { count, rate } => {
                let step = 1.0 / rate.max(1e-6);
                let ts: Vec<f64> = (0..count.max(1)).map(|i| (i + 1) as f64 * step).collect();
                ArrivalPattern::trace(ts).expect("synthetic trace is monotone and positive")
            }
        }
    }
}

/// One member job: which paper model, how it is controlled, how load
/// arrives, and its per-member queueing knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct JobGene {
    pub paper_id: u32,
    pub policy: PolicyGene,
    pub arrivals: ArrivalGene,
    pub queue_capacity: Option<usize>,
    pub batch_timeout_ms: Option<f64>,
    pub shed_deadline: bool,
    /// Spatial-mode SM reservation (fleet scenarios only; the cluster
    /// builder has no such knob, and `build()` rejects it there).
    pub sm_reservation: Option<f64>,
    /// SLO class (open-loop only; the builders reject it on closed
    /// members, which the generator never draws).
    pub slo: Option<SloClass>,
}

impl JobGene {
    fn simple(paper_id: u32, policy: PolicyGene, arrivals: ArrivalGene) -> Self {
        JobGene {
            paper_id,
            policy,
            arrivals,
            queue_capacity: None,
            batch_timeout_ms: None,
            shed_deadline: false,
            sm_reservation: None,
            slo: None,
        }
    }
}

/// One cluster device: a catalogued card, optionally pre-split into MIG
/// slices (each slice becomes its own virtual device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceGene {
    pub gpu: GpuName,
    pub mig: Option<u32>,
}

/// One churn event. Retires reference paper job ids (first live match),
/// exactly like [`ChurnSchedule::retire`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnGene {
    Launch { window: usize, paper_id: u32, rate: f64 },
    Retire { window: usize, paper_id: u32 },
}

/// One fault-injection event, mirroring [`FaultEvent`] (device indices
/// are pool positions, windows are control-window indices).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultGene {
    Crash { window: usize, device: usize },
    Degrade { window: usize, device: usize, factor: f64, for_windows: usize },
    Repair { window: usize, device: usize },
}

/// Optional warehouse dynamics riding on a cluster scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicsGene {
    pub churn: Vec<ChurnGene>,
    /// Periodic re-placement: heuristic + period in windows.
    pub migrate: Option<(PlacementGene, usize)>,
    /// Threshold autoscaler bounds: (min_devices, max_devices).
    pub autoscale: Option<(usize, usize)>,
    /// Explicit fault schedule (validated by the cluster builder).
    pub faults: Vec<FaultGene>,
    /// Stochastic fault mode: (mtbf_windows, mttr_windows).
    pub mtbf: Option<(f64, f64)>,
}

impl DynamicsGene {
    fn is_empty(&self) -> bool {
        self.churn.is_empty()
            && self.migrate.is_none()
            && self.autoscale.is_none()
            && self.faults.is_empty()
            && self.mtbf.is_none()
    }
}

/// Whether the scenario is a single shared-GPU fleet or a multi-device
/// cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioKind {
    Fleet { gpu: GpuName, partition: PartitionGene },
    Cluster { devices: Vec<DeviceGene>, placement: PlacementGene },
}

/// A complete, serializable description of one randomized run.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub seed: u64,
    pub windows: usize,
    pub rounds: usize,
    pub threads: usize,
    pub kind: ScenarioKind,
    pub jobs: Vec<JobGene>,
    pub dynamics: Option<DynamicsGene>,
}

/// Either validated builder output, ready to serve.
pub enum Built<'a> {
    Fleet(Fleet<'a>),
    Cluster(Cluster<'a>),
}

impl Scenario {
    /// Number of devices the scenario declares (a fleet is one device).
    pub fn device_count(&self) -> usize {
        match &self.kind {
            ScenarioKind::Fleet { .. } => 1,
            ScenarioKind::Cluster { devices, .. } => devices.len(),
        }
    }

    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    /// Lower the genes through the SAME public builders the fast path
    /// uses — validation, placement, and churn checking included.
    pub fn build(&self) -> Result<Built<'static>, ConfigError> {
        match &self.kind {
            ScenarioKind::Fleet { gpu, partition } => {
                if self.dynamics.as_ref().is_some_and(|d| !d.is_empty()) {
                    return Err(ConfigError::BadChurn {
                        reason: "dynamics require a cluster scenario".into(),
                    });
                }
                let mut b = Fleet::builder()
                    .gpu(gpu.spec())
                    .windows(self.windows)
                    .rounds_per_window(self.rounds)
                    .seed(self.seed)
                    .partition_mode(partition.mode());
                for j in &self.jobs {
                    b = add_fleet_job(b, j)?;
                }
                b.build().map(Built::Fleet)
            }
            ScenarioKind::Cluster { devices, placement } => {
                let mut b = Cluster::builder()
                    .windows(self.windows)
                    .rounds_per_window(self.rounds)
                    .seed(self.seed)
                    .threads(self.threads);
                for d in devices {
                    b = match d.mig {
                        Some(slices) => b.mig_device(d.gpu.spec(), slices),
                        None => b.device(d.gpu.spec()),
                    };
                }
                b = match placement {
                    PlacementGene::RoundRobin => b.placement(RoundRobin::new()),
                    PlacementGene::BestFit => b.placement(BestFit::new()),
                    PlacementGene::Interference => b.placement(InterferenceAware::new()),
                };
                for j in &self.jobs {
                    if j.sm_reservation.is_some() {
                        // The cluster builder has no reservation knob;
                        // refusing keeps "scenario == what runs" honest.
                        return Err(ConfigError::KnobRequiresPartition {
                            knob: "sm_reservation",
                        });
                    }
                    let spec = paper_job(j.paper_id).ok_or_else(|| {
                        ConfigError::UnknownDnn { dnn: format!("paper job {}", j.paper_id) }
                    })?;
                    b = b.job_with_arrivals(spec, j.policy.spec(), j.arrivals.pattern());
                    if let Some(cap) = j.queue_capacity {
                        b = b.queue_capacity(cap);
                    }
                    if let Some(t) = j.batch_timeout_ms {
                        b = b.batch_timeout_ms(t);
                    }
                    if j.shed_deadline {
                        b = b.shed_deadline(true);
                    }
                    if let Some(c) = j.slo {
                        b = b.slo_class(c);
                    }
                }
                if let Some(dy) = &self.dynamics {
                    if !dy.churn.is_empty() {
                        let mut sched = ChurnSchedule::new();
                        for e in &dy.churn {
                            sched = match *e {
                                ChurnGene::Launch { window, paper_id, rate } => {
                                    let spec = paper_job(paper_id).ok_or_else(|| {
                                        ConfigError::UnknownDnn {
                                            dnn: format!("paper job {paper_id}"),
                                        }
                                    })?;
                                    sched.launch(
                                        window,
                                        spec,
                                        PolicySpec::Static { bs: 2, mtl: 1 },
                                        ArrivalPattern::poisson(rate),
                                    )
                                }
                                ChurnGene::Retire { window, paper_id } => {
                                    sched.retire(window, paper_id)
                                }
                            };
                        }
                        b = b.churn(sched);
                    }
                    if let Some((heur, every)) = dy.migrate {
                        b = match heur {
                            PlacementGene::RoundRobin => {
                                b.placement_policy(PeriodicReplace::new(RoundRobin::new(), every))
                            }
                            PlacementGene::BestFit => {
                                b.placement_policy(PeriodicReplace::new(BestFit::new(), every))
                            }
                            PlacementGene::Interference => b.placement_policy(
                                PeriodicReplace::new(InterferenceAware::new(), every),
                            ),
                        };
                    }
                    if let Some((min, max)) = dy.autoscale {
                        b = b.autoscaler(ThresholdAutoscaler::new(min, max));
                    }
                    if !dy.faults.is_empty() {
                        let mut sched = FaultSchedule::new();
                        for f in &dy.faults {
                            sched = match *f {
                                FaultGene::Crash { window, device } => {
                                    sched.crash(device, window)
                                }
                                FaultGene::Degrade { window, device, factor, for_windows } => {
                                    sched.degrade(device, window, factor, for_windows)
                                }
                                FaultGene::Repair { window, device } => {
                                    sched.repair(device, window)
                                }
                            };
                        }
                        b = b.faults(sched);
                    }
                    if let Some((mtbf, mttr)) = dy.mtbf {
                        b = b.stochastic_faults(mtbf, mttr);
                    }
                }
                b.build().map(Built::Cluster)
            }
        }
    }

    /// Does the scenario pass builder validation?
    pub fn builds(&self) -> bool {
        self.build().is_ok()
    }
}

fn add_fleet_job(
    mut b: FleetBuilder<'static>,
    j: &JobGene,
) -> Result<FleetBuilder<'static>, ConfigError> {
    let spec = paper_job(j.paper_id)
        .ok_or_else(|| ConfigError::UnknownDnn { dnn: format!("paper job {}", j.paper_id) })?;
    b = b.job_with_arrivals(spec, j.policy.spec(), j.arrivals.pattern());
    if let Some(cap) = j.queue_capacity {
        b = b.queue_capacity(cap);
    }
    if let Some(t) = j.batch_timeout_ms {
        b = b.batch_timeout_ms(t);
    }
    if j.shed_deadline {
        b = b.shed_deadline(true);
    }
    if let Some(f) = j.sm_reservation {
        b = b.sm_reservation(f);
    }
    if let Some(c) = j.slo {
        b = b.slo_class(c);
    }
    Ok(b)
}

// ---------------------------------------------------------------------------
// Fast executor
// ---------------------------------------------------------------------------

/// Run the scenario through the production engine. The outer `Result`
/// is builder validation; the inner is the run itself.
pub fn run_fast(sc: &Scenario) -> Result<Result<ClusterOutcome, DeviceError>, ConfigError> {
    match sc.build()? {
        Built::Fleet(f) => {
            let gpu = fleet_gpu(sc);
            let n = sc.jobs.len();
            Ok(f.run().map(|out| wrap_fleet_outcome(out, gpu, n)))
        }
        Built::Cluster(c) => Ok(c.run()),
    }
}

fn fleet_gpu(sc: &Scenario) -> GpuSpec {
    match &sc.kind {
        ScenarioKind::Fleet { gpu, .. } => gpu.spec(),
        ScenarioKind::Cluster { .. } => unreachable!("fleet_gpu on a cluster scenario"),
    }
}

/// Lift a single-GPU fleet outcome into the `ClusterOutcome` shape so
/// every scenario class diffs and audits through one code path.
fn wrap_fleet_outcome(fleet: super::fleet::FleetOutcome, gpu: GpuSpec, jobs: usize) -> ClusterOutcome {
    let total_throughput = fleet.total_throughput;
    let total_goodput = fleet.total_goodput;
    let slo = fleet.slo.clone();
    ClusterOutcome {
        devices: vec![DeviceOutcome {
            device: whole_desc(gpu, 0),
            jobs: (0..jobs).collect(),
            fleet,
        }],
        placement: "fleet".to_string(),
        assignment: vec![0; jobs],
        total_throughput,
        total_goodput,
        dynamics: None,
        slo,
    }
}

// ---------------------------------------------------------------------------
// Reference executor
// ---------------------------------------------------------------------------

/// Run the scenario through the naive reference executor: same validated
/// configuration, same planning arithmetic, but device-outer loops, an
/// O(M) min-scan scheduler instead of the calendar, fresh accumulators
/// every window, and no threads. The outer `Result` is builder
/// validation; the inner is the run.
pub fn run_reference(sc: &Scenario) -> Result<Result<ClusterOutcome, DeviceError>, ConfigError> {
    match sc.build()? {
        Built::Fleet(f) => {
            let gpu = fleet_gpu(sc);
            let n = sc.jobs.len();
            Ok(reference_fleet(f).map(|out| wrap_fleet_outcome(out, gpu, n)))
        }
        Built::Cluster(c) => Ok(reference_cluster(c)),
    }
}

fn reference_fleet(f: Fleet<'_>) -> Result<super::fleet::FleetOutcome, DeviceError> {
    let closed = f.members.iter().all(|m| m.arrivals.is_closed());
    let Fleet { gpu, cfg, seed, members, partition, partition_policy } = f;
    let parts = Partitioner::new(partition, &members, partition_policy, gpu.mem_mb);
    if closed {
        let mut states: Vec<Member<'_>> = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            states.push(new_closed_member(m, &cfg, seed + i as u64)?);
        }
        let mut ctx = DeviceCtx::new(gpu.mem_mb, 1.0, parts, cfg.windows);
        for w in 0..cfg.windows {
            reference_closed_window(&cfg, w, &mut ctx, &mut states)?;
        }
        let outcomes = states.into_iter().map(closed_member_outcome).collect();
        Ok(finish_fleet(outcomes, ctx, partition))
    } else {
        let mut states = Vec::with_capacity(members.len());
        for (i, m) in members.into_iter().enumerate() {
            states.push(new_open_member(m, &cfg, seed + i as u64, arrival_seed(seed, i))?);
        }
        let mut dev = OpenDevice::new(DeviceCtx::new(gpu.mem_mb, 1.0, parts, cfg.windows), states);
        for w in 0..cfg.windows {
            reference_open_window(&cfg, w, &mut dev)?;
        }
        let outcomes = dev.members.into_iter().map(open_member_outcome).collect();
        Ok(finish_fleet(outcomes, dev.ctx, partition))
    }
}

/// One closed-loop control window, written out longhand (the fast
/// engine's window body is private on purpose — the reference must not
/// share orchestration code, only planning arithmetic).
fn reference_closed_window(
    cfg: &RunConfig,
    w: usize,
    ctx: &mut DeviceCtx<'_>,
    states: &mut [Member<'_>],
) -> Result<(), DeviceError> {
    if states.is_empty() {
        return Ok(());
    }
    let requested: Vec<(u32, u32)> = states.iter().map(|m| m.policy.operating_point()).collect();
    let mut points = admit_window(
        &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
        states.len(),
        &requested,
        None,
        ctx.mem_capacity_mb,
        &mut ctx.admission_clamps,
    )?;
    let g = ctx.perf_fraction;
    let shares = ctx.parts.window_shares(
        || {
            states
                .iter()
                .zip(&points)
                .map(|(m, &(bs, mtl))| {
                    if g >= 1.0 {
                        m.sim.sm_utilization(bs, mtl)
                    } else {
                        m.sim.sm_utilization_granted(bs, mtl, g)
                    }
                })
                .sum()
        },
        states.len(),
        ctx.perf_fraction,
        &mut ctx.peak_contention,
        &mut ctx.contention_trace,
        &mut ctx.grant_trace,
    )?;
    if let Some(grants) = ctx.grant_trace.last() {
        clamp_to_slice_ceilings(
            ctx.parts.mode(),
            grants,
            ctx.mem_capacity_mb,
            &|i, (bs, mtl)| states[i].sim.mem_demand_mb(bs, mtl),
            &mut points,
            &mut ctx.admission_clamps,
        )?;
    }
    let resident: f64 = states
        .iter()
        .zip(&points)
        .map(|(m, &(bs, mtl))| m.sim.mem_demand_mb(bs, mtl))
        .sum();
    ctx.peak_mem_mb = ctx.peak_mem_mb.max(resident);

    let mut window_obs: Vec<WindowObservation> = Vec::with_capacity(states.len());
    for (i, m) in states.iter_mut().enumerate() {
        let (bs, mtl) = points[i];
        let slo = m.schedule.at(w);
        let pending = m.pending_launch_ms;
        m.pending_launch_ms = 0.0;
        m.admitted = (bs, mtl);
        let (record, obs) = serve_closed_window(
            cfg,
            w,
            slo,
            (bs, mtl),
            shares[i],
            pending,
            &mut m.sim,
            &mut m.window,
            &mut m.latencies,
            &mut m.acc,
        )?;
        m.trace.push(record);
        let requested_mtl = requested[i].1;
        if let Action::SetPoint { mtl: new_mtl, .. } = m.policy.observe(&obs) {
            if new_mtl > requested_mtl {
                m.pending_launch_ms +=
                    m.sim.launch_overhead_ms() * (new_mtl - requested_mtl) as f64;
            }
        }
        window_obs.push(obs);
    }
    if let Some(grants) = ctx.grant_trace.last() {
        ctx.parts.maybe_rebalance(&window_obs, grants, &mut ctx.admission_clamps);
    }
    Ok(())
}

/// One open-loop control window: shared planning, then a naive member
/// scheduler — scan every member for the smallest virtual clock (ties
/// to the lowest index, the calendar's tie rule) and serve one round.
/// Fresh `WindowAccum`s each window instead of the engine's recycled
/// per-member accumulators.
fn reference_open_window(
    cfg: &RunConfig,
    w: usize,
    dev: &mut OpenDevice<'_>,
) -> Result<(), DeviceError> {
    if dev.members.is_empty() {
        return Ok(());
    }
    let (points, shares) = plan_open_device_window(dev)?;
    let states = &mut dev.members;
    let slos: Vec<f64> = states.iter_mut().map(|m| m.schedule.at(w)).collect();
    let mut wins: Vec<WindowAccum> = states
        .iter()
        .map(|st| {
            let mut a = WindowAccum::new();
            a.begin(&st.lp);
            a
        })
        .collect();
    let mut remaining = vec![cfg.rounds_per_window; states.len()];
    let mut live = vec![true; states.len()];
    loop {
        let mut pick: Option<usize> = None;
        for k in 0..states.len() {
            if !live[k] {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => states[k].lp.now_s < states[p].lp.now_s,
            };
            if better {
                pick = Some(k);
            }
        }
        let Some(k) = pick else { break };
        remaining[k] -= 1;
        let st = &mut states[k];
        let more = st.lp.serve_round(points[k], slos[k], shares[k], &mut st.sim, &mut wins[k])?;
        if !more || remaining[k] == 0 {
            live[k] = false;
        }
    }
    let mut window_obs: Vec<WindowObservation> = Vec::with_capacity(states.len());
    for (k, st) in states.iter_mut().enumerate() {
        st.admitted = points[k];
        let (record, obs) = wins[k].finish(w, slos[k], points[k], &st.lp);
        st.acc.absorb(w, slos[k], wins[k].latencies());
        st.latencies.extend(wins[k].latencies().iter().map(|&l| (l, 1.0)));
        st.trace.push(record);
        st.policy.observe(&obs);
        window_obs.push(obs);
    }
    let ctx = &mut dev.ctx;
    if let Some(grants) = ctx.grant_trace.last() {
        ctx.parts.maybe_rebalance(&window_obs, grants, &mut ctx.admission_clamps);
    }
    Ok(())
}

fn reference_cluster(c: Cluster<'_>) -> Result<ClusterOutcome, DeviceError> {
    let Cluster { cfg, seed, devices, jobs, placement, assignment, dynamics, threads: _ } = c;
    if let Some(dc) = dynamics {
        return reference_dynamic(&cfg, seed, devices, jobs, placement, assignment, dc);
    }
    let open = !jobs.iter().all(|m| m.arrivals.is_closed());
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); devices.len()];
    for (j, &d) in assignment.device_of.iter().enumerate() {
        groups[d].push(j);
    }
    let mut cfgs: Vec<Option<MemberCfg<'_>>> = jobs.into_iter().map(Some).collect();

    // Device-outer serving: devices never couple, so running device d to
    // completion before touching d+1 must reproduce the interleaved fast
    // engine byte for byte — and surfaces the lowest failing device's
    // first error, the same one the fast path reports.
    let outcomes: Vec<DeviceOutcome> = if open {
        let mut devs: Vec<OpenDevice<'_>> = Vec::with_capacity(devices.len());
        for (desc, group) in devices.iter().zip(&groups) {
            let mut members = Vec::with_capacity(group.len());
            for &j in group {
                let m = cfgs[j].take().expect("job placed once");
                members.push(new_open_member(m, &cfg, seed + j as u64, arrival_seed(seed, j))?);
            }
            devs.push(OpenDevice::new(timeshare_ctx(desc, group.len(), &cfg), members));
        }
        for dev in devs.iter_mut() {
            for w in 0..cfg.windows {
                reference_open_window(&cfg, w, dev)?;
            }
        }
        fold_device_outcomes(&devices, &groups, devs, |dev| {
            (dev.ctx, dev.members.into_iter().map(open_member_outcome).collect())
        })
    } else {
        let mut devs: Vec<(DeviceCtx<'_>, Vec<Member<'_>>)> = Vec::with_capacity(devices.len());
        for (desc, group) in devices.iter().zip(&groups) {
            let mut members = Vec::with_capacity(group.len());
            for &j in group {
                let m = cfgs[j].take().expect("job placed once");
                members.push(new_closed_member(m, &cfg, seed + j as u64)?);
            }
            devs.push((timeshare_ctx(desc, group.len(), &cfg), members));
        }
        for (ctx, states) in devs.iter_mut() {
            for w in 0..cfg.windows {
                reference_closed_window(&cfg, w, ctx, states)?;
            }
        }
        fold_device_outcomes(&devices, &groups, devs, |(ctx, members)| {
            (ctx, members.into_iter().map(closed_member_outcome).collect())
        })
    };
    let total_throughput = outcomes.iter().map(|d| d.fleet.total_throughput).sum();
    let total_goodput = outcomes.iter().map(|d| d.fleet.total_goodput).sum();
    let slo = merge_slo_reports(&outcomes);
    Ok(ClusterOutcome {
        devices: outcomes,
        placement,
        assignment: assignment.device_of,
        total_throughput,
        total_goodput,
        dynamics: None,
        slo,
    })
}

/// Naive mirror of `dynamics::run_dynamic`: identical churn, migration,
/// autoscaling, close, and billing steps (those ARE the semantics under
/// test, not an optimization), but the serving step walks devices in
/// pool order with the O(M) min-scan scheduler and fresh accumulators —
/// no global calendar, no recycled state, no spans, no threads.
fn reference_dynamic<'a>(
    cfg: &RunConfig,
    seed: u64,
    mut descs: Vec<super::cluster::DeviceDesc>,
    jobs: Vec<MemberCfg<'a>>,
    placement: String,
    assignment: Assignment,
    dynamics: DynamicsCfg<'a>,
) -> Result<ClusterOutcome, DeviceError> {
    let DynamicsCfg { churn, mut policy, mut autoscaler, faults } = dynamics;
    let mut dyn_out = DynamicsOutcome::default();

    let mut events_at: Vec<Vec<JobEvent<'a>>> = (0..cfg.windows).map(|_| Vec::new()).collect();
    for e in churn.events {
        let w = e.window();
        events_at[w].push(e);
    }

    // Fault schedule grouped by window (verbatim semantics: the fault
    // and recovery arithmetic IS what is under test, so the reference
    // mirrors it step for step — only the serving loop stays naive).
    let have_faults = faults.is_some();
    let failover_enabled = faults.as_ref().map_or(true, |f| f.failover);
    let mut fault_at: Vec<Vec<FaultEvent>> = (0..cfg.windows).map(|_| Vec::new()).collect();
    if let Some(f) = faults {
        for e in f.events {
            let w = e.window();
            fault_at[w].push(e);
        }
    }
    let mut fo = FaultsOutcome::default();

    let template = descs[0].spec.clone();
    let mut next_physical = descs.iter().map(|d| d.physical + 1).max().unwrap_or(0);
    let mut ctxs: Vec<DeviceCtx<'a>> = descs
        .iter()
        .map(|d| DeviceCtx::new(d.mem_mb, d.perf_fraction, Partitioner::timeshare(0), cfg.windows))
        .collect();
    let mut active = vec![true; descs.len()];
    let mut crashed = vec![false; descs.len()];
    let mut degrade: Vec<(f64, usize)> = vec![(1.0, 0); descs.len()];
    let mut pending: Vec<Pending<'a>> = Vec::new();

    let mut lives: Vec<Live<'a>> = Vec::new();
    let mut ended: Vec<(usize, usize, JobOutcome)> = Vec::new();
    let mut next_job_idx = 0usize;
    for (m, &d) in jobs.into_iter().zip(&assignment.device_of) {
        let j = next_job_idx;
        next_job_idx += 1;
        let pjob = PlacementJob::from_cfg(&m);
        lives.push(Live {
            job_idx: j,
            device: d,
            pjob,
            m: new_open_member(m, cfg, seed + j as u64, arrival_seed(seed, j))?,
            win: WindowAccum::new(),
            last_obs: None,
        });
    }

    let mut elapsed_s = 0.0f64;
    let mut pressures: Vec<f64> = vec![0.0; descs.len()];

    for w in 0..cfg.windows {
        // -- 0. Faults (verbatim semantics). --
        for e in std::mem::take(&mut fault_at[w]) {
            match e {
                FaultEvent::Crash { device, .. } => {
                    crashed[device] = true;
                    active[device] = false;
                    fo.crashes += 1;
                    let mut li = 0;
                    while li < lives.len() {
                        if lives[li].device != device {
                            li += 1;
                            continue;
                        }
                        fo.dropped_failure += lives[li].m.lp.fail_queue();
                        let need = lives[li].pjob.mem_floor_mb;
                        let dest = if failover_enabled {
                            let free = free_mb(&descs, &lives);
                            most_free_fit(&free, &active, need)
                        } else {
                            None
                        };
                        match dest {
                            Some(d) => {
                                let stall = model_load_ms(need);
                                let l = &mut lives[li];
                                l.m.lp.stall_ms(stall);
                                l.device = d;
                                fo.failovers += 1;
                                fo.failover_stall_ms += stall;
                                li += 1;
                            }
                            None => {
                                let live = lives.remove(li);
                                pending.push(Pending {
                                    live,
                                    kind: PendingKind::Failover,
                                    next_retry: if failover_enabled {
                                        w + 1
                                    } else {
                                        usize::MAX
                                    },
                                    backoff: 1,
                                });
                                fo.deferred_jobs += 1;
                            }
                        }
                    }
                }
                FaultEvent::Degrade { device, factor, for_windows, .. } => {
                    degrade[device] = (factor, for_windows);
                    fo.degrades += 1;
                }
                FaultEvent::Repair { device, .. } => {
                    crashed[device] = false;
                    active[device] = true;
                    fo.repairs += 1;
                }
            }
        }

        // -- 1. Churn (verbatim semantics). --
        for e in std::mem::take(&mut events_at[w]) {
            match e {
                JobEvent::Retire { job_id, .. } => {
                    if let Some(pos) = lives.iter().position(|l| l.m.job.id == job_id) {
                        let l = lives.remove(pos);
                        ended.push((l.job_idx, l.device, open_member_outcome(l.m)));
                        dyn_out.retires += 1;
                    }
                }
                JobEvent::Launch { job, policy: pol, arrivals, .. } => {
                    let j = next_job_idx;
                    next_job_idx += 1;
                    let cfg_m = MemberCfg::new(&job, pol, arrivals);
                    let pjob = PlacementJob::from_cfg(&cfg_m);
                    let free = free_mb(&descs, &lives);
                    let Some(d) = most_free_fit(&free, &active, pjob.mem_floor_mb) else {
                        if descs.iter().all(|dd| dd.mem_mb < pjob.mem_floor_mb) {
                            dyn_out.failed_launches += 1;
                            continue;
                        }
                        let m = new_open_member(
                            cfg_m,
                            cfg,
                            seed + j as u64,
                            arrival_seed(seed, j),
                        )?;
                        pending.push(Pending {
                            live: Live {
                                job_idx: j,
                                device: usize::MAX,
                                pjob,
                                m,
                                win: WindowAccum::new(),
                                last_obs: None,
                            },
                            kind: PendingKind::Launch,
                            next_retry: w + 1,
                            backoff: 1,
                        });
                        dyn_out.deferred_launches += 1;
                        fo.deferred_jobs += 1;
                        continue;
                    };
                    let mut m = new_open_member(cfg_m, cfg, seed + j as u64, arrival_seed(seed, j))?;
                    m.lp.stall_ms(model_load_ms(pjob.mem_floor_mb));
                    lives.push(Live {
                        job_idx: j,
                        device: d,
                        pjob,
                        m,
                        win: WindowAccum::new(),
                        last_obs: None,
                    });
                    dyn_out.launches += 1;
                }
            }
        }

        // -- 2. Pending retry (verbatim semantics). --
        let mut pi = 0;
        while pi < pending.len() {
            if pending[pi].next_retry > w {
                pi += 1;
                continue;
            }
            let need = pending[pi].live.pjob.mem_floor_mb;
            let free = free_mb(&descs, &lives);
            match most_free_fit(&free, &active, need) {
                Some(d) => {
                    let p = pending.remove(pi);
                    let mut live = p.live;
                    let stall = model_load_ms(need);
                    live.m.lp.stall_ms(stall);
                    live.device = d;
                    match p.kind {
                        PendingKind::Launch => dyn_out.launches += 1,
                        PendingKind::Failover => {
                            fo.failovers += 1;
                            fo.failover_stall_ms += stall;
                        }
                    }
                    lives.push(live);
                }
                None => {
                    let p = &mut pending[pi];
                    p.backoff = (p.backoff * 2).min(MAX_BACKOFF_WINDOWS);
                    p.next_retry = w + p.backoff;
                    pi += 1;
                }
            }
        }

        // -- 3. Live migration (verbatim semantics). --
        if let Some(pol) = policy.as_mut() {
            let active_idx: Vec<usize> = (0..descs.len()).filter(|&d| active[d]).collect();
            let active_descs: Vec<super::cluster::DeviceDesc> =
                active_idx.iter().map(|&d| descs[d].clone()).collect();
            let pjobs: Vec<PlacementJob> = lives.iter().map(|l| l.pjob.clone()).collect();
            let current: Vec<usize> = lives
                .iter()
                .map(|l| active_idx.iter().position(|&d| d == l.device).unwrap_or(0))
                .collect();
            let obs: Vec<WindowObservation> =
                lives.iter().map(|l| l.last_obs.unwrap_or_else(|| blank_obs(w))).collect();
            if let Some(proposal) = pol.replace(&pjobs, &active_descs, &current, &obs) {
                let a = Assignment { device_of: proposal };
                if a.validate(&pjobs, &active_descs).is_ok() {
                    for (l, &to_active) in lives.iter_mut().zip(&a.device_of) {
                        let to = active_idx[to_active];
                        if to != l.device {
                            let stall = model_load_ms(l.pjob.mem_floor_mb);
                            l.m.lp.stall_ms(stall);
                            l.device = to;
                            dyn_out.migrations += 1;
                            dyn_out.migration_stall_ms += stall;
                        }
                    }
                } else {
                    dyn_out.rejected_proposals += 1;
                }
            }
        }

        // -- 4. Autoscaling (verbatim semantics). --
        if let Some(scaler) = autoscaler.as_mut() {
            let n_active = active.iter().filter(|&&a| a).count();
            let (sum_p, max_p) = (0..descs.len())
                .filter(|&d| active[d])
                .fold((0.0f64, 0.0f64), |(s, mx), d| (s + pressures[d], mx.max(pressures[d])));
            let action = {
                let obs = PoolObservation {
                    window: w,
                    active_devices: n_active,
                    live_jobs: lives.len(),
                    mean_pressure: if n_active > 0 { sum_p / n_active as f64 } else { 0.0 },
                    max_pressure: max_p,
                    queue_depth: lives.iter().map(|l| l.m.lp.queue_len()).sum(),
                    drops: lives
                        .iter()
                        .filter_map(|l| l.last_obs.as_ref())
                        .map(|o| o.drops + o.drops_deadline)
                        .sum(),
                    devices: &descs,
                    active: &active,
                };
                scaler.scale(&obs)
            };
            match action {
                ScaleAction::Hold => {}
                ScaleAction::Grow => {
                    if let Some(d) = (0..descs.len()).find(|&d| !active[d] && !crashed[d]) {
                        active[d] = true;
                    } else {
                        let desc = whole_desc(template.clone(), next_physical);
                        next_physical += 1;
                        ctxs.push(DeviceCtx::new(
                            desc.mem_mb,
                            desc.perf_fraction,
                            Partitioner::timeshare(0),
                            cfg.windows,
                        ));
                        descs.push(desc);
                        active.push(true);
                        crashed.push(false);
                        degrade.push((1.0, 0));
                        pressures.push(0.0);
                    }
                    dyn_out.scale_ups += 1;
                }
                ScaleAction::Shrink => {
                    let victim = (0..descs.len()).filter(|&d| active[d]).min_by_key(|&d| {
                        (lives.iter().filter(|l| l.device == d).count(), usize::MAX - d)
                    });
                    if let Some(v) = victim {
                        if try_evacuate(v, &descs, &active, &mut lives, &mut dyn_out) {
                            active[v] = false;
                            dyn_out.scale_downs += 1;
                        }
                    }
                }
            }
        }
        dyn_out.pool_trace.push(active.iter().filter(|&&a| a).count());
        fo.pool_health.push((0..descs.len()).filter(|&d| !crashed[d]).count());

        // -- 5. Serve naively: plan each device in pool order (same
        //       coupling as the fast path), then run each device's
        //       members through the O(M) min-scan loop. --
        for p in pressures.iter_mut() {
            *p = 0.0;
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); descs.len()];
        for (li, l) in lives.iter().enumerate() {
            groups[l.device].push(li);
        }
        let mut flat: Vec<usize> = Vec::new();
        let mut plan: Vec<((u32, u32), SmShare, f64)> = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::new();
        for d in 0..descs.len() {
            if groups[d].is_empty() {
                continue;
            }
            let ctx = &mut ctxs[d];
            let members = &groups[d];
            let requested: Vec<(u32, u32)> =
                members.iter().map(|&li| lives[li].m.policy.operating_point()).collect();
            // Class weights rebuilt per window from the device's current
            // residents — verbatim mirror of the dynamic fast path.
            let weights: Option<Vec<f64>> = members
                .iter()
                .any(|&li| lives[li].m.slo_class.is_some())
                .then(|| {
                    members
                        .iter()
                        .map(|&li| lives[li].m.slo_class.map_or(1.0, SloClass::shed_weight))
                        .collect()
                });
            let pts = admit_window(
                &|i, (bs, mtl)| lives[members[i]].m.sim.mem_demand_mb(bs, mtl),
                members.len(),
                &requested,
                weights.as_deref(),
                ctx.mem_capacity_mb,
                &mut ctx.admission_clamps,
            )?;
            let g = ctx.perf_fraction * degrade[d].0;
            let shr = ctx.parts.window_shares(
                || {
                    members
                        .iter()
                        .zip(&pts)
                        .map(|(&li, &(bs, mtl))| {
                            let sim = &lives[li].m.sim;
                            if g >= 1.0 {
                                sim.sm_utilization(bs, mtl)
                            } else {
                                sim.sm_utilization_granted(bs, mtl, g)
                            }
                        })
                        .sum()
                },
                members.len(),
                g,
                &mut ctx.peak_contention,
                &mut ctx.contention_trace,
                &mut ctx.grant_trace,
            )?;
            pressures[d] = ctx.contention_trace.last().copied().unwrap_or(0.0);
            let resident: f64 = members
                .iter()
                .zip(&pts)
                .map(|(&li, &(bs, mtl))| lives[li].m.sim.mem_demand_mb(bs, mtl))
                .sum();
            ctx.peak_mem_mb = ctx.peak_mem_mb.max(resident);
            let span_start = flat.len();
            for ((&li, &pt), sh) in members.iter().zip(&pts).zip(shr) {
                let l = &mut lives[li];
                let slo = l.m.schedule.at(w);
                // Fresh accumulator every window — the naive analogue of
                // the engine's recycled per-member scratch.
                l.win = WindowAccum::new();
                l.win.begin(&l.m.lp);
                flat.push(li);
                plan.push((pt, sh, slo));
            }
            spans.push((span_start, flat.len() - span_start));
        }

        for &(start, len) in &spans {
            reference_serve_span(cfg, &mut lives, &flat, &plan, start, len)?;
        }

        // -- 6. Close the window (verbatim semantics). --
        for (f, &li) in flat.iter().enumerate() {
            let l = &mut lives[li];
            let (pt, _, slo) = plan[f];
            l.m.admitted = pt;
            let (record, obs) = l.win.finish(w, slo, pt, &l.m.lp);
            l.m.acc.absorb(w, slo, l.win.latencies());
            l.m.latencies.extend(l.win.latencies().iter().map(|&lat| (lat, 1.0)));
            l.m.trace.push(record);
            l.m.policy.observe(&obs);
            l.last_obs = Some(obs);
        }

        // -- 7. Billing (verbatim semantics). --
        let now_max = lives.iter().map(|l| l.m.lp.now_s).fold(elapsed_s, f64::max);
        let span_h = (now_max - elapsed_s) / 3600.0;
        elapsed_s = now_max;
        for d in 0..descs.len() {
            if active[d] {
                dyn_out.device_hours += span_h;
                dyn_out.cost_usd += descs[d].price_per_hour * span_h;
            }
        }

        // Degrade timers tick per served window (verbatim semantics).
        for dg in degrade.iter_mut() {
            if dg.1 > 0 {
                dg.1 -= 1;
                if dg.1 == 0 {
                    dg.0 = 1.0;
                }
            }
        }
    }

    // End-of-run pendings (verbatim semantics): deferred launches never
    // served, stranded crash victims finalize as-is.
    for p in pending {
        match p.kind {
            PendingKind::Launch => dyn_out.failed_launches += 1,
            PendingKind::Failover => {
                ended.push((p.live.job_idx, p.live.device, open_member_outcome(p.live.m)));
            }
        }
    }

    for l in lives {
        ended.push((l.job_idx, l.device, open_member_outcome(l.m)));
    }
    ended.sort_by_key(|&(j, _, _)| j);

    let device_of: Vec<usize> = ended.iter().map(|&(_, d, _)| d).collect();
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); descs.len()];
    let mut outs: Vec<Vec<JobOutcome>> = (0..descs.len()).map(|_| Vec::new()).collect();
    for (j, d, out) in ended {
        groups[d].push(j);
        outs[d].push(out);
    }
    let devices: Vec<DeviceOutcome> = descs
        .iter()
        .zip(groups)
        .zip(ctxs.into_iter().zip(outs))
        .map(|((desc, group), (ctx, members))| DeviceOutcome {
            device: desc.clone(),
            jobs: group,
            fleet: finish_fleet(members, ctx, PartitionMode::TimeShare),
        })
        .collect();
    let total_throughput = devices.iter().map(|d| d.fleet.total_throughput).sum();
    let total_goodput: f64 = devices.iter().map(|d| d.fleet.total_goodput).sum();
    dyn_out.cost_per_goodput = (total_goodput > 0.0).then(|| dyn_out.cost_usd / total_goodput);
    if have_faults {
        dyn_out.faults = Some(fo);
    }
    let slo = merge_slo_reports(&devices);
    Ok(ClusterOutcome {
        devices,
        placement,
        assignment: device_of,
        total_throughput,
        total_goodput,
        dynamics: Some(dyn_out),
        slo,
    })
}

/// Serve one device's window slots by repeatedly scanning for the
/// member with the smallest virtual clock (ties to the lowest index).
fn reference_serve_span(
    cfg: &RunConfig,
    lives: &mut [Live<'_>],
    flat: &[usize],
    plan: &[((u32, u32), SmShare, f64)],
    start: usize,
    len: usize,
) -> Result<(), DeviceError> {
    let mut remaining = vec![cfg.rounds_per_window; len];
    let mut live = vec![true; len];
    loop {
        let mut pick: Option<usize> = None;
        for k in 0..len {
            if !live[k] {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => lives[flat[start + k]].m.lp.now_s < lives[flat[start + p]].m.lp.now_s,
            };
            if better {
                pick = Some(k);
            }
        }
        let Some(k) = pick else { break };
        remaining[k] -= 1;
        let l = &mut lives[flat[start + k]];
        let (pt, sh, slo) = plan[start + k];
        let more = l.m.lp.serve_round(pt, slo, sh, &mut l.m.sim, &mut l.win)?;
        if !more || remaining[k] == 0 {
            live[k] = false;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Differential driver
// ---------------------------------------------------------------------------

/// Test-only corruption applied to the FAST outcome after a successful
/// run — a stand-in for a real engine bug, proving the oracle catches
/// what it is supposed to catch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// Snapshot-visible drift: the headline throughput is off by one.
    InflateTotalThroughput,
    /// Conservation violation: a member reports more terminal requests
    /// than ever arrived — `audit()` must refuse it even in release.
    ForgePhantomDrops,
}

pub fn apply_mutation(out: &mut ClusterOutcome, m: Mutation) {
    match m {
        Mutation::InflateTotalThroughput => out.total_throughput += 1.0,
        Mutation::ForgePhantomDrops => {
            if let Some(mem) =
                out.devices.iter_mut().flat_map(|d| d.fleet.members.iter_mut()).next()
            {
                mem.arrived = mem.arrived.max(1);
                mem.drops = mem.arrived + 1;
            }
        }
    }
}

/// Run one scenario through both executors and every oracle. `Ok(())`
/// means: the scenario either fails builder validation (vacuously fine —
/// the generator retries those) or both executors agree byte-for-byte
/// and both outcomes audit clean. `Err` carries a human-readable
/// mismatch description.
pub fn check_scenario(sc: &Scenario, mutation: Option<Mutation>) -> Result<(), String> {
    let fast = match run_fast(sc) {
        Ok(r) => r,
        Err(_) => return Ok(()),
    };
    let reference = match run_reference(sc) {
        Ok(r) => r,
        Err(e) => return Err(format!("built for the fast executor but not the reference: {e}")),
    };
    match (fast, reference) {
        (Err(a), Err(b)) => {
            let (a, b) = (a.to_string(), b.to_string());
            if a == b {
                Ok(())
            } else {
                Err(format!("error mismatch: fast [{a}] vs reference [{b}]"))
            }
        }
        (Ok(_), Err(b)) => Err(format!("fast succeeded, reference failed: {b}")),
        (Err(a), Ok(_)) => Err(format!("reference succeeded, fast failed: {a}")),
        (Ok(mut f), Ok(r)) => {
            if let Some(m) = mutation {
                apply_mutation(&mut f, m);
            }
            // Satellite: audit() always runs here — debug_assert! in
            // run() is compiled out of release builds, the fuzzer's
            // oracle is not.
            f.audit().map_err(|e| format!("fast outcome failed audit: {e}"))?;
            r.audit().map_err(|e| format!("reference outcome failed audit: {e}"))?;
            let fj = cluster_outcome_to_json(&f);
            let rj = cluster_outcome_to_json(&r);
            if render(&fj) == render(&rj) {
                return Ok(());
            }
            let mut paths = Vec::new();
            diff_json("$", &fj, &rj, &mut paths);
            paths.truncate(8);
            Err(format!("snapshot mismatch (fast vs reference): {}", paths.join("; ")))
        }
    }
}

/// Recursive field-by-field JSON diff: every differing path is reported
/// as `$.a.b[3]: fast != reference`.
fn diff_json(path: &str, a: &Json, b: &Json, out: &mut Vec<String>) {
    match (a, b) {
        (Json::Obj(x), Json::Obj(y)) => {
            let keys: std::collections::BTreeSet<&String> = x.keys().chain(y.keys()).collect();
            for k in keys {
                let p = format!("{path}.{k}");
                match (x.get(k), y.get(k)) {
                    (Some(va), Some(vb)) => diff_json(&p, va, vb, out),
                    (Some(_), None) => out.push(format!("{p}: present only in fast")),
                    (None, Some(_)) => out.push(format!("{p}: present only in reference")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(x), Json::Arr(y)) => {
            if x.len() != y.len() {
                out.push(format!("{path}: length {} != {}", x.len(), y.len()));
            }
            for (i, (va, vb)) in x.iter().zip(y).enumerate() {
                diff_json(&format!("{path}[{i}]"), va, vb, out);
            }
        }
        _ => {
            let (wa, wb) = (json::write(a), json::write(b));
            if wa != wb {
                out.push(format!("{path}: {wa} != {wb}"));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shrinking
// ---------------------------------------------------------------------------

/// Greedily minimize a failing scenario: each pass tries the candidate
/// edits in order (drop devices, drop jobs, drop dynamics, truncate
/// windows/rounds, simplify arrivals and policies, clear knobs, drop
/// threads, flatten partition/placement) and restarts from the first
/// edit that still fails. Deterministic, bounded, proptest-style.
pub fn shrink(start: &Scenario, failing: &mut dyn FnMut(&Scenario) -> bool) -> Scenario {
    let mut cur = start.clone();
    for _ in 0..64 {
        let mut advanced = false;
        for cand in shrink_candidates(&cur) {
            if failing(&cand) {
                cur = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    cur
}

fn shrink_candidates(cur: &Scenario) -> Vec<Scenario> {
    let mut cands = Vec::new();
    // 1. Drop devices.
    if let ScenarioKind::Cluster { devices, placement } = &cur.kind {
        if devices.len() > 1 {
            for d in 0..devices.len() {
                let mut c = cur.clone();
                let mut devs = devices.clone();
                devs.remove(d);
                c.kind = ScenarioKind::Cluster { devices: devs, placement: *placement };
                cands.push(c);
            }
        }
    }
    // 2. Drop jobs.
    if cur.jobs.len() > 1 {
        for j in 0..cur.jobs.len() {
            let mut c = cur.clone();
            c.jobs.remove(j);
            cands.push(c);
        }
    }
    // 3. Drop dynamics wholesale, then piecewise.
    if let Some(dy) = &cur.dynamics {
        let mut c = cur.clone();
        c.dynamics = None;
        cands.push(c);
        for e in 0..dy.churn.len() {
            let mut c = cur.clone();
            if let Some(d) = c.dynamics.as_mut() {
                d.churn.remove(e);
            }
            cands.push(c);
        }
        if dy.migrate.is_some() {
            let mut c = cur.clone();
            if let Some(d) = c.dynamics.as_mut() {
                d.migrate = None;
            }
            cands.push(c);
        }
        if dy.autoscale.is_some() {
            let mut c = cur.clone();
            if let Some(d) = c.dynamics.as_mut() {
                d.autoscale = None;
            }
            cands.push(c);
        }
        for e in 0..dy.faults.len() {
            let mut c = cur.clone();
            if let Some(d) = c.dynamics.as_mut() {
                d.faults.remove(e);
            }
            cands.push(c);
        }
        if dy.mtbf.is_some() {
            let mut c = cur.clone();
            if let Some(d) = c.dynamics.as_mut() {
                d.mtbf = None;
            }
            cands.push(c);
        }
    }
    // 4. Truncate windows / rounds.
    if cur.windows > 1 {
        let mut c = cur.clone();
        c.windows = (cur.windows / 2).max(1);
        cands.push(c);
        let mut c = cur.clone();
        c.windows = cur.windows - 1;
        cands.push(c);
    }
    if cur.rounds > 1 {
        let mut c = cur.clone();
        c.rounds = (cur.rounds / 2).max(1);
        cands.push(c);
        let mut c = cur.clone();
        c.rounds = cur.rounds - 1;
        cands.push(c);
    }
    // 5. Simplify arrivals (toward plain Poisson, then closed).
    for j in 0..cur.jobs.len() {
        match cur.jobs[j].arrivals {
            ArrivalGene::Closed | ArrivalGene::Poisson { .. } => {}
            ArrivalGene::Uniform { rate }
            | ArrivalGene::Bursty { rate, .. }
            | ArrivalGene::Trace { rate, .. } => {
                let mut c = cur.clone();
                c.jobs[j].arrivals = ArrivalGene::Poisson { rate };
                cands.push(c);
            }
        }
        if !cur.jobs[j].arrivals.is_closed() {
            let mut c = cur.clone();
            c.jobs[j].arrivals = ArrivalGene::Closed;
            cands.push(c);
        }
    }
    // 6. Simplify policies and clear per-job knobs (class assignments
    //    first on their own — a minimal SLO counterexample should keep
    //    the unrelated queueing knobs it does not need).
    for j in 0..cur.jobs.len() {
        if cur.jobs[j].policy != (PolicyGene::Static { bs: 1, mtl: 1 }) {
            let mut c = cur.clone();
            c.jobs[j].policy = PolicyGene::Static { bs: 1, mtl: 1 };
            cands.push(c);
        }
        if cur.jobs[j].slo.is_some() {
            let mut c = cur.clone();
            c.jobs[j].slo = None;
            cands.push(c);
        }
        let g = &cur.jobs[j];
        if g.queue_capacity.is_some()
            || g.batch_timeout_ms.is_some()
            || g.shed_deadline
            || g.sm_reservation.is_some()
            || g.slo.is_some()
        {
            let mut c = cur.clone();
            c.jobs[j].queue_capacity = None;
            c.jobs[j].batch_timeout_ms = None;
            c.jobs[j].shed_deadline = false;
            c.jobs[j].sm_reservation = None;
            c.jobs[j].slo = None;
            cands.push(c);
        }
    }
    // 7. Serial threads, flat partition, plain placement, plain MIG.
    if cur.threads != 1 {
        let mut c = cur.clone();
        c.threads = 1;
        cands.push(c);
    }
    match &cur.kind {
        ScenarioKind::Fleet { gpu, partition } => {
            if *partition != PartitionGene::TimeShare {
                let mut c = cur.clone();
                c.kind = ScenarioKind::Fleet { gpu: *gpu, partition: PartitionGene::TimeShare };
                cands.push(c);
            }
        }
        ScenarioKind::Cluster { devices, placement } => {
            for d in 0..devices.len() {
                if devices[d].mig.is_some() {
                    let mut devs = devices.clone();
                    devs[d].mig = None;
                    let mut c = cur.clone();
                    c.kind = ScenarioKind::Cluster { devices: devs, placement: *placement };
                    cands.push(c);
                }
            }
            if *placement != PlacementGene::RoundRobin {
                let mut c = cur.clone();
                c.kind = ScenarioKind::Cluster {
                    devices: devices.clone(),
                    placement: PlacementGene::RoundRobin,
                };
                cands.push(c);
            }
        }
    }
    cands
}

// ---------------------------------------------------------------------------
// Scenario generator
// ---------------------------------------------------------------------------

/// Generate a buildable scenario of the given class. Random draws that
/// fail builder validation (an over-large model on a MIG slice, an
/// unsatisfiable placement, an invalid churn schedule) are retried with
/// a perturbed seed; a hand-written per-class fallback guarantees the
/// call always returns something runnable.
pub fn generate_class(class: usize, seed: u64) -> Scenario {
    for attempt in 0..200u64 {
        let sc = gen_attempt(class, seed.wrapping_add(attempt.wrapping_mul(0x9E37_79B9)));
        if sc.builds() {
            return sc;
        }
    }
    fallback_scenario(class, seed)
}

fn gen_gpu(r: &mut Rng) -> GpuName {
    [GpuName::P40, GpuName::P4, GpuName::T4][r.below(3)]
}

fn gen_policy(r: &mut Rng, open: bool) -> PolicyGene {
    let n = if open { 4 } else { 3 };
    match r.below(n) {
        0 | 1 => {
            PolicyGene::Static { bs: 1 + r.below(8) as u32, mtl: 1 + r.below(3) as u32 }
        }
        2 => PolicyGene::Clipper,
        _ => PolicyGene::QueueAware,
    }
}

fn gen_open_arrivals(r: &mut Rng) -> ArrivalGene {
    let rate = r.uniform_range(5.0, 120.0);
    match r.below(4) {
        0 => ArrivalGene::Poisson { rate },
        1 => ArrivalGene::Uniform { rate },
        2 => {
            let period_s = r.uniform_range(0.5, 3.5);
            ArrivalGene::Bursty {
                rate,
                factor: r.uniform_range(1.5, 4.5),
                period_s,
                burst_s: period_s * r.uniform_range(0.2, 0.6),
            }
        }
        _ => ArrivalGene::Trace { count: 10 + r.below(40), rate },
    }
}

fn gen_job(r: &mut Rng, open: bool) -> JobGene {
    let mut g = JobGene::simple(
        1 + r.below(30) as u32,
        gen_policy(r, open),
        if open { gen_open_arrivals(r) } else { ArrivalGene::Closed },
    );
    if open {
        if r.chance(0.5) {
            g.queue_capacity = Some(4 + r.below(60));
        }
        if r.chance(0.5) {
            g.batch_timeout_ms = Some(r.uniform_range(1.0, 10.0));
        }
        g.shed_deadline = r.chance(0.3);
    }
    g
}

fn gen_attempt(class: usize, seed: u64) -> Scenario {
    let mut r = Rng::new(seed ^ (class as u64).wrapping_mul(0xA076_1D64_78BD_642F));
    let windows = 2 + r.below(4);
    let rounds = 2 + r.below(6);
    let threads = [1, 2, 3, 8][r.below(4)];
    let sc_seed = r.next_u64();
    match class % NUM_CLASSES {
        0 => {
            let jobs = (0..1 + r.below(3)).map(|_| gen_job(&mut r, false)).collect();
            Scenario {
                seed: sc_seed,
                windows,
                rounds,
                threads: 1,
                kind: ScenarioKind::Fleet {
                    gpu: gen_gpu(&mut r),
                    partition: PartitionGene::TimeShare,
                },
                jobs,
                dynamics: None,
            }
        }
        1 => {
            let open = r.chance(0.5);
            let n = 2 + r.below(2);
            let mut jobs: Vec<JobGene> = (0..n).map(|_| gen_job(&mut r, open)).collect();
            // Reservations on the first members sometimes; the rest
            // split the unreserved remainder.
            if r.chance(0.5) {
                for j in jobs.iter_mut().take(2) {
                    let reserve = r.chance(0.7);
                    if reserve {
                        j.sm_reservation = Some(r.uniform_range(0.05, 0.30));
                    }
                }
            }
            Scenario {
                seed: sc_seed,
                windows,
                rounds,
                threads: 1,
                kind: ScenarioKind::Fleet { gpu: GpuName::P40, partition: PartitionGene::Mps },
                jobs,
                dynamics: None,
            }
        }
        2 => {
            let slices = [2u32, 4][r.below(2)];
            let open = r.chance(0.5);
            let n = 1 + r.below((slices as usize).min(3));
            let jobs = (0..n).map(|_| gen_job(&mut r, open)).collect();
            Scenario {
                seed: sc_seed,
                windows,
                rounds,
                threads: 1,
                kind: ScenarioKind::Fleet {
                    gpu: GpuName::P40,
                    partition: PartitionGene::Mig { slices },
                },
                jobs,
                dynamics: None,
            }
        }
        3 | 4 => {
            let open = class % NUM_CLASSES == 4;
            let n_dev = 1 + r.below(3);
            let devices = (0..n_dev)
                .map(|_| {
                    let gpu = gen_gpu(&mut r);
                    let mig = if r.chance(0.2) { Some([2u32, 4][r.below(2)]) } else { None };
                    DeviceGene { gpu, mig }
                })
                .collect();
            let placement = [
                PlacementGene::RoundRobin,
                PlacementGene::BestFit,
                PlacementGene::Interference,
            ][r.below(3)];
            let jobs = (0..1 + r.below(4)).map(|_| gen_job(&mut r, open)).collect();
            Scenario {
                seed: sc_seed,
                windows,
                rounds,
                threads,
                kind: ScenarioKind::Cluster { devices, placement },
                jobs,
                dynamics: None,
            }
        }
        5 => gen_dynamics_attempt(&mut r, sc_seed, windows.max(4), rounds, threads),
        6 => gen_faults_attempt(&mut r, sc_seed, windows.max(4), rounds, threads),
        _ => gen_slo_attempt(&mut r, sc_seed, windows, rounds, threads),
    }
}

/// Class 7: open cluster with SLO classes. Most jobs carry a class
/// (uniform over gold/silver/best-effort) and shed their deadline
/// overruns, so class-weighted shedding AND class-weighted admission
/// both fire; at least one job is always classed, else the scenario
/// would degenerate to plain class 4.
fn gen_slo_attempt(
    r: &mut Rng,
    sc_seed: u64,
    windows: usize,
    rounds: usize,
    threads: usize,
) -> Scenario {
    let n_dev = 1 + r.below(3);
    let devices: Vec<DeviceGene> =
        (0..n_dev).map(|_| DeviceGene { gpu: gen_gpu(r), mig: None }).collect();
    let mut jobs: Vec<JobGene> = (0..2 + r.below(3)).map(|_| gen_job(r, true)).collect();
    for j in jobs.iter_mut() {
        if r.chance(0.8) {
            j.slo = Some(SloClass::ALL[r.below(3)]);
            if r.chance(0.7) {
                j.shed_deadline = true;
            }
        }
    }
    if jobs.iter().all(|j| j.slo.is_none()) {
        jobs[0].slo = Some(SloClass::Gold);
        jobs[0].shed_deadline = true;
    }
    Scenario {
        seed: sc_seed,
        windows,
        rounds,
        threads,
        kind: ScenarioKind::Cluster { devices, placement: PlacementGene::RoundRobin },
        jobs,
        dynamics: None,
    }
}

fn gen_dynamics_attempt(
    r: &mut Rng,
    sc_seed: u64,
    windows: usize,
    rounds: usize,
    threads: usize,
) -> Scenario {
    let n_dev = 2 + r.below(2);
    let devices: Vec<DeviceGene> =
        (0..n_dev).map(|_| DeviceGene { gpu: gen_gpu(r), mig: None }).collect();
    let jobs: Vec<JobGene> = (0..1 + r.below(3)).map(|_| gen_job(r, true)).collect();

    // Track (paper id, first window the job is live from) so retires
    // always target a job that exists at their window — ChurnSchedule
    // validation replays events in window order.
    let mut live: Vec<(u32, usize)> = jobs.iter().map(|j| (j.paper_id, 0)).collect();
    let mut churn = Vec::new();
    for _ in 0..1 + r.below(3) {
        let retirable: Vec<usize> =
            (0..live.len()).filter(|&i| live[i].1 + 1 < windows).collect();
        let retire = !retirable.is_empty() && r.chance(0.4);
        if retire {
            let pick = retirable[r.below(retirable.len())];
            let (id, from) = live.remove(pick);
            let w = from + 1 + r.below(windows - from - 1);
            churn.push(ChurnGene::Retire { window: w, paper_id: id });
        } else {
            let w = 1 + r.below(windows - 1);
            let id = 1 + r.below(30) as u32;
            churn.push(ChurnGene::Launch {
                window: w,
                paper_id: id,
                rate: r.uniform_range(5.0, 60.0),
            });
            live.push((id, w));
        }
    }
    let migrate = if r.chance(0.5) {
        Some((
            [PlacementGene::RoundRobin, PlacementGene::BestFit][r.below(2)],
            1 + r.below(3),
        ))
    } else {
        None
    };
    let autoscale =
        if r.chance(0.5) { Some((1, n_dev + 1 + r.below(2))) } else { None };
    let mut dy = DynamicsGene { churn, migrate, autoscale, faults: Vec::new(), mtbf: None };
    if dy.is_empty() {
        dy.autoscale = Some((1, n_dev + 1));
    }
    Scenario {
        seed: sc_seed,
        windows,
        rounds,
        threads,
        kind: ScenarioKind::Cluster { devices, placement: PlacementGene::RoundRobin },
        jobs,
        dynamics: Some(dy),
    }
}

/// Class 6: fault injection interleaved with churn and autoscaling.
/// Fault sequences are valid by construction — at most one per-device
/// sequence (crash-only, crash then repair, or a degrade window), or a
/// stochastic MTBF/MTTR draw with no explicit events — so rejection
/// sampling rarely has to retry.
fn gen_faults_attempt(
    r: &mut Rng,
    sc_seed: u64,
    windows: usize,
    rounds: usize,
    threads: usize,
) -> Scenario {
    let n_dev = 2 + r.below(2);
    let devices: Vec<DeviceGene> =
        (0..n_dev).map(|_| DeviceGene { gpu: gen_gpu(r), mig: None }).collect();
    let jobs: Vec<JobGene> = (0..1 + r.below(3)).map(|_| gen_job(r, true)).collect();

    let mut churn = Vec::new();
    if r.chance(0.5) {
        churn.push(ChurnGene::Launch {
            window: 1 + r.below(windows - 1),
            paper_id: 1 + r.below(30) as u32,
            rate: r.uniform_range(5.0, 60.0),
        });
    }
    let autoscale =
        if r.chance(0.4) { Some((1, n_dev + 1 + r.below(2))) } else { None };

    let mut faults = Vec::new();
    let mut mtbf = None;
    if r.chance(0.3) {
        // Stochastic mode: the schedule is materialized from the run
        // seed inside the builder.
        mtbf = Some((r.uniform_range(2.0, 6.0), r.uniform_range(1.0, 3.0)));
    } else {
        for device in 0..n_dev {
            if !r.chance(0.6) {
                continue;
            }
            match r.below(3) {
                0 => {
                    faults.push(FaultGene::Crash {
                        window: 1 + r.below(windows - 1),
                        device,
                    });
                }
                1 if windows >= 3 => {
                    let cw = 1 + r.below(windows - 2);
                    faults.push(FaultGene::Crash { window: cw, device });
                    faults.push(FaultGene::Repair {
                        window: cw + 1 + r.below(windows - cw - 1),
                        device,
                    });
                }
                _ => {
                    faults.push(FaultGene::Degrade {
                        window: 1 + r.below(windows - 1),
                        device,
                        factor: r.uniform_range(0.3, 0.9),
                        for_windows: 1 + r.below(3),
                    });
                }
            }
        }
        if faults.is_empty() {
            faults.push(FaultGene::Crash { window: 1 + r.below(windows - 1), device: 0 });
        }
    }

    Scenario {
        seed: sc_seed,
        windows,
        rounds,
        threads,
        kind: ScenarioKind::Cluster { devices, placement: PlacementGene::RoundRobin },
        jobs,
        dynamics: Some(DynamicsGene { churn, migrate: None, autoscale, faults, mtbf }),
    }
}

/// Hand-written per-class scenarios, each guaranteed to build — the
/// generator's last resort and the seed corpus for unit tests.
pub fn fallback_scenario(class: usize, seed: u64) -> Scenario {
    let base = |kind, jobs, dynamics| Scenario {
        seed,
        windows: 4,
        rounds: 2,
        threads: 1,
        kind,
        jobs,
        dynamics,
    };
    match class % NUM_CLASSES {
        0 => base(
            ScenarioKind::Fleet { gpu: GpuName::P40, partition: PartitionGene::TimeShare },
            vec![JobGene::simple(1, PolicyGene::Static { bs: 1, mtl: 1 }, ArrivalGene::Closed)],
            None,
        ),
        1 => base(
            ScenarioKind::Fleet { gpu: GpuName::P40, partition: PartitionGene::Mps },
            vec![
                JobGene::simple(1, PolicyGene::Static { bs: 2, mtl: 1 }, ArrivalGene::Closed),
                JobGene::simple(5, PolicyGene::Static { bs: 1, mtl: 1 }, ArrivalGene::Closed),
            ],
            None,
        ),
        2 => base(
            ScenarioKind::Fleet { gpu: GpuName::P40, partition: PartitionGene::Mig { slices: 2 } },
            vec![JobGene::simple(5, PolicyGene::Static { bs: 1, mtl: 1 }, ArrivalGene::Closed)],
            None,
        ),
        3 => base(
            ScenarioKind::Cluster {
                devices: vec![
                    DeviceGene { gpu: GpuName::P40, mig: None },
                    DeviceGene { gpu: GpuName::P40, mig: None },
                ],
                placement: PlacementGene::RoundRobin,
            },
            vec![
                JobGene::simple(1, PolicyGene::Static { bs: 2, mtl: 1 }, ArrivalGene::Closed),
                JobGene::simple(5, PolicyGene::Clipper, ArrivalGene::Closed),
            ],
            None,
        ),
        4 => base(
            ScenarioKind::Cluster {
                devices: vec![
                    DeviceGene { gpu: GpuName::P40, mig: None },
                    DeviceGene { gpu: GpuName::T4, mig: None },
                ],
                placement: PlacementGene::RoundRobin,
            },
            vec![
                JobGene::simple(
                    1,
                    PolicyGene::Static { bs: 2, mtl: 1 },
                    ArrivalGene::Poisson { rate: 20.0 },
                ),
                JobGene::simple(
                    5,
                    PolicyGene::QueueAware,
                    ArrivalGene::Poisson { rate: 30.0 },
                ),
            ],
            None,
        ),
        5 => base(
            ScenarioKind::Cluster {
                devices: vec![
                    DeviceGene { gpu: GpuName::P40, mig: None },
                    DeviceGene { gpu: GpuName::P40, mig: None },
                ],
                placement: PlacementGene::RoundRobin,
            },
            vec![
                JobGene::simple(
                    1,
                    PolicyGene::Static { bs: 2, mtl: 1 },
                    ArrivalGene::Poisson { rate: 20.0 },
                ),
                JobGene::simple(
                    5,
                    PolicyGene::Static { bs: 1, mtl: 1 },
                    ArrivalGene::Poisson { rate: 15.0 },
                ),
            ],
            Some(DynamicsGene {
                churn: vec![
                    ChurnGene::Launch { window: 1, paper_id: 7, rate: 15.0 },
                    ChurnGene::Retire { window: 3, paper_id: 1 },
                ],
                migrate: Some((PlacementGene::RoundRobin, 2)),
                autoscale: Some((1, 3)),
                faults: Vec::new(),
                mtbf: None,
            }),
        ),
        6 => base(
            ScenarioKind::Cluster {
                devices: vec![
                    DeviceGene { gpu: GpuName::P40, mig: None },
                    DeviceGene { gpu: GpuName::P40, mig: None },
                    DeviceGene { gpu: GpuName::T4, mig: None },
                ],
                placement: PlacementGene::RoundRobin,
            },
            vec![
                JobGene::simple(
                    1,
                    PolicyGene::Static { bs: 2, mtl: 1 },
                    ArrivalGene::Poisson { rate: 20.0 },
                ),
                JobGene::simple(
                    5,
                    PolicyGene::Static { bs: 1, mtl: 1 },
                    ArrivalGene::Poisson { rate: 15.0 },
                ),
            ],
            Some(DynamicsGene {
                churn: vec![ChurnGene::Launch { window: 1, paper_id: 7, rate: 15.0 }],
                migrate: None,
                autoscale: None,
                faults: vec![
                    FaultGene::Crash { window: 2, device: 1 },
                    FaultGene::Repair { window: 3, device: 1 },
                ],
                mtbf: None,
            }),
        ),
        _ => {
            let mut jobs = vec![
                JobGene::simple(
                    1,
                    PolicyGene::Static { bs: 2, mtl: 1 },
                    ArrivalGene::Poisson { rate: 40.0 },
                ),
                JobGene::simple(
                    5,
                    PolicyGene::Static { bs: 1, mtl: 1 },
                    ArrivalGene::Poisson { rate: 40.0 },
                ),
                JobGene::simple(7, PolicyGene::QueueAware, ArrivalGene::Poisson { rate: 40.0 }),
            ];
            for (j, c) in jobs.iter_mut().zip(SloClass::ALL) {
                j.slo = Some(c);
                j.shed_deadline = true;
            }
            base(
                ScenarioKind::Cluster {
                    devices: vec![
                        DeviceGene { gpu: GpuName::P40, mig: None },
                        DeviceGene { gpu: GpuName::T4, mig: None },
                    ],
                    placement: PlacementGene::RoundRobin,
                },
                jobs,
                None,
            )
        }
    }
}

// ---------------------------------------------------------------------------
// Canonical text format (regression corpus files)
// ---------------------------------------------------------------------------

/// Serialize a scenario to the line-based canonical format committed
/// under `rust/tests/fuzz_corpus/`. Floats print with Rust's shortest
/// round-trip `Display`, so `from_canon(to_canon(sc)) == sc` exactly.
pub fn to_canon(sc: &Scenario) -> String {
    let mut s = String::from("# dnnscaler fuzz scenario v1\n");
    s.push_str(&format!("seed={}\n", sc.seed));
    s.push_str(&format!("windows={}\n", sc.windows));
    s.push_str(&format!("rounds={}\n", sc.rounds));
    s.push_str(&format!("threads={}\n", sc.threads));
    match &sc.kind {
        ScenarioKind::Fleet { gpu, partition } => {
            s.push_str("kind=fleet\n");
            s.push_str(&format!("gpu={}\n", gpu.tag()));
            let p = match partition {
                PartitionGene::TimeShare => "timeshare".to_string(),
                PartitionGene::Mps => "mps".to_string(),
                PartitionGene::Mig { slices } => format!("mig:{slices}"),
            };
            s.push_str(&format!("partition={p}\n"));
        }
        ScenarioKind::Cluster { devices, placement } => {
            s.push_str("kind=cluster\n");
            for d in devices {
                match d.mig {
                    Some(slices) => s.push_str(&format!("device={}:mig{slices}\n", d.gpu.tag())),
                    None => s.push_str(&format!("device={}\n", d.gpu.tag())),
                }
            }
            s.push_str(&format!("placement={}\n", placement.tag()));
        }
    }
    for j in &sc.jobs {
        let policy = match j.policy {
            PolicyGene::Static { bs, mtl } => format!("static:{bs}:{mtl}"),
            PolicyGene::Clipper => "clipper".to_string(),
            PolicyGene::QueueAware => "queue".to_string(),
        };
        let arrivals = match j.arrivals {
            ArrivalGene::Closed => "closed".to_string(),
            ArrivalGene::Poisson { rate } => format!("poisson:{rate}"),
            ArrivalGene::Uniform { rate } => format!("uniform:{rate}"),
            ArrivalGene::Bursty { rate, factor, period_s, burst_s } => {
                format!("bursty:{rate}:{factor}:{period_s}:{burst_s}")
            }
            ArrivalGene::Trace { count, rate } => format!("trace:{count}:{rate}"),
        };
        s.push_str(&format!("job id={} policy={policy} arrivals={arrivals}", j.paper_id));
        if let Some(cap) = j.queue_capacity {
            s.push_str(&format!(" queue={cap}"));
        }
        if let Some(t) = j.batch_timeout_ms {
            s.push_str(&format!(" timeout={t}"));
        }
        if j.shed_deadline {
            s.push_str(" shed=1");
        }
        if let Some(f) = j.sm_reservation {
            s.push_str(&format!(" resv={f}"));
        }
        if let Some(c) = j.slo {
            s.push_str(&format!(" slo={}", c.letter()));
        }
        s.push('\n');
    }
    if let Some(dy) = &sc.dynamics {
        for e in &dy.churn {
            match *e {
                ChurnGene::Launch { window, paper_id, rate } => {
                    s.push_str(&format!("churn=launch:{window}:{paper_id}:{rate}\n"));
                }
                ChurnGene::Retire { window, paper_id } => {
                    s.push_str(&format!("churn=retire:{window}:{paper_id}\n"));
                }
            }
        }
        if let Some((heur, every)) = dy.migrate {
            s.push_str(&format!("migrate={}:{every}\n", heur.tag()));
        }
        if let Some((min, max)) = dy.autoscale {
            s.push_str(&format!("autoscale={min}:{max}\n"));
        }
        for f in &dy.faults {
            match *f {
                FaultGene::Crash { window, device } => {
                    s.push_str(&format!("fault=crash:{window}:{device}\n"));
                }
                FaultGene::Degrade { window, device, factor, for_windows } => {
                    s.push_str(&format!("fault=degrade:{window}:{device}:{factor}:{for_windows}\n"));
                }
                FaultGene::Repair { window, device } => {
                    s.push_str(&format!("fault=repair:{window}:{device}\n"));
                }
            }
        }
        if let Some((mtbf, mttr)) = dy.mtbf {
            s.push_str(&format!("mtbf={mtbf}:{mttr}\n"));
        }
    }
    s
}

fn parse_num<T: std::str::FromStr>(what: &str, s: &str) -> Result<T, String> {
    s.parse().map_err(|_| format!("bad {what}: {s:?}"))
}

fn parse_job_line(line: &str) -> Result<JobGene, String> {
    let mut id = None;
    let mut policy = None;
    let mut arrivals = None;
    let mut g = JobGene::simple(0, PolicyGene::Clipper, ArrivalGene::Closed);
    for tok in line.split_whitespace().skip(1) {
        let (k, v) = tok.split_once('=').ok_or_else(|| format!("bad job token: {tok:?}"))?;
        match k {
            "id" => id = Some(parse_num::<u32>("job id", v)?),
            "policy" => {
                let parts: Vec<&str> = v.split(':').collect();
                policy = Some(match parts[0] {
                    "static" if parts.len() == 3 => PolicyGene::Static {
                        bs: parse_num("bs", parts[1])?,
                        mtl: parse_num("mtl", parts[2])?,
                    },
                    "clipper" => PolicyGene::Clipper,
                    "queue" => PolicyGene::QueueAware,
                    _ => return Err(format!("bad policy: {v:?}")),
                });
            }
            "arrivals" => {
                let parts: Vec<&str> = v.split(':').collect();
                arrivals = Some(match parts[0] {
                    "closed" => ArrivalGene::Closed,
                    "poisson" if parts.len() == 2 => {
                        ArrivalGene::Poisson { rate: parse_num("rate", parts[1])? }
                    }
                    "uniform" if parts.len() == 2 => {
                        ArrivalGene::Uniform { rate: parse_num("rate", parts[1])? }
                    }
                    "bursty" if parts.len() == 5 => ArrivalGene::Bursty {
                        rate: parse_num("rate", parts[1])?,
                        factor: parse_num("factor", parts[2])?,
                        period_s: parse_num("period", parts[3])?,
                        burst_s: parse_num("burst", parts[4])?,
                    },
                    "trace" if parts.len() == 3 => ArrivalGene::Trace {
                        count: parse_num("count", parts[1])?,
                        rate: parse_num("rate", parts[2])?,
                    },
                    _ => return Err(format!("bad arrivals: {v:?}")),
                });
            }
            "queue" => g.queue_capacity = Some(parse_num("queue capacity", v)?),
            "timeout" => g.batch_timeout_ms = Some(parse_num("batch timeout", v)?),
            "shed" => g.shed_deadline = v == "1",
            "resv" => g.sm_reservation = Some(parse_num("reservation", v)?),
            "slo" => g.slo = Some(SloClass::parse(v).map_err(|e| e.to_string())?),
            _ => return Err(format!("unknown job key: {k:?}")),
        }
    }
    g.paper_id = id.ok_or("job line missing id=")?;
    g.policy = policy.ok_or("job line missing policy=")?;
    g.arrivals = arrivals.ok_or("job line missing arrivals=")?;
    Ok(g)
}

/// Parse the canonical format back into a [`Scenario`]. Errors are
/// human-readable strings (the corpus replayer surfaces them verbatim).
pub fn from_canon(text: &str) -> Result<Scenario, String> {
    let mut seed = None;
    let mut windows = None;
    let mut rounds = None;
    let mut threads = 1usize;
    let mut kind_tag: Option<&str> = None;
    let mut gpu = None;
    let mut partition = None;
    let mut devices: Vec<DeviceGene> = Vec::new();
    let mut placement = None;
    let mut jobs: Vec<JobGene> = Vec::new();
    let mut churn: Vec<ChurnGene> = Vec::new();
    let mut migrate = None;
    let mut autoscale = None;
    let mut faults: Vec<FaultGene> = Vec::new();
    let mut mtbf = None;

    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with("job ") {
            jobs.push(parse_job_line(line)?);
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| format!("bad line: {line:?}"))?;
        match k {
            "seed" => seed = Some(parse_num::<u64>("seed", v)?),
            "windows" => windows = Some(parse_num::<usize>("windows", v)?),
            "rounds" => rounds = Some(parse_num::<usize>("rounds", v)?),
            "threads" => threads = parse_num::<usize>("threads", v)?,
            "kind" => {
                kind_tag = Some(match v {
                    "fleet" => "fleet",
                    "cluster" => "cluster",
                    _ => return Err(format!("bad kind: {v:?}")),
                });
            }
            "gpu" => gpu = Some(GpuName::parse(v).ok_or_else(|| format!("bad gpu: {v:?}"))?),
            "partition" => {
                partition = Some(if v == "timeshare" {
                    PartitionGene::TimeShare
                } else if v == "mps" {
                    PartitionGene::Mps
                } else if let Some(n) = v.strip_prefix("mig:") {
                    PartitionGene::Mig { slices: parse_num("mig slices", n)? }
                } else {
                    return Err(format!("bad partition: {v:?}"));
                });
            }
            "device" => {
                let (tag, mig) = match v.split_once(':') {
                    Some((tag, m)) => {
                        let n = m
                            .strip_prefix("mig")
                            .ok_or_else(|| format!("bad device: {v:?}"))?;
                        (tag, Some(parse_num::<u32>("mig slices", n)?))
                    }
                    None => (v, None),
                };
                let gpu = GpuName::parse(tag).ok_or_else(|| format!("bad device gpu: {tag:?}"))?;
                devices.push(DeviceGene { gpu, mig });
            }
            "placement" => {
                placement =
                    Some(PlacementGene::parse(v).ok_or_else(|| format!("bad placement: {v:?}"))?)
            }
            "churn" => {
                let parts: Vec<&str> = v.split(':').collect();
                churn.push(match parts[0] {
                    "launch" if parts.len() == 4 => ChurnGene::Launch {
                        window: parse_num("churn window", parts[1])?,
                        paper_id: parse_num("churn job id", parts[2])?,
                        rate: parse_num("churn rate", parts[3])?,
                    },
                    "retire" if parts.len() == 3 => ChurnGene::Retire {
                        window: parse_num("churn window", parts[1])?,
                        paper_id: parse_num("churn job id", parts[2])?,
                    },
                    _ => return Err(format!("bad churn: {v:?}")),
                });
            }
            "migrate" => {
                let (tag, every) =
                    v.split_once(':').ok_or_else(|| format!("bad migrate: {v:?}"))?;
                migrate = Some((
                    PlacementGene::parse(tag)
                        .ok_or_else(|| format!("bad migrate heuristic: {tag:?}"))?,
                    parse_num::<usize>("migrate period", every)?,
                ));
            }
            "autoscale" => {
                let (min, max) =
                    v.split_once(':').ok_or_else(|| format!("bad autoscale: {v:?}"))?;
                autoscale = Some((
                    parse_num::<usize>("autoscale min", min)?,
                    parse_num::<usize>("autoscale max", max)?,
                ));
            }
            "fault" => {
                let parts: Vec<&str> = v.split(':').collect();
                faults.push(match parts[0] {
                    "crash" if parts.len() == 3 => FaultGene::Crash {
                        window: parse_num("fault window", parts[1])?,
                        device: parse_num("fault device", parts[2])?,
                    },
                    "degrade" if parts.len() == 5 => FaultGene::Degrade {
                        window: parse_num("fault window", parts[1])?,
                        device: parse_num("fault device", parts[2])?,
                        factor: parse_num("degrade factor", parts[3])?,
                        for_windows: parse_num("degrade duration", parts[4])?,
                    },
                    "repair" if parts.len() == 3 => FaultGene::Repair {
                        window: parse_num("fault window", parts[1])?,
                        device: parse_num("fault device", parts[2])?,
                    },
                    _ => return Err(format!("bad fault: {v:?}")),
                });
            }
            "mtbf" => {
                let (m, t) = v.split_once(':').ok_or_else(|| format!("bad mtbf: {v:?}"))?;
                mtbf = Some((
                    parse_num::<f64>("mtbf windows", m)?,
                    parse_num::<f64>("mttr windows", t)?,
                ));
            }
            _ => return Err(format!("unknown key: {k:?}")),
        }
    }

    let kind = match kind_tag.ok_or("missing kind=")? {
        "fleet" => ScenarioKind::Fleet {
            gpu: gpu.ok_or("fleet scenario missing gpu=")?,
            partition: partition.ok_or("fleet scenario missing partition=")?,
        },
        _ => {
            if devices.is_empty() {
                return Err("cluster scenario has no device= lines".into());
            }
            ScenarioKind::Cluster {
                devices,
                placement: placement.ok_or("cluster scenario missing placement=")?,
            }
        }
    };
    let dynamics = if churn.is_empty()
        && migrate.is_none()
        && autoscale.is_none()
        && faults.is_empty()
        && mtbf.is_none()
    {
        None
    } else {
        Some(DynamicsGene { churn, migrate, autoscale, faults, mtbf })
    };
    Ok(Scenario {
        seed: seed.ok_or("missing seed=")?,
        windows: windows.ok_or("missing windows=")?,
        rounds: rounds.ok_or("missing rounds=")?,
        threads,
        kind,
        jobs,
        dynamics,
    })
}

// ---------------------------------------------------------------------------
// Fuzz campaign driver
// ---------------------------------------------------------------------------

/// One caught-and-shrunk mismatch.
#[derive(Debug)]
pub struct FuzzFailure {
    pub case: usize,
    pub class: usize,
    /// The scenario as generated.
    pub scenario: Scenario,
    /// The minimal still-failing scenario after shrinking.
    pub shrunk: Scenario,
    /// Mismatch description re-derived from the shrunk scenario.
    pub mismatch: String,
}

/// Result of a fuzz campaign.
#[derive(Debug)]
pub struct FuzzReport {
    pub cases: usize,
    /// Buildable scenarios generated per class.
    pub built: [usize; NUM_CLASSES],
    pub failures: Vec<FuzzFailure>,
}

/// Run `cases` seeded scenarios round-robin across the generator
/// classes, checking each differentially; mismatches are shrunk to
/// minimal counterexamples. `mutation` injects a deliberate fast-side
/// bug into every successful run (test-only — proves the oracle bites).
pub fn run_fuzz(cases: usize, seed: u64, mutation: Option<Mutation>) -> FuzzReport {
    let mut report = FuzzReport { cases, built: [0; NUM_CLASSES], failures: Vec::new() };
    for i in 0..cases {
        let class = i % NUM_CLASSES;
        let case_seed =
            seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0x1234_5678);
        let sc = generate_class(class, case_seed);
        if sc.builds() {
            report.built[class] += 1;
        }
        if let Err(first) = check_scenario(&sc, mutation) {
            let shrunk = shrink(&sc, &mut |c| check_scenario(c, mutation).is_err());
            let mismatch = check_scenario(&shrunk, mutation).err().unwrap_or(first);
            report.failures.push(FuzzFailure { case: i, class, scenario: sc, shrunk, mismatch });
        }
    }
    report
}

/// Render a failure as the ready-to-commit regression case: the
/// mismatch, then the shrunk scenario in canonical format (drop it into
/// `rust/tests/fuzz_corpus/<name>.case` to pin it forever).
pub fn describe_failure(f: &FuzzFailure) -> String {
    format!(
        "case {} [{}]: {}\n--- shrunk counterexample ({} device(s), {} job(s)) ---\n{}",
        f.case,
        class_name(f.class),
        f.mismatch,
        f.shrunk.device_count(),
        f.shrunk.job_count(),
        to_canon(&f.shrunk),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_scenarios_build_for_every_class() {
        for class in 0..NUM_CLASSES {
            let sc = fallback_scenario(class, 7);
            assert!(sc.builds(), "fallback for class {} must build", class_name(class));
        }
    }

    #[test]
    fn generated_scenarios_build_and_are_deterministic() {
        for class in 0..NUM_CLASSES {
            let a = generate_class(class, 99);
            let b = generate_class(class, 99);
            assert_eq!(a, b, "generation must be a pure function of (class, seed)");
            assert!(a.builds(), "generate_class must return a buildable scenario");
        }
    }

    #[test]
    fn canon_round_trips_every_fallback_and_a_knobbed_scenario() {
        for class in 0..NUM_CLASSES {
            let sc = fallback_scenario(class, 13);
            let text = to_canon(&sc);
            assert_eq!(from_canon(&text), Ok(sc), "round-trip for class {class}");
        }
        let mut sc = fallback_scenario(4, 21);
        sc.threads = 8;
        sc.jobs[0].queue_capacity = Some(12);
        sc.jobs[0].batch_timeout_ms = Some(2.625);
        sc.jobs[0].shed_deadline = true;
        sc.jobs[1].arrivals =
            ArrivalGene::Bursty { rate: 33.5, factor: 2.25, period_s: 1.5, burst_s: 0.375 };
        assert_eq!(from_canon(&to_canon(&sc)), Ok(sc));
    }

    #[test]
    fn fallback_scenarios_pass_the_differential_check() {
        for class in 0..NUM_CLASSES {
            let sc = fallback_scenario(class, 5);
            assert_eq!(
                check_scenario(&sc, None),
                Ok(()),
                "class {} fallback must match fast-vs-reference",
                class_name(class)
            );
        }
    }

    #[test]
    fn injected_bug_is_caught_and_shrinks_small() {
        let sc = fallback_scenario(3, 11);
        let mutation = Some(Mutation::InflateTotalThroughput);
        assert!(check_scenario(&sc, mutation).is_err(), "mutation must trip the oracle");
        let shrunk = shrink(&sc, &mut |c| check_scenario(c, mutation).is_err());
        assert!(shrunk.device_count() <= 2, "shrunk to {} devices", shrunk.device_count());
        assert!(shrunk.job_count() <= 2, "shrunk to {} jobs", shrunk.job_count());
        assert!(shrunk.windows <= sc.windows && shrunk.rounds <= sc.rounds);
    }

    #[test]
    fn audit_mutation_is_refused_in_any_build_profile() {
        let sc = fallback_scenario(4, 3);
        let err = check_scenario(&sc, Some(Mutation::ForgePhantomDrops))
            .expect_err("forged drops must fail the always-on audit");
        assert!(err.contains("audit"), "expected an audit failure, got: {err}");
    }

    #[test]
    fn cluster_scenarios_reject_fleet_only_knobs() {
        let mut sc = fallback_scenario(3, 1);
        sc.jobs[0].sm_reservation = Some(0.25);
        assert!(
            matches!(
                sc.build().err(),
                Some(ConfigError::KnobRequiresPartition { knob: "sm_reservation" })
            ),
            "cluster scenarios must refuse sm_reservation rather than ignore it"
        );
        let mut sc = fallback_scenario(0, 1);
        sc.dynamics = Some(DynamicsGene {
            churn: Vec::new(),
            migrate: None,
            autoscale: Some((1, 2)),
            faults: Vec::new(),
            mtbf: None,
        });
        assert!(sc.build().is_err(), "fleet scenarios must refuse dynamics");
    }

    #[test]
    fn fault_fallback_reports_fault_telemetry() {
        let sc = fallback_scenario(6, 5);
        let out = match sc.build().expect("fault fallback must build") {
            Built::Cluster(c) => c.run().expect("fault fallback must run"),
            Built::Fleet(_) => panic!("fault fallback must be a cluster scenario"),
        };
        let dy = out.dynamics.as_ref().expect("dynamic run must report dynamics");
        let fo = dy.faults.as_ref().expect("faulty run must report fault telemetry");
        assert_eq!(fo.crashes, 1);
        assert_eq!(fo.repairs, 1);
        assert_eq!(fo.pool_health.len(), sc.windows);
        assert!(fo.pool_health.iter().any(|&h| h < 3), "a crash window must show up");
        assert!(out.audit().is_ok(), "fault run must conserve requests: {:?}", out.audit());
    }

    #[test]
    fn slo_fallback_reports_classes_and_round_trips() {
        let sc = fallback_scenario(7, 5);
        // Canon serializes the class letters and parses them back.
        let text = to_canon(&sc);
        assert!(text.contains(" slo=g") && text.contains(" slo=s") && text.contains(" slo=b"));
        assert_eq!(from_canon(&text), Ok(sc.clone()));
        // The fast engine reports one member per class, and the naive
        // reference reproduces the class-weighted arithmetic exactly.
        let out = run_fast(&sc).expect("slo fallback builds").expect("slo fallback runs");
        let slo = out.slo.as_ref().expect("classed run must report slo");
        for c in SloClass::ALL {
            assert_eq!(slo.class(c).members, 1, "{} membership", c.name());
        }
        assert_eq!(check_scenario(&sc, None), Ok(()));
        // Shrinking an SLO failure can drop the class assignments.
        let shrunk = shrink(&sc, &mut |c| c.jobs.iter().any(|j| j.slo.is_some()));
        assert_eq!(shrunk.jobs.iter().filter(|j| j.slo.is_some()).count(), 1);
    }

    #[test]
    fn canon_round_trips_fault_and_mtbf_lines() {
        let sc = fallback_scenario(6, 17);
        assert_eq!(from_canon(&to_canon(&sc)), Ok(sc));
        let mut sc = fallback_scenario(6, 18);
        if let Some(dy) = sc.dynamics.as_mut() {
            dy.faults = vec![FaultGene::Degrade {
                window: 1,
                device: 0,
                factor: 0.625,
                for_windows: 2,
            }];
            dy.mtbf = None;
        }
        assert_eq!(from_canon(&to_canon(&sc)), Ok(sc.clone()));
        if let Some(dy) = sc.dynamics.as_mut() {
            dy.faults = Vec::new();
            dy.mtbf = Some((3.5, 1.25));
        }
        assert_eq!(from_canon(&to_canon(&sc)), Ok(sc));
    }
}
