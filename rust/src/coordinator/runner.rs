//! Legacy closed-loop entry point, kept as a thin deprecated shim.
//!
//! `JobRunner` predates the event-driven [`ServingSession`] API and is
//! retained only so existing call sites and scripts keep working: each
//! method builds a closed-loop session (`ArrivalPattern::Closed`) and
//! runs it, which reproduces the old serving loop exactly — same device
//! RNG consumption order, same window accounting. New code should use
//! [`ServingSession::builder`] directly (open-loop arrivals, bounded
//! queues, custom policies) or [`super::fleet::Fleet`] for multi-job
//! serving.
//!
//! [`ServingSession`]: super::session::ServingSession
//! [`ServingSession::builder`]: super::session::ServingSession::builder

use crate::device::{Device, DeviceError};

use super::controller::Controller;
use super::job::JobSpec;
use super::policy::AsPolicy;
use super::session::{PolicySpec, ServingSession};

pub use super::session::{JobOutcome, RunConfig, WindowRecord};

/// Result type of every shim entry point.
type RunResult = Result<JobOutcome, DeviceError>;

/// Deprecated: drives one job on one device with one controller, closed
/// loop. Use [`ServingSession`] instead.
pub struct JobRunner {
    pub cfg: RunConfig,
}

impl JobRunner {
    pub fn new(cfg: RunConfig) -> Self {
        JobRunner { cfg }
    }

    /// Full DNNScaler: profile, pick the method, scale (closed loop).
    pub fn run_dnnscaler(&self, job: &JobSpec, dev: &mut dyn Device) -> RunResult {
        self.run_spec(job, dev, PolicySpec::DnnScaler)
    }

    /// The Clipper baseline (batching-only AIMD).
    pub fn run_clipper(&self, job: &JobSpec, dev: &mut dyn Device) -> RunResult {
        self.run_spec(job, dev, PolicySpec::Clipper)
    }

    /// Serve with an explicit controller (ablations, Fig. 11/12 probes).
    pub fn serve<'a>(
        &self,
        job: &JobSpec,
        dev: &'a mut (dyn Device + 'a),
        controller: &'a mut (dyn Controller + 'a),
    ) -> RunResult {
        self.run_spec(job, dev, PolicySpec::custom(AsPolicy(controller)))
    }

    fn run_spec<'a>(
        &self,
        job: &JobSpec,
        dev: &'a mut (dyn Device + 'a),
        spec: PolicySpec<'a>,
    ) -> RunResult {
        let session = ServingSession::builder().config(self.cfg.clone()).job(job).device(dev);
        session.policy(spec).build().map_err(|e| DeviceError::Exec(e.to_string()))?.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::controller::Method;
    use crate::coordinator::job::{paper_job, SteadyKnob};
    use crate::gpusim::GpuSim;

    fn run(job_id: u32, windows: usize) -> (JobOutcome, JobOutcome) {
        let job = paper_job(job_id).unwrap();
        let cfg = RunConfig::windows(windows, 20);
        let runner = JobRunner::new(cfg);
        let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 1000 + job_id as u64).unwrap();
        let scaler = runner.run_dnnscaler(job, &mut d1).unwrap();
        let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 2000 + job_id as u64).unwrap();
        let clipper = runner.run_clipper(job, &mut d2).unwrap();
        (scaler, clipper)
    }

    #[test]
    fn job1_mt_beats_clipper() {
        // Job 1 (inc-v1): the paper reports MT with ~7x throughput.
        let (scaler, clipper) = run(1, 40);
        assert_eq!(scaler.method, Some(Method::MultiTenancy));
        assert!(scaler.steady_mtl >= 6, "steady mtl {}", scaler.steady_mtl);
        assert!(
            scaler.throughput > 1.5 * clipper.throughput,
            "DNNScaler {:.0}/s must beat Clipper {:.0}/s",
            scaler.throughput,
            clipper.throughput
        );
        assert!(scaler.slo_attainment > 0.9, "attainment {}", scaler.slo_attainment);
        // Clipper's +4 step massively overshoots job 1's knee (BS ~ 4),
        // so its sawtooth spends most windows in violation. The paper
        // shows the same collapse: Table 6 reports Clipper at 32.9 inf/s
        // on job 1 versus 118.7 inf/s base throughput.
        assert!(clipper.slo_attainment > 0.1, "attainment {}", clipper.slo_attainment);
        assert!(clipper.slo_attainment < scaler.slo_attainment);
    }

    #[test]
    fn job3_batching_parity_with_clipper() {
        // Job 3 (inc-v4): both use batching; throughput parity (±20%).
        let (scaler, clipper) = run(3, 40);
        assert_eq!(scaler.method, Some(Method::Batching));
        let ratio = scaler.throughput / clipper.throughput;
        assert!((0.8..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn steady_knob_close_to_paper_for_batching_jobs() {
        // Jobs 3 and 12 (inc-v4, resv2-152 on ImageNet): the paper's two
        // canonical batching jobs. Job 17's Caltech knee is dominated by
        // prep calibration we only bound loosely, so it is not asserted.
        for id in [3u32, 12] {
            let job = paper_job(id).unwrap();
            let (scaler, _) = run(id, 40);
            if let SteadyKnob::Bs(paper_bs) = job.paper_steady {
                let got = scaler.steady_bs;
                // Within a factor of ~3 of the paper's steady BS — the
                // absolute knee depends on absolute latency calibration,
                // which we only bound to coarse bands (DESIGN.md §7).
                assert!(
                    got as f64 >= paper_bs as f64 / 3.0 && got as f64 <= paper_bs as f64 * 3.0,
                    "job {id}: steady bs {got} vs paper {paper_bs}"
                );
            }
        }
    }

    #[test]
    fn slo_schedule_is_applied() {
        let job = paper_job(1).unwrap();
        let cfg = RunConfig {
            windows: 30,
            rounds_per_window: 10,
            slo_schedule: vec![(15, 10.0)],
            ..Default::default()
        };
        let runner = JobRunner::new(cfg);
        let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 5).unwrap();
        let out = runner.run_dnnscaler(job, &mut d).unwrap();
        assert_eq!(out.trace[14].slo_ms, 35.0);
        assert_eq!(out.trace[15].slo_ms, 10.0);
        // MT must shed instances when the SLO halves (Fig. 10(a)).
        let before = out.trace[14].mtl;
        let after = out.trace.last().unwrap().mtl;
        assert!(after < before, "mtl {before} -> {after} must shrink");
    }

    #[test]
    fn outcome_accounting_consistent() {
        let (scaler, _) = run(26, 30);
        assert_eq!(scaler.trace.len(), 30);
        assert!(scaler.throughput > 0.0);
        assert!(scaler.p95_ms > 0.0);
        assert!((0.0..=1.0).contains(&scaler.slo_attainment));
        let total_reqs: f64 = scaler.latencies.iter().map(|(_, w)| w).sum();
        assert!(total_reqs > 0.0);
    }

    #[test]
    fn zero_window_config_is_a_typed_error_not_a_panic() {
        // Regression: windows == 0 used to underflow `trace.len() - 1`
        // deep inside serve; it must surface as a config error now.
        let job = paper_job(1).unwrap();
        let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 1).unwrap();
        let runner = JobRunner::new(RunConfig { windows: 0, ..Default::default() });
        let err = runner.run_dnnscaler(job, &mut d).unwrap_err();
        assert!(err.to_string().contains("windows"), "{err}");
        let runner = JobRunner::new(RunConfig { rounds_per_window: 0, ..Default::default() });
        let err = runner.run_dnnscaler(job, &mut d).unwrap_err();
        assert!(err.to_string().contains("rounds_per_window"), "{err}");
    }
}
