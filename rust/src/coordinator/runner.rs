//! The serving loop: device + controller + SLO monitor + metrics.
//!
//! Time is driven by executed batches (virtual time in sim mode, wall
//! time in real mode): each control window executes a fixed number of
//! rounds at the current operating point, computes the windowed p95, and
//! lets the controller move the knob — exactly the paper's monitor/adjust
//! cycle. Instance launches are charged their overhead (§3.3.2).


use crate::device::{Device, DeviceError};

use super::clipper::Clipper;
use super::controller::{Controller, Decision, Method};
use super::job::JobSpec;
use super::latency::LatencyWindow;
use super::matcomp::LatencyLibrary;
use super::profiler::{ProfileOutcome, Profiler};
use super::scaler_batching::BatchScaler;
use super::scaler_mt::MtScaler;
use super::MAX_MTL;

/// Serving-loop configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Number of control windows.
    pub windows: usize,
    /// Batch rounds executed per window.
    pub rounds_per_window: usize,
    /// Optional SLO schedule: `(window_index, new_slo_ms)` steps applied
    /// in order (sensitivity analysis, Figs. 9-10).
    pub slo_schedule: Vec<(usize, f64)>,
    /// Batch-size ceiling (128 on the P40; the largest exported artifact
    /// in real mode).
    pub max_bs: u32,
    /// Instance-count ceiling (10 on the P40).
    pub max_mtl: u32,
    /// Profiler probe points (paper: m = 32, n = 8); clamped to the
    /// ceilings above.
    pub probe_bs: u32,
    pub probe_mtl: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            windows: 60,
            rounds_per_window: 20,
            slo_schedule: Vec::new(),
            max_bs: super::MAX_BS,
            max_mtl: MAX_MTL,
            probe_bs: 32,
            probe_mtl: 8,
        }
    }
}

impl RunConfig {
    /// Config with the paper's knobs but custom window counts.
    pub fn windows(windows: usize, rounds_per_window: usize) -> Self {
        RunConfig { windows, rounds_per_window, ..Default::default() }
    }
}

/// Per-window trace record (the raw material of Figs. 7-10).
#[derive(Debug, Clone)]
pub struct WindowRecord {
    pub window: usize,
    pub bs: u32,
    pub mtl: u32,
    pub slo_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    /// Requests completed / window wall time.
    pub throughput: f64,
    pub power_w: f64,
}

/// Result of one job run.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u32,
    pub dnn: String,
    pub controller: String,
    /// Method DNNScaler's profiler chose (None for Clipper).
    pub method: Option<Method>,
    /// Final operating point.
    pub steady_bs: u32,
    pub steady_mtl: u32,
    /// Mean throughput over the steady half of the run (inferences/s).
    pub throughput: f64,
    /// p95 latency over the steady half (ms).
    pub p95_ms: f64,
    /// Fraction of requests whose latency met the SLO in effect (whole
    /// run, including the search/convergence phase).
    pub slo_attainment: f64,
    /// Same, restricted to the steady half of the run — the paper's
    /// Fig. 6 regime, after the knob has converged.
    pub steady_attainment: f64,
    /// Mean power over the steady half (W); 0 in real mode.
    pub power_w: f64,
    /// Per-window trace.
    pub trace: Vec<WindowRecord>,
    /// Per-request (latency, weight) pairs for CDFs (weight = requests
    /// that observed that latency).
    pub latencies: Vec<(f64, f64)>,
    /// Profiler outcome (DNNScaler only).
    pub profile: Option<ProfileOutcome>,
}

impl JobOutcome {
    /// Power efficiency (throughput per watt); None when power unknown.
    pub fn power_efficiency(&self) -> Option<f64> {
        (self.power_w > 0.0).then(|| self.throughput / self.power_w)
    }
}

/// Drives one job on one device with one controller.
pub struct JobRunner {
    pub cfg: RunConfig,
}

impl JobRunner {
    pub fn new(cfg: RunConfig) -> Self {
        JobRunner { cfg }
    }

    /// Full DNNScaler: profile, pick the method, build the matching
    /// scaler (MT seeded by matrix completion from the profiling
    /// latencies), then serve.
    pub fn run_dnnscaler(
        &self,
        job: &JobSpec,
        device: &mut dyn Device,
    ) -> Result<JobOutcome, DeviceError> {
        let profiler = Profiler {
            probe_bs: self.cfg.probe_bs.min(self.cfg.max_bs),
            probe_mtl: self.cfg.probe_mtl.min(self.cfg.max_mtl),
            batches_per_point: 5,
        };
        let profile = profiler.run(device)?;
        let mut controller: Box<dyn Controller> = match profile.method {
            Method::Batching => Box::new(BatchScaler::with_limits(1, self.cfg.max_bs)),
            Method::MultiTenancy => {
                let lib = LatencyLibrary::from_paper_profiles(job.dnn, self.cfg.max_mtl);
                // The two MT observations come free from profiling.
                let observed =
                    [(1u32, profile.lat_base_ms), (profiler.probe_mtl, profile.lat_mt_ms)];
                Box::new(MtScaler::seeded(&lib, &observed, job.slo_ms))
            }
        };
        let mut outcome = self.serve(job, device, controller.as_mut())?;
        outcome.controller = "dnnscaler".into();
        outcome.method = Some(profile.method);
        outcome.profile = Some(profile);
        Ok(outcome)
    }

    /// The Clipper baseline (batching-only AIMD).
    pub fn run_clipper(
        &self,
        job: &JobSpec,
        device: &mut dyn Device,
    ) -> Result<JobOutcome, DeviceError> {
        let mut c = Clipper::with_params(4, 0.10, self.cfg.max_bs);
        let mut outcome = self.serve(job, device, &mut c)?;
        outcome.controller = "clipper".into();
        Ok(outcome)
    }

    /// Serve with an explicit controller (ablations, Fig. 11/12 probes).
    pub fn serve(
        &self,
        job: &JobSpec,
        device: &mut dyn Device,
        controller: &mut dyn Controller,
    ) -> Result<JobOutcome, DeviceError> {
        let mut slo = job.slo_ms;
        let mut schedule = self.cfg.slo_schedule.clone();
        schedule.sort_by_key(|(w, _)| *w);
        let mut schedule_iter = schedule.into_iter().peekable();

        let mut window = LatencyWindow::new(self.cfg.rounds_per_window);
        let mut trace = Vec::with_capacity(self.cfg.windows);
        let mut latencies: Vec<(f64, f64)> = Vec::new();
        let mut pending_launch_ms = 0.0;

        for w in 0..self.cfg.windows {
            while let Some(&(at, new_slo)) = schedule_iter.peek() {
                if at <= w {
                    slo = new_slo;
                    schedule_iter.next();
                } else {
                    break;
                }
            }

            let (bs, mtl) = controller.operating_point();
            let mut wall_ms = pending_launch_ms;
            pending_launch_ms = 0.0;
            let mut requests = 0.0;
            let mut power_acc = 0.0;
            window.reset();

            for _ in 0..self.cfg.rounds_per_window {
                let s = device.execute_batch(bs, mtl)?;
                window.record(s.latency_ms);
                wall_ms += s.latency_ms;
                let reqs = (bs * mtl) as f64;
                requests += reqs;
                latencies.push((s.latency_ms, reqs));
                power_acc += s.power_w;
            }

            let p95 = window.p95().unwrap_or(0.0);
            let mean = window.mean().unwrap_or(0.0);
            let throughput = requests / (wall_ms / 1000.0);
            trace.push(WindowRecord {
                window: w,
                bs,
                mtl,
                slo_ms: slo,
                p95_ms: p95,
                mean_ms: mean,
                throughput,
                power_w: power_acc / self.cfg.rounds_per_window as f64,
            });

            let decision: Decision = controller.observe_window(p95, slo);
            if decision.changed && decision.mtl > mtl {
                // Charge instance-launch overhead to the next window.
                pending_launch_ms +=
                    device.launch_overhead_ms() * (decision.mtl - mtl) as f64;
            }
        }

        // Steady-state = last half of the run.
        let steady = &trace[trace.len() / 2..];
        let throughput = steady.iter().map(|r| r.throughput).sum::<f64>() / steady.len() as f64;
        let power_w = steady.iter().map(|r| r.power_w).sum::<f64>() / steady.len() as f64;
        let mut steady_lat: Vec<f64> = steady.iter().map(|r| r.p95_ms).collect();
        steady_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95_ms = steady_lat[((steady_lat.len() as f64 * 0.95).ceil() as usize - 1)
            .min(steady_lat.len() - 1)];

        // SLO attainment over all requests, against the SLO in effect;
        // also restricted to the steady half.
        let mut met = 0.0;
        let mut total = 0.0;
        let mut steady_met = 0.0;
        let mut steady_total = 0.0;
        let per_window = self.cfg.rounds_per_window;
        let steady_from = self.cfg.windows / 2;
        for (i, (lat, weight)) in latencies.iter().enumerate() {
            let wi = (i / per_window).min(trace.len() - 1);
            let slo_then = trace[wi].slo_ms;
            let ok = *lat <= slo_then;
            if ok {
                met += weight;
            }
            total += weight;
            if wi >= steady_from {
                if ok {
                    steady_met += weight;
                }
                steady_total += weight;
            }
        }

        let (steady_bs, steady_mtl) = controller.operating_point();
        Ok(JobOutcome {
            job_id: job.id,
            dnn: job.dnn.to_string(),
            controller: controller.name().to_string(),
            method: None,
            steady_bs,
            steady_mtl,
            throughput,
            p95_ms,
            slo_attainment: met / total,
            steady_attainment: steady_met / steady_total.max(1e-12),
            power_w,
            trace,
            latencies,
            profile: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{paper_job, SteadyKnob};
    use crate::gpusim::GpuSim;

    fn run(job_id: u32, windows: usize) -> (JobOutcome, JobOutcome) {
        let job = paper_job(job_id).unwrap();
        let cfg = RunConfig::windows(windows, 20);
        let runner = JobRunner::new(cfg);
        let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 1000 + job_id as u64).unwrap();
        let scaler = runner.run_dnnscaler(job, &mut d1).unwrap();
        let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 2000 + job_id as u64).unwrap();
        let clipper = runner.run_clipper(job, &mut d2).unwrap();
        (scaler, clipper)
    }

    #[test]
    fn job1_mt_beats_clipper() {
        // Job 1 (inc-v1): the paper reports MT with ~7x throughput.
        let (scaler, clipper) = run(1, 40);
        assert_eq!(scaler.method, Some(Method::MultiTenancy));
        assert!(scaler.steady_mtl >= 6, "steady mtl {}", scaler.steady_mtl);
        assert!(
            scaler.throughput > 1.5 * clipper.throughput,
            "DNNScaler {:.0}/s must beat Clipper {:.0}/s",
            scaler.throughput,
            clipper.throughput
        );
        assert!(scaler.slo_attainment > 0.9, "attainment {}", scaler.slo_attainment);
        // Clipper's +4 step massively overshoots job 1's knee (BS ~ 4),
        // so its sawtooth spends most windows in violation. The paper
        // shows the same collapse: Table 6 reports Clipper at 32.9 inf/s
        // on job 1 versus 118.7 inf/s base throughput.
        assert!(clipper.slo_attainment > 0.1, "attainment {}", clipper.slo_attainment);
        assert!(clipper.slo_attainment < scaler.slo_attainment);
    }

    #[test]
    fn job3_batching_parity_with_clipper() {
        // Job 3 (inc-v4): both use batching; throughput parity (±20%).
        let (scaler, clipper) = run(3, 40);
        assert_eq!(scaler.method, Some(Method::Batching));
        let ratio = scaler.throughput / clipper.throughput;
        assert!((0.8..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn steady_knob_close_to_paper_for_batching_jobs() {
        // Jobs 3 and 12 (inc-v4, resv2-152 on ImageNet): the paper's two
        // canonical batching jobs. Job 17's Caltech knee is dominated by
        // prep calibration we only bound loosely, so it is not asserted.
        for id in [3u32, 12] {
            let job = paper_job(id).unwrap();
            let (scaler, _) = run(id, 40);
            if let SteadyKnob::Bs(paper_bs) = job.paper_steady {
                let got = scaler.steady_bs;
                // Within a factor of ~3 of the paper's steady BS — the
                // absolute knee depends on absolute latency calibration,
                // which we only bound to coarse bands (DESIGN.md §7).
                assert!(
                    got as f64 >= paper_bs as f64 / 3.0 && got as f64 <= paper_bs as f64 * 3.0,
                    "job {id}: steady bs {got} vs paper {paper_bs}"
                );
            }
        }
    }

    #[test]
    fn slo_schedule_is_applied() {
        let job = paper_job(1).unwrap();
        let cfg = RunConfig {
            windows: 30,
            rounds_per_window: 10,
            slo_schedule: vec![(15, 10.0)],
            ..Default::default()
        };
        let runner = JobRunner::new(cfg);
        let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 5).unwrap();
        let out = runner.run_dnnscaler(job, &mut d).unwrap();
        assert_eq!(out.trace[14].slo_ms, 35.0);
        assert_eq!(out.trace[15].slo_ms, 10.0);
        // MT must shed instances when the SLO halves (Fig. 10(a)).
        let before = out.trace[14].mtl;
        let after = out.trace.last().unwrap().mtl;
        assert!(after < before, "mtl {before} -> {after} must shrink");
    }

    #[test]
    fn outcome_accounting_consistent() {
        let (scaler, _) = run(26, 30);
        assert_eq!(scaler.trace.len(), 30);
        assert!(scaler.throughput > 0.0);
        assert!(scaler.p95_ms > 0.0);
        assert!((0.0..=1.0).contains(&scaler.slo_attainment));
        let total_reqs: f64 = scaler.latencies.iter().map(|(_, w)| w).sum();
        assert!(total_reqs > 0.0);
    }
}
