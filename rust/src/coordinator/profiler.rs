//! The Profiler module (paper §3.2.1, Algorithm 1 lines 1-9).
//!
//! A lightweight run-time probe: measure throughput at `BS=1` (which is
//! also `MTL=1`), at `BS=m` (m=32), and at `MTL=n` (n=8); compute the
//! throughput improvements
//!
//! ```text
//! TI_B  = (thr[BS=m]  - thr[BS=1])  / thr[BS=1]  * 100
//! TI_MT = (thr[MTL=n] - thr[MTL=1]) / thr[MTL=1] * 100
//! ```
//!
//! and select Batching if `TI_B > TI_MT`, Multi-Tenancy if `TI_MT > TI_B`,
//! and on a tie whichever had the lower latency (Eq. 5). Only a few
//! batches per point are executed — "the profiling is of the order of
//! seconds, therefore its overhead on the system is negligible".

use crate::device::{Device, DeviceError};

use super::controller::Method;

/// Profiler configuration (the paper's m = 32, n = 8).
#[derive(Debug, Clone, Copy)]
pub struct Profiler {
    /// Batch size probed for the Batching arm.
    pub probe_bs: u32,
    /// Instance count probed for the Multi-Tenancy arm.
    pub probe_mtl: u32,
    /// Batches executed per probe point.
    pub batches_per_point: usize,
}

impl Default for Profiler {
    fn default() -> Self {
        Profiler { probe_bs: 32, probe_mtl: 8, batches_per_point: 5 }
    }
}

/// Everything the Profiler hands to the Scaler.
#[derive(Debug, Clone)]
pub struct ProfileOutcome {
    pub method: Method,
    /// Throughput improvements in percent (Eqs. 3-4).
    pub ti_b: f64,
    pub ti_mt: f64,
    /// Probe throughputs (inferences/s).
    pub thr_base: f64,
    pub thr_batch: f64,
    pub thr_mt: f64,
    /// Mean probe latencies (ms) — reused as the matrix-completion
    /// observations so MT seeding costs no extra profiling (§3.3.2).
    pub lat_base_ms: f64,
    pub lat_batch_ms: f64,
    pub lat_mt_ms: f64,
    /// Total profiling wall-clock charged (ms).
    pub overhead_ms: f64,
}

impl Profiler {
    /// Probe `device` and decide the method.
    pub fn run(&self, device: &mut dyn Device) -> Result<ProfileOutcome, DeviceError> {
        let (thr_base, lat_base_ms, t0) = self.probe(device, 1, 1)?;
        let (thr_batch, lat_batch_ms, t1) = self.probe(device, self.probe_bs, 1)?;
        let (thr_mt, lat_mt_ms, t2) = self.probe(device, 1, self.probe_mtl)?;

        let ti_b = (thr_batch - thr_base) / thr_base * 100.0;
        let ti_mt = (thr_mt - thr_base) / thr_base * 100.0;
        let method = if ti_b > ti_mt {
            Method::Batching
        } else if ti_mt > ti_b {
            Method::MultiTenancy
        } else if lat_batch_ms <= lat_mt_ms {
            // Tie: the one with lower latency (Eq. 5 third case).
            Method::Batching
        } else {
            Method::MultiTenancy
        };

        Ok(ProfileOutcome {
            method,
            ti_b,
            ti_mt,
            thr_base,
            thr_batch,
            thr_mt,
            lat_base_ms,
            lat_batch_ms,
            lat_mt_ms,
            overhead_ms: t0 + t1 + t2,
        })
    }

    /// Execute a few batches at `(bs, mtl)`; returns (throughput, mean
    /// latency ms, total wall ms).
    fn probe(
        &self,
        device: &mut dyn Device,
        bs: u32,
        mtl: u32,
    ) -> Result<(f64, f64, f64), DeviceError> {
        let mut total_ms = 0.0;
        for _ in 0..self.batches_per_point {
            let s = device.execute_batch(bs, mtl)?;
            total_ms += s.latency_ms;
        }
        let mean_ms = total_ms / self.batches_per_point as f64;
        // mtl instances each complete bs inferences per batch interval.
        let thr = (mtl as f64) * (bs as f64) / (mean_ms / 1000.0);
        Ok((thr, mean_ms, total_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::PAPER_JOBS;
    use crate::gpusim::GpuSim;

    #[test]
    fn profiler_matches_paper_method_for_all_30_jobs() {
        // The headline calibration check (DESIGN.md §7): the Profiler run
        // against the simulated P40 must reproduce the "DNNScaler Method"
        // column of Table 4 for at least 27 of the 30 jobs.
        let profiler = Profiler::default();
        let mut hits = 0;
        let mut misses = Vec::new();
        for job in PAPER_JOBS {
            let mut sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 42).unwrap();
            let out = profiler.run(&mut sim).unwrap();
            if out.method == job.paper_method {
                hits += 1;
            } else {
                misses.push((job.id, job.dnn, out.ti_b, out.ti_mt));
            }
        }
        assert!(
            hits >= 27,
            "only {hits}/30 jobs match the paper's method; misses: {misses:?}"
        );
    }

    #[test]
    fn ti_values_in_expected_bands_for_anchor_jobs() {
        // Table 5 anchor rows (loose bands; see gpusim::perf for the
        // tight ones on the noise-free surfaces).
        let profiler = Profiler::default();
        let cases = [
            ("inc-v1", crate::gpusim::Dataset::ImageNet, false),
            ("inc-v4", crate::gpusim::Dataset::ImageNet, true),
            ("textclassif", crate::gpusim::Dataset::Sentiment140, true),
            ("mobv1-05", crate::gpusim::Dataset::Caltech256, false),
        ];
        for (dnn, ds, batching) in cases {
            let mut sim = GpuSim::for_paper_dnn(dnn, ds, 7).unwrap();
            let out = profiler.run(&mut sim).unwrap();
            assert_eq!(
                out.method,
                if batching { Method::Batching } else { Method::MultiTenancy },
                "{dnn}: TI_B={:.1}% TI_MT={:.1}%",
                out.ti_b,
                out.ti_mt
            );
        }
    }

    #[test]
    fn probe_overhead_is_bounded() {
        let profiler = Profiler::default();
        let mut sim = GpuSim::for_paper_dnn("inc-v4", crate::gpusim::Dataset::ImageNet, 1).unwrap();
        let out = profiler.run(&mut sim).unwrap();
        // 15 batches total; inc-v4 at BS=32 is the slowest probe
        // (~275 ms) -> total must stay under ~5 s ("order of seconds").
        assert!(out.overhead_ms < 5000.0, "overhead {}", out.overhead_ms);
        assert!(out.thr_base > 0.0 && out.thr_batch > 0.0 && out.thr_mt > 0.0);
    }

    #[test]
    fn tie_breaks_on_latency() {
        // A synthetic device with identical throughput everywhere but
        // lower latency for batching.
        struct Flat;
        impl Device for Flat {
            fn model(&self) -> &str {
                "flat"
            }
            fn execute_batch(
                &mut self,
                bs: u32,
                mtl: u32,
            ) -> Result<crate::device::ExecSample, DeviceError> {
                // latency proportional to bs*mtl => constant throughput.
                Ok(crate::device::ExecSample {
                    latency_ms: 10.0 * bs as f64 * mtl as f64,
                    batch_size: bs,
                    mtl,
                    power_w: 0.0,
                    sm_util: 0.0,
                })
            }
        }
        let out = Profiler { probe_bs: 8, probe_mtl: 8, batches_per_point: 2 }
            .run(&mut Flat)
            .unwrap();
        assert!((out.ti_b - out.ti_mt).abs() < 1e-9);
        assert_eq!(out.method, Method::Batching); // equal latency -> Batching
    }
}
