//! The 30-job workload of Table 4.
//!
//! Each job is one DNN + dataset + SLO (p95 ms). The `paper_method` and
//! `paper_steady` columns record what the paper's DNNScaler chose — our
//! calibration tests assert we reproduce the method column, and the
//! benches print our steady knob next to the paper's.


use crate::gpusim::Dataset;

use super::controller::Method;

/// The steady operating point Table 4 reports for a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SteadyKnob {
    Bs(u32),
    Mtl(u32),
}

/// One inference job (Table 4 row).
#[derive(Debug, Clone, Copy)]
pub struct JobSpec {
    pub id: u32,
    /// Paper DNN name (gpusim profile key).
    pub dnn: &'static str,
    pub dataset: Dataset,
    /// p95 latency SLO in ms.
    pub slo_ms: f64,
    /// Method the paper's DNNScaler selected.
    pub paper_method: Method,
    /// Steady BS/MTL the paper reports.
    pub paper_steady: SteadyKnob,
}

macro_rules! job {
    ($id:expr, $dnn:expr, $ds:ident, $slo:expr, B, $bs:expr) => {
        JobSpec {
            id: $id,
            dnn: $dnn,
            dataset: Dataset::$ds,
            slo_ms: $slo,
            paper_method: Method::Batching,
            paper_steady: SteadyKnob::Bs($bs),
        }
    };
    ($id:expr, $dnn:expr, $ds:ident, $slo:expr, MT, $mtl:expr) => {
        JobSpec {
            id: $id,
            dnn: $dnn,
            dataset: Dataset::$ds,
            slo_ms: $slo,
            paper_method: Method::MultiTenancy,
            paper_steady: SteadyKnob::Mtl($mtl),
        }
    };
}

/// Table 4, verbatim.
pub const PAPER_JOBS: &[JobSpec] = &[
    job!(1, "inc-v1", ImageNet, 35.0, MT, 8),
    job!(2, "inc-v2", ImageNet, 53.0, MT, 9),
    job!(3, "inc-v4", ImageNet, 419.0, B, 28),
    job!(4, "mobv1-05", ImageNet, 199.0, MT, 10),
    job!(5, "mobv1-025", ImageNet, 186.0, MT, 10),
    job!(6, "mobv2-1", ImageNet, 81.0, MT, 10),
    job!(7, "nas-large", ImageNet, 417.0, B, 13),
    job!(8, "nas-mob", ImageNet, 85.0, MT, 10),
    job!(9, "pnas-mob", ImageNet, 82.0, MT, 10),
    job!(10, "resv2-50", ImageNet, 45.0, MT, 6),
    job!(11, "resv2-101", ImageNet, 72.0, B, 4),
    job!(12, "resv2-152", ImageNet, 206.0, B, 14),
    job!(13, "resv2-101", ImageNet, 107.0, B, 7),
    job!(14, "inc-v1", Caltech256, 48.0, MT, 10),
    job!(15, "inc-v2", Caltech256, 116.0, B, 16),
    job!(16, "inc-v3", Caltech256, 322.0, B, 37),
    job!(17, "inc-v4", Caltech256, 139.0, B, 10),
    job!(18, "mobv1-1", Caltech256, 89.0, MT, 10),
    job!(19, "mobv1-05", Caltech256, 60.0, MT, 10),
    job!(20, "mobv1-025", Caltech256, 104.0, MT, 10),
    job!(21, "mobv2-1", Caltech256, 129.0, MT, 10),
    job!(22, "pnas-large", Caltech256, 524.0, B, 19),
    job!(23, "pnas-mob", Caltech256, 321.0, B, 50),
    job!(24, "resv2-50", Caltech256, 31.0, B, 1),
    job!(25, "resv2-101", Caltech256, 107.0, B, 10),
    job!(26, "textclassif", Sentiment140, 3.5, B, 102),
    job!(27, "textclassif", ImdbReviews, 3.0, B, 76),
    job!(28, "deepspeech", LibriSpeech, 1250.0, B, 28),
    job!(29, "deepvs", Ledov, 3000.0, MT, 6),
    job!(30, "deepvs", Dhf1k, 5000.0, MT, 8),
];

/// Lookup a Table 4 job by id.
pub fn paper_job(id: u32) -> Option<&'static JobSpec> {
    PAPER_JOBS.iter().find(|j| j.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::paper_profile;

    #[test]
    fn thirty_jobs_with_unique_ids() {
        assert_eq!(PAPER_JOBS.len(), 30);
        let mut ids: Vec<u32> = PAPER_JOBS.iter().map(|j| j.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 30);
        assert_eq!(ids[0], 1);
        assert_eq!(ids[29], 30);
    }

    #[test]
    fn every_job_references_a_calibrated_profile() {
        for j in PAPER_JOBS {
            assert!(paper_profile(j.dnn).is_some(), "job {} references unknown {}", j.id, j.dnn);
            assert!(j.slo_ms > 0.0);
        }
    }

    #[test]
    fn method_split_matches_paper() {
        let mt = PAPER_JOBS.iter().filter(|j| j.paper_method == Method::MultiTenancy).count();
        let b = PAPER_JOBS.iter().filter(|j| j.paper_method == Method::Batching).count();
        assert_eq!((mt, b), (15, 15), "Table 4 has 15 MT and 15 B jobs");
    }

    #[test]
    fn lookup_by_id() {
        assert_eq!(paper_job(5).unwrap().dnn, "mobv1-025");
        assert!(paper_job(31).is_none());
    }

    #[test]
    fn steady_knobs_within_global_bounds() {
        for j in PAPER_JOBS {
            match j.paper_steady {
                SteadyKnob::Bs(b) => assert!((1..=128).contains(&b), "job {}", j.id),
                SteadyKnob::Mtl(n) => assert!((1..=10).contains(&n), "job {}", j.id),
            }
        }
    }
}
