//! Multi-Tenancy Scaler: matrix-completion seed + AIMD refinement
//! (Algorithm 1 lines 30-41).
//!
//! Launching/terminating TF instances is expensive, so unlike the batch
//! scaler this controller cannot binary-search. Instead it
//!
//! 1. seeds `MTL` from the matrix-completion latency estimates (jump
//!    straight to the largest SLO-feasible instance count),
//! 2. then walks additively: `+1` instance while there is headroom
//!    (`p95 < alpha*SLO`), `-1` on violation (`p95 > SLO`) — terminating
//!    only the last-added instance, exactly the paper's scheme.

use super::controller::{Controller, Decision};
use super::matcomp::{pick_mtl, LatencyLibrary};
use super::policy::{Action, Policy, WindowObservation};
use super::{ALPHA, MAX_MTL};

/// Matrix-completion-seeded AIMD instance-count controller.
#[derive(Debug, Clone)]
pub struct MtScaler {
    mtl: u32,
    max_mtl: u32,
    /// Latency estimates from matrix completion (index n-1 = MTL n).
    estimates: Vec<f64>,
    /// Count of launch/terminate events (overhead accounting + Fig. 8).
    pub launches: u32,
    pub terminations: u32,
    settled: bool,
    /// Spike debounce (§4.4), as in the batch scaler.
    violations: u32,
}

impl MtScaler {
    /// Seed from matrix completion: complete the latency curve from the
    /// profiling observations and jump to the largest feasible MTL.
    pub fn seeded(lib: &LatencyLibrary, observed: &[(u32, f64)], slo_ms: f64) -> Self {
        let estimates = lib.complete(observed);
        let mtl = pick_mtl(&estimates, slo_ms).min(lib.max_mtl());
        MtScaler {
            mtl,
            max_mtl: lib.max_mtl().min(MAX_MTL),
            estimates,
            launches: mtl,
            terminations: 0,
            settled: false,
            violations: 0,
        }
    }

    /// Start at a fixed MTL without estimates (brute-force ablation).
    pub fn unseeded(start: u32, max_mtl: u32) -> Self {
        MtScaler {
            mtl: start.clamp(1, max_mtl),
            max_mtl,
            estimates: Vec::new(),
            launches: start,
            terminations: 0,
            settled: false,
            violations: 0,
        }
    }

    pub fn mtl(&self) -> u32 {
        self.mtl
    }

    pub fn estimates(&self) -> &[f64] {
        &self.estimates
    }

    pub fn converged(&self) -> bool {
        self.settled
    }
}

impl Controller for MtScaler {
    fn name(&self) -> &'static str {
        "dnnscaler-mt"
    }

    fn operating_point(&self) -> (u32, u32) {
        (1, self.mtl)
    }

    fn observe_window(&mut self, p95_ms: f64, slo_ms: f64) -> Decision {
        let prev = self.mtl;
        if p95_ms > slo_ms {
            // Violation — in steady state debounce one-off spikes (§4.4);
            // when still moving, terminate the last-added instance right
            // away (line 39-41).
            let act = if self.settled {
                self.violations += 1;
                self.violations >= 2
            } else {
                true
            };
            if act {
                self.violations = 0;
                if self.mtl > 1 {
                    self.mtl -= 1;
                    self.terminations += 1;
                }
            }
        } else if p95_ms < ALPHA * slo_ms {
            self.violations = 0;
            // Headroom: add one instance (line 36-38).
            if self.mtl < self.max_mtl {
                self.mtl += 1;
                self.launches += 1;
            }
        }
        else {
            // In the alpha band — hold (line 34-35).
            self.violations = 0;
        }
        self.settled = self.mtl == prev;
        Decision { bs: 1, mtl: self.mtl, changed: self.mtl != prev }
    }
}

/// `Policy` view of the MT scaler: like the paper's Algorithm 1, it acts
/// on p95/SLO; the richer observation fields are available to subclasses
/// of the interface, not needed here.
impl Policy for MtScaler {
    fn name(&self) -> &'static str {
        Controller::name(self)
    }

    fn operating_point(&self) -> (u32, u32) {
        Controller::operating_point(self)
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        Action::from_decision(self.observe_window(obs.p95_ms, obs.slo_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::matcomp::LatencyLibrary;

    fn linear_lib() -> LatencyLibrary {
        // Library of linear co-location curves with varying slopes.
        let rows: Vec<Vec<f64>> = [0.1, 0.2, 0.4, 0.8]
            .iter()
            .map(|k| (0..10).map(|j| 1.0 + k * j as f64).collect())
            .collect();
        LatencyLibrary::from_rows(rows)
    }

    /// Drive against a synthetic latency curve until stable.
    fn drive(s: &mut MtScaler, lat: impl Fn(u32) -> f64, slo: f64, steps: usize) {
        for _ in 0..steps {
            let n = s.mtl();
            s.observe_window(lat(n), slo);
        }
    }

    #[test]
    fn seed_jumps_to_feasible_mtl() {
        // True latency 10*(1 + 0.3*(n-1)); SLO 31 -> feasible n <= 8.
        let lat = |n: u32| 10.0 * (1.0 + 0.3 * (n - 1) as f64);
        let s = MtScaler::seeded(&linear_lib(), &[(1, lat(1)), (8, lat(8))], 31.0);
        assert!(s.mtl() >= 6, "seed {} should jump close to 8", s.mtl());
        assert!(s.mtl() <= 9);
    }

    #[test]
    fn aimd_corrects_underestimate() {
        // Estimator thinks latency is flat; reality violates at n > 4.
        let lib = LatencyLibrary::from_rows(vec![vec![1.0; 10], vec![1.0; 10]]);
        let mut s = MtScaler::seeded(&lib, &[(1, 10.0), (8, 10.0)], 50.0);
        assert_eq!(s.mtl(), 10, "flat estimate seeds at max");
        let lat = |n: u32| if n > 4 { 60.0 } else { 10.0 };
        drive(&mut s, lat, 50.0, 20);
        // AIMD must walk down until feasible... it settles at 4 or
        // oscillates within the band {4,5}.
        assert!(s.mtl() <= 5, "mtl {} must be trimmed", s.mtl());
        assert!(s.terminations >= 5);
    }

    #[test]
    fn aimd_exploits_headroom() {
        let lib = LatencyLibrary::from_rows(vec![vec![1.0; 10], vec![1.0; 10]]);
        let mut s = MtScaler::seeded(&lib, &[(1, 10.0), (8, 10.0)], 12.0);
        // Seed lands low because estimate ~10 > 0.85*12 is in band...
        let lat = |_n: u32| 5.0; // plenty of headroom in reality
        drive(&mut s, lat, 12.0, 20);
        assert_eq!(s.mtl(), 10, "must climb to max with headroom");
    }

    #[test]
    fn never_leaves_bounds() {
        let mut s = MtScaler::unseeded(5, 10);
        for i in 0..100 {
            let p95 = if i % 3 == 0 { 1e6 } else { 0.0 };
            let d = s.observe_window(p95, 100.0);
            assert!((1..=10).contains(&d.mtl));
            assert_eq!(d.bs, 1);
        }
    }

    #[test]
    fn holds_in_alpha_band() {
        let mut s = MtScaler::unseeded(4, 10);
        let d = s.observe_window(90.0, 100.0);
        assert!(!d.changed);
        assert_eq!(s.mtl(), 4);
        assert!(s.converged());
    }

    #[test]
    fn slo_changes_tracked_like_fig10() {
        // Fig. 10: relaxed SLO -> 10 instances; SLO halves -> ~5 left;
        // SLO rises again -> climbs back.
        let lat = |n: u32| 8.0 * (1.0 + 0.25 * (n - 1) as f64);
        let mut s = MtScaler::unseeded(4, 10);
        drive(&mut s, lat, 100.0, 15);
        assert_eq!(s.mtl(), 10, "relaxed SLO fills the GPU");
        drive(&mut s, lat, 18.0, 15);
        assert!(s.mtl() <= 6, "tight SLO trims instances, got {}", s.mtl());
        drive(&mut s, lat, 100.0, 15);
        assert_eq!(s.mtl(), 10, "climbs back after SLO relaxes");
    }
}
