//! Matrix completion for latency-vs-MTL estimation (paper §3.3.2).
//!
//! The paper profiles a new DNN at only two MTL points (1 and 8) and uses
//! matrix completion (SVD-based, TFOCS in their implementation) to
//! estimate the latency at every other MTL, so the Scaler can jump
//! straight to the largest SLO-feasible instance count instead of paying
//! launch/terminate overhead on a linear search.
//!
//! Our estimator is *hard-impute* (iterative SVD with rank truncation, the
//! fixed-rank cousin of soft-impute / PQ-reconstruction): stack a library
//! of fully-observed latency-ratio curves `L(n)/L(1)` from previously
//! profiled DNNs, append the target row with its two observed entries,
//! then alternate [fill missing entries from the current low-rank
//! reconstruction] and [rank-r SVD truncation] until the imputed entries
//! stop moving. The library rows come from the calibrated `gpusim`
//! profiles — in the paper they accumulate from production profiling runs.

use crate::gpusim::{perf, profiles, Dataset};
use crate::linalg::{svd, Mat};

/// Library of latency-vs-MTL ratio curves for matrix completion.
#[derive(Debug, Clone)]
pub struct LatencyLibrary {
    /// Each row: `[L(1)/L(1), L(2)/L(1), ..., L(max_mtl)/L(1)]`.
    rows: Vec<Vec<f64>>,
    max_mtl: u32,
}

impl LatencyLibrary {
    /// Build the library from every calibrated paper DNN except `exclude`
    /// (the DNN currently being served — it must not see its own curve).
    pub fn from_paper_profiles(exclude: &str, max_mtl: u32) -> Self {
        let mut rows = Vec::new();
        for p in profiles::PAPER_DNNS {
            if p.name == exclude {
                continue;
            }
            let base = perf::batch_latency_ms(p, Dataset::ImageNet, 1, 1).total_ms;
            let row: Vec<f64> = (1..=max_mtl)
                .map(|n| perf::batch_latency_ms(p, Dataset::ImageNet, 1, n).total_ms / base)
                .collect();
            rows.push(row);
        }
        LatencyLibrary { rows, max_mtl }
    }

    /// Library from explicit rows (tests / custom deployments).
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        assert!(!rows.is_empty());
        let max_mtl = rows[0].len() as u32;
        assert!(rows.iter().all(|r| r.len() as usize == max_mtl as usize));
        LatencyLibrary { rows, max_mtl }
    }

    pub fn max_mtl(&self) -> u32 {
        self.max_mtl
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Estimate absolute latency (ms) at every MTL in `1..=max_mtl` for a
    /// DNN observed only at the given `(mtl, latency_ms)` points.
    ///
    /// Returns `estimates[n-1]` = latency at MTL = n. Observed points are
    /// returned exactly.
    pub fn complete(&self, observed: &[(u32, f64)]) -> Vec<f64> {
        assert!(!observed.is_empty(), "need at least one observation");
        let base = observed
            .iter()
            .find(|(n, _)| *n == 1)
            .map(|(_, l)| *l)
            .unwrap_or_else(|| observed[0].1);
        let m = self.max_mtl as usize;

        // Assemble the matrix: library rows fully observed, target last.
        let rows = self.rows.len() + 1;
        let mut mat = Mat::zeros(rows, m);
        let mut mask = vec![vec![true; m]; rows]; // true = observed
        for (i, r) in self.rows.iter().enumerate() {
            for j in 0..m {
                mat[(i, j)] = r[j];
            }
        }
        let target = rows - 1;
        for j in 0..m {
            mask[target][j] = false;
        }
        for &(n, lat) in observed {
            let j = (n as usize).saturating_sub(1).min(m - 1);
            mat[(target, j)] = lat / base;
            mask[target][j] = true;
        }
        // Initialize missing entries with the library column means.
        for j in 0..m {
            if !mask[target][j] {
                let mean: f64 =
                    self.rows.iter().map(|r| r[j]).sum::<f64>() / self.rows.len() as f64;
                mat[(target, j)] = mean;
            }
        }

        // Hard-impute: alternate rank-r reconstruction and data re-pinning.
        let rank = 2.min(m).min(rows);
        let mut current = mat.clone();
        for _ in 0..50 {
            let dec = svd(&current);
            let recon = dec.reconstruct(rank);
            let mut next = current.clone();
            let mut delta: f64 = 0.0;
            for i in 0..rows {
                for j in 0..m {
                    if mask[i][j] {
                        next[(i, j)] = mat[(i, j)];
                    } else {
                        delta = delta.max((recon[(i, j)] - next[(i, j)]).abs());
                        next[(i, j)] = recon[(i, j)];
                    }
                }
            }
            current = next;
            if delta < 1e-9 {
                break;
            }
        }

        // Extract the target row; pin observed points exactly; convert
        // ratios back to absolute latency.
        let mut est: Vec<f64> = (0..m).map(|j| current[(target, j)].max(0.0) * base).collect();
        let mut pins: Vec<(usize, f64)> = observed
            .iter()
            .map(|&(n, lat)| ((n as usize).saturating_sub(1).min(m - 1), lat))
            .collect();
        pins.sort_by_key(|(j, _)| *j);
        for &(j, lat) in &pins {
            est[j] = lat;
        }
        // Physical projection: latency is monotone in MTL, so every
        // interpolated point must lie inside the bracket formed by its
        // nearest observations (a flat target curve in a steep library
        // would otherwise overshoot and even drag pinned points upward).
        for j in 0..m {
            let lo = pins.iter().filter(|(pj, _)| *pj <= j).map(|(_, v)| *v).fold(0.0, f64::max);
            let hi = pins
                .iter()
                .filter(|(pj, _)| *pj >= j)
                .map(|(_, v)| *v)
                .fold(f64::INFINITY, f64::min);
            est[j] = est[j].clamp(lo.min(hi), hi);
        }
        // Monotone pass for the tail beyond the last observation.
        for j in 1..m {
            if est[j] < est[j - 1] {
                est[j] = est[j - 1];
            }
        }
        est
    }
}

/// Pick the largest MTL whose *estimated* latency meets the SLO
/// (Algorithm 1 line 32); at least 1.
pub fn pick_mtl(estimates: &[f64], slo_ms: f64) -> u32 {
    let mut best = 1u32;
    for (idx, &lat) in estimates.iter().enumerate() {
        if lat <= slo_ms {
            best = (idx + 1) as u32;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{perf, profiles, Dataset};

    #[test]
    fn library_excludes_target() {
        let lib = LatencyLibrary::from_paper_profiles("inc-v1", 10);
        assert_eq!(lib.len(), profiles::PAPER_DNNS.len() - 1);
        assert_eq!(lib.max_mtl(), 10);
    }

    #[test]
    fn completion_recovers_heldout_curve() {
        // Leave one DNN out, observe its MTL=1 and MTL=8 latencies, and
        // check the completed curve tracks the true simulator curve.
        for name in ["inc-v1", "mobv1-05", "inc-v4", "resv2-101"] {
            let p = profiles::paper_profile(name).unwrap();
            let truth: Vec<f64> = (1..=10)
                .map(|n| perf::batch_latency_ms(&p, Dataset::ImageNet, 1, n).total_ms)
                .collect();
            let lib = LatencyLibrary::from_paper_profiles(name, 10);
            let est = lib.complete(&[(1, truth[0]), (8, truth[7])]);
            assert_eq!(est.len(), 10);
            // Observed points exact.
            assert_eq!(est[0], truth[0]);
            assert_eq!(est[7], truth[7]);
            // Interpolated points within 35% (the paper's estimator is
            // explicitly "not 100% accurate" — AIMD cleans up the rest).
            for n in [2usize, 4, 6, 9] {
                let rel = (est[n - 1] - truth[n - 1]).abs() / truth[n - 1];
                assert!(rel < 0.35, "{name} MTL={}: est {:.1} true {:.1}", n, est[n - 1], truth[n - 1]);
            }
        }
    }

    #[test]
    fn estimates_monotone_in_mtl() {
        let lib = LatencyLibrary::from_paper_profiles("mobv1-1", 10);
        let est = lib.complete(&[(1, 10.0), (8, 45.0)]);
        for w in est.windows(2) {
            assert!(w[1] >= w[0], "estimates must be monotone: {est:?}");
        }
    }

    #[test]
    fn pick_mtl_boundaries() {
        let est = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(pick_mtl(&est, 35.0), 3);
        assert_eq!(pick_mtl(&est, 50.0), 5);
        assert_eq!(pick_mtl(&est, 9.0), 1); // nothing feasible -> 1
        assert_eq!(pick_mtl(&est, 1e9), 5);
    }

    #[test]
    fn synthetic_low_rank_exact() {
        // Rows are multiples of one curve -> rank 1; completion must be
        // near-exact from two observations.
        let curve: Vec<f64> = (0..10).map(|j| 1.0 + 0.3 * j as f64).collect();
        let rows: Vec<Vec<f64>> =
            (1..6).map(|k| curve.iter().map(|c| c * k as f64 / 3.0).collect()).collect();
        let lib = LatencyLibrary::from_rows(rows);
        let true_target: Vec<f64> = curve.iter().map(|c| c * 7.0).collect();
        let est = lib.complete(&[(1, true_target[0]), (8, true_target[7])]);
        for j in 0..10 {
            let rel = (est[j] - true_target[j]).abs() / true_target[j];
            assert!(rel < 0.05, "j={j}: est {} true {}", est[j], true_target[j]);
        }
    }
}
