//! Clipper baseline (Crankshaw et al., NSDI'17) as described in §4.1:
//! AIMD batch sizing — additively increase BS by a fixed step (4) while
//! the tail latency meets the SLO, multiplicatively back off by 10% on
//! violation. Batching only; Multi-Tenancy is never used.

use super::controller::{Controller, Decision};
use super::policy::{Action, Policy, WindowObservation};
use super::MAX_BS;

/// AIMD batch-size controller (the paper's comparison system).
///
/// After a violation-triggered back-off Clipper *holds* the discovered
/// batch size for a few windows before re-probing additively — without
/// the hold the sawtooth would spend most windows above the SLO, which
/// contradicts the paper's Fig. 6 (Clipper also keeps p95 <= SLO).
#[derive(Debug, Clone)]
pub struct Clipper {
    bs: u32,
    step: u32,
    backoff: f64,
    hard_max: u32,
    /// Windows to hold after a back-off before probing upward again.
    hold_windows: u32,
    hold_left: u32,
}

impl Clipper {
    /// Paper configuration: step 4, 10% back-off, BS in [1, 128].
    pub fn new() -> Self {
        Self::with_params(4, 0.10, MAX_BS)
    }

    pub fn with_params(step: u32, backoff: f64, hard_max: u32) -> Self {
        assert!(step >= 1 && (0.0..1.0).contains(&backoff) && hard_max >= 1);
        Clipper { bs: 1, step, backoff, hard_max, hold_windows: 8, hold_left: 0 }
    }

    pub fn batch_size(&self) -> u32 {
        self.bs
    }
}

impl Default for Clipper {
    fn default() -> Self {
        Self::new()
    }
}

impl Controller for Clipper {
    fn name(&self) -> &'static str {
        "clipper"
    }

    fn operating_point(&self) -> (u32, u32) {
        (self.bs, 1)
    }

    fn observe_window(&mut self, p95_ms: f64, slo_ms: f64) -> Decision {
        let prev = self.bs;
        if p95_ms > slo_ms {
            // Multiplicative back-off: reduce BS by 10%, then hold.
            self.bs = (((self.bs as f64) * (1.0 - self.backoff)).floor() as u32).max(1);
            self.hold_left = self.hold_windows;
        } else if self.hold_left > 0 {
            self.hold_left -= 1;
        } else {
            // Additive increase.
            self.bs = (self.bs + self.step).min(self.hard_max);
        }
        Decision { bs: self.bs, mtl: 1, changed: self.bs != prev }
    }
}

/// `Policy` view of the Clipper baseline (p95/SLO-driven AIMD).
impl Policy for Clipper {
    fn name(&self) -> &'static str {
        Controller::name(self)
    }

    fn operating_point(&self) -> (u32, u32) {
        Controller::operating_point(self)
    }

    fn observe(&mut self, obs: &WindowObservation) -> Action {
        Action::from_decision(self.observe_window(obs.p95_ms, obs.slo_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn additive_increase_until_violation() {
        let mut c = Clipper::new();
        let lat = |b: u32| 2.0 * b as f64; // SLO 100 -> feasible b <= 50
        let mut trace = Vec::new();
        for _ in 0..40 {
            let b = c.batch_size();
            trace.push(b);
            c.observe_window(lat(b), 100.0);
        }
        // Must have climbed past 40 and oscillate around the knee.
        assert!(trace.iter().any(|&b| b >= 45));
        let tail: Vec<u32> = trace[25..].to_vec();
        assert!(tail.iter().all(|&b| (40..=56).contains(&b)), "tail {tail:?}");
    }

    #[test]
    fn slower_than_binary_search() {
        // Fig. 7's observation: Clipper reaches the knee later than
        // DNNScaler's pseudo binary search.
        let lat = |b: u32| 1.0 * b as f64; // knee at ~100 with SLO 100
        let mut c = Clipper::new();
        let mut c_steps = 0;
        while c.batch_size() < 85 && c_steps < 200 {
            let b = c.batch_size();
            c.observe_window(lat(b), 100.0);
            c_steps += 1;
        }
        let mut s = crate::coordinator::scaler_batching::BatchScaler::new();
        let mut s_steps = 0;
        while s.batch_size() < 85 && s_steps < 200 {
            let b = s.batch_size();
            s.observe_window(lat(b), 100.0);
            s_steps += 1;
        }
        assert!(
            s_steps < c_steps,
            "binary search ({s_steps}) must beat AIMD ({c_steps})"
        );
    }

    #[test]
    fn backoff_on_violation() {
        let mut c = Clipper::with_params(4, 0.10, 128);
        // Force BS upward first.
        for _ in 0..30 {
            let b = c.batch_size();
            c.observe_window(if b > 60 { 1e6 } else { 0.0 }, 100.0);
        }
        let b = c.batch_size();
        assert!((54..=68).contains(&b), "oscillates at the knee, got {b}");
    }

    #[test]
    fn respects_bounds() {
        let mut c = Clipper::new();
        for _ in 0..100 {
            c.observe_window(0.0, 100.0);
        }
        assert_eq!(c.batch_size(), MAX_BS);
        for _ in 0..200 {
            c.observe_window(1e9, 100.0);
        }
        assert_eq!(c.batch_size(), 1);
    }
}
