//! `Cluster`: heterogeneous multi-device serving with pluggable job
//! placement — the scheduling layer *above* one device.
//!
//! The paper's DNNScaler tunes batch size or co-location on a single
//! GPU. Warehouse-scale interactive services run on pools of unequal
//! devices — big and small GPUs, plus MIG slices rented out as if they
//! were whole cards — where *which device a job lands on* dominates
//! anything a per-device knob can recover afterwards (the multi-tenant
//! GPU inference surveys and D-STACK's spatio-temporal multiplexing
//! both make this point). This module is that layer:
//!
//! * [`DeviceDesc`] — one serving target: a catalogued [`GpuSpec`]
//!   (`p40`, `p4`, `t4`), its SM capacity as a fraction of the
//!   calibration P40 (`perf_fraction`), and its memory ceiling. A MIG'd
//!   GPU is exposed as `slices` *virtual devices*, carved through
//!   `gpusim::partition` ([`plan_grants`] for the SM split,
//!   [`plan_mem_ceilings`] for the per-slice memory) — "slice as
//!   device": members on a slice execute inside its grant and can never
//!   touch their physical neighbours.
//! * [`Placement`] — the pluggable assignment of jobs to devices:
//!   [`RoundRobin`] (order-blind spreading), [`BestFit`] (memory-aware
//!   bin packing, largest footprint first), and [`InterferenceAware`]
//!   (per-device SM-demand estimates weighted by arrival burstiness, so
//!   two bursty SM hogs never share a device while anything better is
//!   free). Every placer returns a feasible [`Assignment`] or a typed
//!   [`PlacementError`]; whatever a (custom) placer returns is
//!   re-validated before serving.
//! * [`ClusterBuilder`] / [`Cluster`] — jobs carry the same arrival
//!   processes, queueing knobs, and policies as fleet members; serving
//!   runs through the *same* per-device engine the fleet uses
//!   ([`fleet::run_open_devices`] / [`fleet::run_closed_devices`]): per
//!   device, the PR 1–4 semantics (memory admission, SM contention,
//!   deadline shedding, zero-allocation steady state) apply unchanged,
//!   and ONE global virtual-time event calendar interleaves every
//!   member of every device. A single-device cluster therefore
//!   reproduces [`Fleet`] byte for byte (golden-fixture enforced in
//!   `tests/cluster.rs`).
//! * [`ClusterOutcome`] — per-device [`FleetOutcome`]s plus the
//!   placement metadata (placer name, assignment). Placement is decided
//!   once at `build()` — migration-free by design in this PR.
//!
//! ```ignore
//! let out = Cluster::builder()
//!     .device(TESLA_P40)             // one big card ...
//!     .mig_device(TESLA_P40, 2)      // ... plus two half-card slices
//!     .job_with_arrivals(job_a, PolicySpec::QueueAware,
//!                        ArrivalPattern::bursty(80.0, 4.0, 4.0, 1.0))
//!     .job_with_arrivals(job_b, PolicySpec::DnnScaler,
//!                        ArrivalPattern::poisson(30.0))
//!     .placement(InterferenceAware::new())
//!     .build()?                      // placement happens HERE (typed errors)
//!     .run()?;                       // ClusterOutcome
//! ```
//!
//! [`plan_grants`]: crate::gpusim::plan_grants
//! [`plan_mem_ceilings`]: crate::gpusim::plan_mem_ceilings
//! [`fleet::run_open_devices`]: super::fleet
//! [`fleet::run_closed_devices`]: super::fleet
//! [`Fleet`]: super::fleet::Fleet
//! [`FleetOutcome`]: super::fleet::FleetOutcome

use crate::device::DeviceError;
use crate::gpusim::{
    gpu_by_name, paper_profile, perf, plan_grants, plan_mem_ceilings, GpuSpec, PartitionMode,
    MIN_GRANT, TESLA_P40,
};
use crate::workload::ArrivalPattern;

use super::dynamics::{Autoscaler, ChurnSchedule, DynamicsCfg, DynamicsOutcome, PlacementPolicy};
use super::faults::FaultSchedule;
use super::fleet::{
    self, arrival_seed, finish_fleet, new_closed_member, new_open_member, validate_arrival_modes,
    validate_member_cfg, ClosedDevice, DeviceCtx, FleetOutcome, MemberCfg, OpenDevice,
    Partitioner,
};
use super::job::JobSpec;
use super::session::{ConfigError, PolicySpec, RunConfig};
use super::slo::{SloClass, SloReport};

use std::fmt;

/// One serving target of a cluster: a whole GPU, or one MIG slice of a
/// GPU exposed as a virtual device.
#[derive(Debug, Clone)]
pub struct DeviceDesc {
    /// Display name, e.g. `p40#0` or `p40#1[2/4]` (slice 2 of 4).
    pub name: String,
    /// The physical accelerator this (virtual) device lives on.
    pub spec: GpuSpec,
    /// SM capacity as a fraction of the calibration GPU (Tesla P40):
    /// the grant this device's members execute inside. 1.0 only for a
    /// whole P40-class card; smaller catalogued GPUs and MIG slices
    /// hold proportionally less.
    pub perf_fraction: f64,
    /// Memory admission ceiling (MB): the whole card's memory, or the
    /// slice's share of it under MIG.
    pub mem_mb: f64,
    /// Index of the physical GPU (devices carved from one card share it).
    pub physical: usize,
    /// `Some((slice_index, slices))` when this is a MIG virtual device.
    pub slice: Option<(u32, u32)>,
    /// `$ / device-hour` billed while the device is active — from the
    /// [`dynamics::price_per_hour`] catalogue (a MIG slice costs its
    /// grant's share of the card), overridable per device with
    /// [`ClusterBuilder::prices`]. Only the dynamics layer bills it;
    /// static runs carry it as metadata.
    ///
    /// [`dynamics::price_per_hour`]: super::dynamics::price_per_hour
    pub price_per_hour: f64,
}

/// A parsed CLI device spec: `NAME` or `NAME:migN` with `NAME` one of
/// the catalogued GPUs (`p40`, `p4`, `t4`).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub gpu: GpuSpec,
    /// `Some(n)` = expose the card as `n` MIG virtual devices.
    pub mig: Option<u32>,
}

impl DeviceSpec {
    /// Parse one spec token (`p40`, `t4`, `p40:mig4`, ...).
    pub fn parse(s: &str) -> Option<DeviceSpec> {
        let s = s.trim();
        if let Some((name, rest)) = s.split_once(':') {
            let n = rest.trim().strip_prefix("mig")?;
            let slices: u32 = n.parse().ok().filter(|&n| n >= 1)?;
            Some(DeviceSpec { gpu: gpu_by_name(name)?, mig: Some(slices) })
        } else {
            Some(DeviceSpec { gpu: gpu_by_name(s)?, mig: None })
        }
    }

    /// Parse a comma-separated device list (the CLI's `--devices`).
    pub fn parse_list(s: &str) -> Result<Vec<DeviceSpec>, ConfigError> {
        s.split(',')
            .map(|tok| {
                DeviceSpec::parse(tok)
                    .ok_or_else(|| ConfigError::BadDeviceSpec { spec: tok.trim().to_string() })
            })
            .collect()
    }
}

/// What the placement sees of one job: the spec plus the demand
/// estimates placement heuristics act on (all derived from the
/// calibrated device model and the job's arrival process — no serving
/// has happened yet when placement runs).
#[derive(Debug, Clone)]
pub struct PlacementJob {
    pub spec: JobSpec,
    /// Bare model footprint at (bs = 1, mtl = 1), MB — the least memory
    /// the job can ever occupy on its device.
    pub mem_floor_mb: f64,
    /// One instance's SM residency on the calibration GPU (0..=1): the
    /// per-device SM-demand estimate. A `resv2`/`inc-v4`-class model
    /// (~0.9) fills a device on its own; a mobilenet (~0.1) co-locates
    /// freely.
    pub sm_demand: f64,
    /// Mean offered arrival rate, requests/s (0 for closed-loop jobs).
    pub mean_rate: f64,
    /// Peak-to-mean arrival ratio: the `factor` of a bursty pattern,
    /// 1.0 for smooth (uniform/Poisson/closed) arrivals and for traces
    /// (whose shape is not summarized here).
    pub burstiness: f64,
}

impl PlacementJob {
    pub(crate) fn from_cfg(m: &MemberCfg<'_>) -> Self {
        // The builder validated the DNN before placement runs.
        let p = paper_profile(m.job.dnn).expect("validated DNN");
        let burstiness = match &m.arrivals {
            ArrivalPattern::Bursty { factor, .. } => *factor,
            _ => 1.0,
        };
        PlacementJob {
            spec: m.job,
            // The same footprint definition MIG admission uses, so
            // placement feasibility and slice admission cannot disagree.
            mem_floor_mb: fleet::model_footprint_mb(m.job.dnn),
            sm_demand: perf::residency(&p, 1),
            mean_rate: m.arrivals.mean_rate(),
            burstiness,
        }
    }

    /// The interference weight heuristics rank by: SM demand scaled by
    /// how bursty the offered load is (a bursty SM hog is the worst
    /// possible neighbour).
    pub fn interference_weight(&self) -> f64 {
        self.sm_demand * self.burstiness.max(1.0)
    }
}

/// Job-to-device assignment: `device_of[j]` is the device index serving
/// job `j` (indices into the builder's job and device orders).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    pub device_of: Vec<usize>,
}

impl Assignment {
    /// Check feasibility: one device per job, every index in range, and
    /// no device memory over-committed by the bare model footprints.
    /// Run on every assignment a [`Placement`] returns — a buggy custom
    /// placer yields a typed error here, never a mid-serve OOM surprise.
    pub fn validate(
        &self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<(), PlacementError> {
        if self.device_of.len() != jobs.len() {
            return Err(PlacementError::WrongLength {
                got: self.device_of.len(),
                jobs: jobs.len(),
            });
        }
        let mut demand = vec![0.0f64; devices.len()];
        for (job, &d) in self.device_of.iter().enumerate() {
            if d >= devices.len() {
                return Err(PlacementError::DeviceOutOfRange {
                    job,
                    device: d,
                    devices: devices.len(),
                });
            }
            demand[d] += jobs[job].mem_floor_mb;
        }
        for (device, (&demand_mb, desc)) in demand.iter().zip(devices).enumerate() {
            if demand_mb > desc.mem_mb {
                return Err(PlacementError::MemoryOverCommit {
                    device,
                    demand_mb,
                    capacity_mb: desc.mem_mb,
                });
            }
        }
        Ok(())
    }
}

/// Why a placement failed. Every variant is a *configuration* verdict:
/// placement runs at `build()`, so these surface as
/// [`ConfigError::Placement`] before any serving happens.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementError {
    /// The cluster has no devices to place onto.
    NoDevices,
    /// The assignment does not cover every job exactly once.
    WrongLength { got: usize, jobs: usize },
    /// An assignment points at a device that does not exist.
    DeviceOutOfRange { job: usize, device: usize, devices: usize },
    /// No device has enough free memory left for this job's footprint.
    NoDeviceFits { job: usize, need_mb: f64 },
    /// The finished assignment over-commits a device's memory.
    MemoryOverCommit { device: usize, demand_mb: f64, capacity_mb: f64 },
}

impl fmt::Display for PlacementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlacementError::NoDevices => write!(f, "no devices to place jobs onto"),
            PlacementError::WrongLength { got, jobs } => {
                write!(f, "assignment covers {got} job(s), cluster has {jobs}")
            }
            PlacementError::DeviceOutOfRange { job, device, devices } => write!(
                f,
                "job {job} assigned to device {device}, but only {devices} device(s) exist"
            ),
            PlacementError::NoDeviceFits { job, need_mb } => write!(
                f,
                "job {job} (footprint {need_mb:.0} MB) fits no device's remaining memory"
            ),
            PlacementError::MemoryOverCommit { device, demand_mb, capacity_mb } => write!(
                f,
                "device {device} over-committed: {demand_mb:.0} MB of model footprints on \
                 {capacity_mb:.0} MB"
            ),
        }
    }
}

impl std::error::Error for PlacementError {}

/// A job-placement strategy: map jobs onto devices once, up front.
///
/// Contract: on success the returned [`Assignment`] covers every job
/// with an in-range device and over-commits no device's memory (the
/// cluster re-validates via [`Assignment::validate`] regardless); on
/// failure a typed [`PlacementError`] names the first obstacle.
/// Placement is pure configuration — it sees demand *estimates*
/// ([`PlacementJob`]), never serving results.
pub trait Placement {
    /// Human-readable name for reports/snapshots.
    fn name(&self) -> &'static str;

    /// Assign every job a device.
    fn place(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<Assignment, PlacementError>;
}

/// Forwarding impl so a boxed placement (e.g. one picked at runtime
/// from a CLI flag) plugs into [`ClusterBuilder::placement`] directly.
impl<P: Placement + ?Sized> Placement for Box<P> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn place(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<Assignment, PlacementError> {
        (**self).place(jobs, devices)
    }
}

/// Order-blind spreading: job `j` lands on device `j mod D`. The
/// baseline every demand-aware placer must beat — and the one that
/// co-locates two bursty neighbours whenever the job order happens to
/// align them.
#[derive(Debug, Clone, Default)]
pub struct RoundRobin;

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin
    }
}

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn place(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<Assignment, PlacementError> {
        if devices.is_empty() {
            return Err(PlacementError::NoDevices);
        }
        let a = Assignment {
            device_of: (0..jobs.len()).map(|j| j % devices.len()).collect(),
        };
        // Modulo placement is memory-blind; keep the contract honest by
        // reporting the infeasibility as a typed error instead of
        // handing back an assignment that cannot serve.
        a.validate(jobs, devices)?;
        Ok(a)
    }
}

/// Memory-aware bin packing: jobs in decreasing footprint order, each
/// onto the device whose remaining memory is *smallest but sufficient*
/// (classic best-fit-decreasing). Packing tight preserves the largest
/// contiguous free memory for jobs still to come — the placement that
/// minimizes "nothing fits" failures, not the one that spreads load
/// (it happily stacks every job onto one device if that device keeps
/// fitting them; use [`InterferenceAware`] when SM pressure matters).
#[derive(Debug, Clone, Default)]
pub struct BestFit;

impl BestFit {
    pub fn new() -> Self {
        BestFit
    }
}

impl Placement for BestFit {
    fn name(&self) -> &'static str {
        "bestfit"
    }

    fn place(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<Assignment, PlacementError> {
        if devices.is_empty() {
            return Err(PlacementError::NoDevices);
        }
        let mut free: Vec<f64> = devices.iter().map(|d| d.mem_mb).collect();
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            jobs[b]
                .mem_floor_mb
                .total_cmp(&jobs[a].mem_floor_mb)
                .then(a.cmp(&b))
        });
        let mut device_of = vec![0usize; jobs.len()];
        for job in order {
            let need = jobs[job].mem_floor_mb;
            let best = free
                .iter()
                .enumerate()
                .filter(|(_, &f)| f >= need)
                .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)));
            let Some((d, _)) = best else {
                return Err(PlacementError::NoDeviceFits { job, need_mb: need });
            };
            free[d] -= need;
            device_of[job] = d;
        }
        let a = Assignment { device_of };
        a.validate(jobs, devices)?;
        Ok(a)
    }
}

/// Interference-aware greedy placement: jobs in decreasing
/// [`PlacementJob::interference_weight`] order (bursty SM hogs first),
/// each onto the memory-feasible device with the lowest projected SM
/// pressure — the sum of already-placed interference weights divided by
/// the device's capacity fraction, with an extra penalty for pairing
/// two bursty jobs. The effect the acceptance test pins down: two
/// bursty neighbours never share a device while a quieter one is free.
#[derive(Debug, Clone)]
pub struct InterferenceAware {
    /// Extra pressure charged for co-locating a bursty job (factor > 1)
    /// with a device that already hosts one.
    bursty_penalty: f64,
}

impl InterferenceAware {
    pub fn new() -> Self {
        InterferenceAware { bursty_penalty: 1.0 }
    }
}

impl Default for InterferenceAware {
    fn default() -> Self {
        Self::new()
    }
}

impl Placement for InterferenceAware {
    fn name(&self) -> &'static str {
        "interference"
    }

    fn place(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
    ) -> Result<Assignment, PlacementError> {
        if devices.is_empty() {
            return Err(PlacementError::NoDevices);
        }
        let mut free: Vec<f64> = devices.iter().map(|d| d.mem_mb).collect();
        let mut pressure: Vec<f64> = vec![0.0; devices.len()];
        let mut hosts_bursty: Vec<bool> = vec![false; devices.len()];
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            jobs[b]
                .interference_weight()
                .total_cmp(&jobs[a].interference_weight())
                .then(a.cmp(&b))
        });
        let mut device_of = vec![0usize; jobs.len()];
        for job in order {
            let j = &jobs[job];
            let bursty = j.burstiness > 1.0;
            let best = (0..devices.len())
                .filter(|&d| free[d] >= j.mem_floor_mb)
                .min_by(|&a, &b| {
                    let cost = |d: usize| {
                        let mut c = (pressure[d] + j.interference_weight())
                            / devices[d].perf_fraction.max(MIN_GRANT);
                        if bursty && hosts_bursty[d] {
                            c += self.bursty_penalty;
                        }
                        c
                    };
                    cost(a).total_cmp(&cost(b)).then(a.cmp(&b))
                });
            let Some(d) = best else {
                return Err(PlacementError::NoDeviceFits { job, need_mb: j.mem_floor_mb });
            };
            free[d] -= j.mem_floor_mb;
            pressure[d] += j.interference_weight();
            hosts_bursty[d] |= bursty;
            device_of[job] = d;
        }
        let a = Assignment { device_of };
        a.validate(jobs, devices)?;
        Ok(a)
    }
}

/// Builder for [`Cluster`]. Devices and jobs accumulate in order; jobs
/// take the same per-member knobs as [`super::fleet::FleetBuilder`]
/// (applying to the most recently added job). Placement runs at
/// [`ClusterBuilder::build`], so every placement problem is a typed
/// [`ConfigError`] before any serving starts.
pub struct ClusterBuilder<'a> {
    cfg: RunConfig,
    seed: u64,
    devices: Vec<DeviceDesc>,
    n_physical: usize,
    jobs: Vec<MemberCfg<'a>>,
    placement: Box<dyn Placement + 'a>,
    rate_list: Option<Vec<f64>>,
    class_list: Option<Vec<SloClass>>,
    knob_before_job: Option<&'static str>,
    device_error: Option<ConfigError>,
    churn: ChurnSchedule<'a>,
    placement_policy: Option<Box<dyn PlacementPolicy + 'a>>,
    autoscaler: Option<Box<dyn Autoscaler + 'a>>,
    faults: FaultSchedule,
    mtbf_windows: Option<f64>,
    mttr_windows: Option<f64>,
    price_list: Option<Vec<f64>>,
    threads: usize,
}

impl<'a> ClusterBuilder<'a> {
    fn new() -> Self {
        ClusterBuilder {
            cfg: RunConfig::default(),
            seed: 42,
            devices: Vec::new(),
            n_physical: 0,
            jobs: Vec::new(),
            placement: Box::new(RoundRobin::new()),
            rate_list: None,
            class_list: None,
            knob_before_job: None,
            device_error: None,
            churn: ChurnSchedule::new(),
            placement_policy: None,
            autoscaler: None,
            faults: FaultSchedule::new(),
            mtbf_windows: None,
            mttr_windows: None,
            price_list: None,
            threads: 1,
        }
    }

    /// Replace the shared serving config.
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn windows(mut self, windows: usize) -> Self {
        self.cfg.windows = windows;
        self
    }

    pub fn rounds_per_window(mut self, rounds: usize) -> Self {
        self.cfg.rounds_per_window = rounds;
        self
    }

    /// Seed for member simulators and arrival streams. Job `j` derives
    /// its streams from `seed + j` regardless of where placement puts
    /// it, so two placements of the same cluster face *identical*
    /// per-job load and noise — placements are directly comparable.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Add one whole GPU to the pool.
    pub fn device(mut self, spec: GpuSpec) -> Self {
        let physical = self.n_physical;
        self.n_physical += 1;
        self.devices.push(whole_desc(spec, physical));
        self
    }

    /// Add one GPU carved into `slices` MIG virtual devices: the SM
    /// split comes from [`plan_grants`] (equal whole-slice bundles) and
    /// each slice's memory ceiling from [`plan_mem_ceilings`] — slice
    /// as device, with both resources partitioned.
    pub fn mig_device(mut self, spec: GpuSpec, slices: u32) -> Self {
        let physical = self.n_physical;
        self.n_physical += 1;
        let mode = PartitionMode::MigSlices { slices };
        let grants = match plan_grants(mode, &vec![None; slices as usize]) {
            Ok(g) => g,
            Err(e) => {
                if self.device_error.is_none() {
                    self.device_error = Some(ConfigError::BadPartition(e));
                }
                return self;
            }
        };
        let ceilings = plan_mem_ceilings(mode, &grants, spec.mem_mb);
        let base = whole_device_fraction(&spec);
        for (k, (&g, &mem)) in grants.iter().zip(&ceilings).enumerate() {
            let fraction = base * g;
            // A slice of a smaller-than-P40 card can undercut MIN_GRANT
            // even when the slice count alone is fine (e.g. p4:mig32).
            if fraction < MIN_GRANT {
                if self.device_error.is_none() {
                    self.device_error = Some(ConfigError::SliceTooSmall {
                        gpu: spec.name.to_string(),
                        slices,
                        fraction,
                    });
                }
                return self;
            }
            self.devices.push(DeviceDesc {
                name: format!("{}#{physical}[{}/{slices}]", short_name(&spec), k + 1),
                perf_fraction: fraction,
                mem_mb: mem,
                physical,
                slice: Some((k as u32 + 1, slices)),
                // A rented slice costs its share of the card.
                price_per_hour: super::dynamics::price_per_hour(&spec) * g,
                spec: spec.clone(),
            });
        }
        self
    }

    /// Add a device from a parsed CLI spec (`p40`, `t4:mig2`, ...).
    pub fn device_spec(self, spec: &DeviceSpec) -> Self {
        match spec.mig {
            None => self.device(spec.gpu.clone()),
            Some(slices) => self.mig_device(spec.gpu.clone(), slices),
        }
    }

    /// The placement strategy (default: [`RoundRobin`]).
    pub fn placement(mut self, placement: impl Placement + 'a) -> Self {
        self.placement = Box::new(placement);
        self
    }

    /// Job churn: launch/retire events fired at window boundaries.
    /// Any non-empty schedule switches the run onto the dynamics path
    /// (requires every job to be open-loop).
    pub fn churn(mut self, schedule: ChurnSchedule<'a>) -> Self {
        self.churn = schedule;
        self
    }

    /// Live migration: a [`PlacementPolicy`] consulted at every window
    /// boundary. Switches the run onto the dynamics path.
    pub fn placement_policy(mut self, policy: impl PlacementPolicy + 'a) -> Self {
        self.placement_policy = Some(Box::new(policy));
        self
    }

    /// Price-aware elasticity: an [`Autoscaler`] consulted at every
    /// window boundary. Switches the run onto the dynamics path.
    pub fn autoscaler(mut self, scaler: impl Autoscaler + 'a) -> Self {
        self.autoscaler = Some(Box::new(scaler));
        self
    }

    /// Fault injection: crash / degrade / repair events fired at window
    /// boundaries (validated at build; see
    /// [`FaultSchedule`](super::faults::FaultSchedule) and
    /// `docs/faults.md`). Any non-empty schedule switches the run onto
    /// the dynamics path.
    pub fn faults(mut self, schedule: FaultSchedule) -> Self {
        self.faults = schedule;
        self
    }

    /// Stochastic fault injection: per-device crash/repair events drawn
    /// from exponential MTBF / MTTR distributions (both in control
    /// windows), materialized deterministically from the run seed at
    /// build time and merged with any explicit
    /// [`ClusterBuilder::faults`] schedule. Switches the run onto the
    /// dynamics path.
    pub fn stochastic_faults(mut self, mtbf_windows: f64, mttr_windows: f64) -> Self {
        self.mtbf_windows = Some(mtbf_windows);
        self.mttr_windows = Some(mttr_windows);
        self
    }

    /// Override the catalogue `$ / device-hour` prices: one value
    /// (broadcast to every device) or exactly one per device, in device
    /// order — any other count is a typed
    /// [`ConfigError::ListCountMismatch`].
    pub fn prices(mut self, prices: &[f64]) -> Self {
        self.price_list = Some(prices.to_vec());
        self
    }

    /// Worker threads for serving (default 1 = the serial reference
    /// engine). Devices are sharded into contiguous whole-device chunks,
    /// one scoped worker per chunk; snapshot output is byte-identical at
    /// every thread count (see `docs/perf.md`). Values are clamped to
    /// `[1, devices]` at run time; `threads(0)` behaves like `threads(1)`.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Add a closed-loop job with its serving policy.
    pub fn job(self, job: &JobSpec, policy: PolicySpec<'a>) -> Self {
        self.job_with_arrivals(job, policy, ArrivalPattern::Closed)
    }

    /// Add a job with its own open-loop arrival process. Follow with
    /// [`ClusterBuilder::queue_capacity`] /
    /// [`ClusterBuilder::batch_timeout_ms`] /
    /// [`ClusterBuilder::shed_deadline`] to tune that job's queueing.
    pub fn job_with_arrivals(
        mut self,
        job: &JobSpec,
        policy: PolicySpec<'a>,
        arrivals: ArrivalPattern,
    ) -> Self {
        self.jobs.push(MemberCfg::new(job, policy, arrivals));
        self
    }

    /// Give every job a Poisson arrival process: one rate (broadcast)
    /// or exactly one per job, in job order. Any other count is the
    /// same typed [`ConfigError::ListCountMismatch`] the fleet's
    /// reservation list gets — a list longer than the job count is
    /// refused, never silently truncated — and combining the list with
    /// jobs that already carry their own open-loop arrival process is a
    /// typed [`ConfigError::ListOverridesMemberKnob`], not a silent
    /// overwrite.
    pub fn poisson_rates(mut self, rates: &[f64]) -> Self {
        self.rate_list = Some(rates.to_vec());
        self
    }

    fn last_job(&mut self, knob: &'static str) -> Option<&mut MemberCfg<'a>> {
        if self.jobs.is_empty() && self.knob_before_job.is_none() {
            self.knob_before_job = Some(knob);
        }
        self.jobs.last_mut()
    }

    /// Bound the most recently added job's request queue.
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        if let Some(m) = self.last_job("queue_capacity") {
            m.queue_capacity = Some(capacity);
        }
        self
    }

    /// Batch-formation timeout for the most recently added job.
    pub fn batch_timeout_ms(mut self, timeout_ms: f64) -> Self {
        if let Some(m) = self.last_job("batch_timeout_ms") {
            m.batch_timeout_ms = Some(timeout_ms);
        }
        self
    }

    /// SLO deadline shedding for the most recently added job.
    pub fn shed_deadline(mut self, enabled: bool) -> Self {
        if let Some(m) = self.last_job("shed_deadline") {
            m.shed_deadline = enabled;
        }
        self
    }

    /// Explicit shed deadline (ms) for the most recently added job,
    /// replacing the job's model SLO as the shedding cutoff. Requires
    /// open-loop arrivals and [`ClusterBuilder::shed_deadline`]; the
    /// job's [`SloClass`] (if any) still scales it.
    pub fn deadline_ms(mut self, deadline_ms: f64) -> Self {
        if let Some(m) = self.last_job("deadline_ms") {
            m.deadline_ms = Some(deadline_ms);
        }
        self
    }

    /// Service class for the most recently added job: scales the shed
    /// deadline, weights overload admission, and adds the job to the
    /// outcome's per-class [`SloReport`]. Open-loop only.
    pub fn slo_class(mut self, class: SloClass) -> Self {
        if let Some(m) = self.last_job("slo_class") {
            m.slo_class = Some(class);
        }
        self
    }

    /// Give every job a service class: one class (broadcast) or exactly
    /// one per job, in job order — any other count is a typed
    /// [`ConfigError::ListCountMismatch`], and combining the list with
    /// per-job [`ClusterBuilder::slo_class`] calls is a typed
    /// [`ConfigError::ListOverridesMemberKnob`].
    pub fn slo_classes(mut self, classes: &[SloClass]) -> Self {
        self.class_list = Some(classes.to_vec());
        self
    }

    /// Validate the configuration, run the placement, and assemble the
    /// cluster. All placement failures surface here as
    /// [`ConfigError::Placement`].
    pub fn build(mut self) -> Result<Cluster<'a>, ConfigError> {
        if let Some(e) = self.device_error.take() {
            return Err(e);
        }
        if let Some(knob) = self.knob_before_job {
            return Err(ConfigError::MemberKnobBeforeJob { knob });
        }
        if self.cfg.windows == 0 {
            return Err(ConfigError::ZeroWindows);
        }
        if self.cfg.rounds_per_window == 0 {
            return Err(ConfigError::ZeroRounds);
        }
        if self.cfg.max_bs == 0 || self.cfg.max_mtl == 0 {
            return Err(ConfigError::ZeroKnobCeiling {
                max_bs: self.cfg.max_bs,
                max_mtl: self.cfg.max_mtl,
            });
        }
        if self.devices.is_empty() {
            return Err(ConfigError::NoClusterDevices);
        }
        if self.jobs.is_empty() {
            return Err(ConfigError::NoFleetMembers);
        }
        // A rate list maps onto the jobs through the same expansion
        // policy as the fleet's reservation list (broadcast one value or
        // match one-per-job; other counts and conflicts with jobs that
        // already carry open-loop arrivals are typed errors).
        if let Some(list) = self.rate_list.take() {
            let expanded = fleet::expand_member_list(
                "poisson_rates",
                "job_with_arrivals",
                list,
                self.jobs.len(),
                self.jobs.iter().any(|m| !m.arrivals.is_closed()),
            )?;
            for (m, rate) in self.jobs.iter_mut().zip(expanded) {
                m.arrivals = ArrivalPattern::Poisson { rate };
            }
        }
        if let Some(list) = self.class_list.take() {
            let expanded = fleet::expand_member_list(
                "slo_classes",
                "slo_class",
                list,
                self.jobs.len(),
                self.jobs.iter().any(|m| m.slo_class.is_some()),
            )?;
            for (m, class) in self.jobs.iter_mut().zip(expanded) {
                m.slo_class = Some(class);
            }
        }
        for m in &self.jobs {
            validate_member_cfg(m)?;
        }
        validate_arrival_modes(&self.jobs)?;
        // Per-device price overrides expand like every other list knob.
        if let Some(list) = self.price_list.take() {
            let expanded =
                fleet::expand_member_list("prices", "device", list, self.devices.len(), false)?;
            for (d, price) in self.devices.iter_mut().zip(expanded) {
                d.price_per_hour = price;
            }
        }
        // Dynamics: any churn / migration / autoscaling / fault request
        // switches the run onto the dynamic path; nothing requested
        // leaves the static path (and its snapshot bytes) untouched.
        let dynamics = if !self.churn.is_empty()
            || self.placement_policy.is_some()
            || self.autoscaler.is_some()
            || !self.faults.is_empty()
            || self.mtbf_windows.is_some()
        {
            if self.jobs.iter().any(|m| m.arrivals.is_closed()) {
                return Err(ConfigError::DynamicsRequireOpenLoop);
            }
            let ids: Vec<u32> = self.jobs.iter().map(|m| m.job.id).collect();
            self.churn.validate(self.cfg.windows, &ids)?;
            // Stochastic faults materialize from the run seed, merge
            // with the explicit schedule, and the merged whole is
            // validated — a stochastic crash landing on an explicitly
            // crashed device is caught here, not at run time.
            let mut faults = self.faults;
            if let Some(mtbf) = self.mtbf_windows {
                let mttr = self.mttr_windows.unwrap_or(1.0);
                if !mtbf.is_finite() || mtbf <= 0.0 || !mttr.is_finite() || mttr <= 0.0 {
                    return Err(ConfigError::BadFaults {
                        reason: format!(
                            "stochastic faults need finite positive MTBF and MTTR \
                             (got mtbf {mtbf}, mttr {mttr} windows)"
                        ),
                    });
                }
                faults.extend(super::faults::materialize_stochastic(
                    self.seed,
                    self.devices.len(),
                    self.cfg.windows,
                    mtbf,
                    mttr,
                ));
            }
            faults.validate(self.cfg.windows, self.devices.len())?;
            let faults = (!faults.is_empty() || self.mtbf_windows.is_some()).then_some(faults);
            Some(DynamicsCfg {
                churn: self.churn,
                policy: self.placement_policy,
                autoscaler: self.autoscaler,
                faults,
            })
        } else {
            None
        };
        // Placement: decided once, re-validated whatever the placer
        // claims, and recorded in the outcome.
        let pjobs: Vec<PlacementJob> = self.jobs.iter().map(PlacementJob::from_cfg).collect();
        let assignment = self
            .placement
            .place(&pjobs, &self.devices)
            .map_err(ConfigError::Placement)?;
        assignment.validate(&pjobs, &self.devices).map_err(ConfigError::Placement)?;
        Ok(Cluster {
            cfg: self.cfg,
            seed: self.seed,
            devices: self.devices,
            jobs: self.jobs,
            placement: self.placement.name().to_string(),
            assignment,
            dynamics,
            threads: self.threads,
        })
    }
}

/// Build the [`DeviceDesc`] for one whole GPU — shared by
/// [`ClusterBuilder::device`] and the autoscaler's pool growth, so a
/// grown device is indistinguishable from a built one.
pub(crate) fn whole_desc(spec: GpuSpec, physical: usize) -> DeviceDesc {
    DeviceDesc {
        name: format!("{}#{physical}", short_name(&spec)),
        perf_fraction: whole_device_fraction(&spec),
        mem_mb: spec.mem_mb,
        physical,
        slice: None,
        price_per_hour: super::dynamics::price_per_hour(&spec),
        spec,
    }
}

/// Short CLI-ish name for a catalogued spec (`Tesla P40` -> `p40`).
fn short_name(spec: &GpuSpec) -> String {
    spec.name
        .rsplit(' ')
        .next()
        .unwrap_or(spec.name)
        .to_ascii_lowercase()
}

/// A device's SM capacity relative to the calibration GPU. The perf
/// model is calibrated on the P40, so a smaller catalogued card is
/// modelled as a fractional-capacity P40 (members execute inside the
/// fraction as a grant); anything at least as fast serves as a whole
/// calibration device.
fn whole_device_fraction(spec: &GpuSpec) -> f64 {
    (spec.peak_tflops / TESLA_P40.peak_tflops).min(1.0)
}

/// One cluster device's serving context: its own memory ceiling and SM
/// fraction, members time-sharing within it (single source for both the
/// open- and closed-loop branches of [`Cluster::run`]).
pub(crate) fn timeshare_ctx<'x>(desc: &DeviceDesc, members: usize, cfg: &RunConfig) -> DeviceCtx<'x> {
    DeviceCtx::new(
        desc.mem_mb,
        desc.perf_fraction,
        Partitioner::timeshare(members),
        cfg.windows,
    )
}

/// Fold finished per-device serving states into [`DeviceOutcome`]s:
/// `split` extracts each device's context and member outcomes (the only
/// part that differs between the open and closed paths).
pub(crate) fn fold_device_outcomes<'a, T>(
    devices: &[DeviceDesc],
    groups: &[Vec<usize>],
    devs: Vec<T>,
    split: impl Fn(T) -> (DeviceCtx<'a>, Vec<super::session::JobOutcome>),
) -> Vec<DeviceOutcome> {
    devices
        .iter()
        .zip(groups)
        .zip(devs)
        .map(|((desc, group), dev)| {
            let (ctx, members) = split(dev);
            DeviceOutcome {
                device: desc.clone(),
                jobs: group.clone(),
                fleet: finish_fleet(members, ctx, PartitionMode::TimeShare),
            }
        })
        .collect()
}

/// A validated, placed cluster, ready to run. Fields are crate-visible
/// so `coordinator::testkit` can re-serve the identical validated,
/// placed configuration through its naive reference executor.
pub struct Cluster<'a> {
    pub(crate) cfg: RunConfig,
    pub(crate) seed: u64,
    pub(crate) devices: Vec<DeviceDesc>,
    pub(crate) jobs: Vec<MemberCfg<'a>>,
    pub(crate) placement: String,
    pub(crate) assignment: Assignment,
    pub(crate) dynamics: Option<DynamicsCfg<'a>>,
    pub(crate) threads: usize,
}

/// One device's slice of a finished cluster run.
#[derive(Debug, Clone)]
pub struct DeviceOutcome {
    pub device: DeviceDesc,
    /// Global job indices served on this device, in member order.
    pub jobs: Vec<usize>,
    /// The device's serving result — the same shape a single-device
    /// [`super::fleet::Fleet`] run produces (per-member outcomes,
    /// admission/contention telemetry).
    pub fleet: FleetOutcome,
}

/// Result of one cluster run: per-device fleet outcomes plus the
/// placement metadata that produced them.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub devices: Vec<DeviceOutcome>,
    /// Name of the placement strategy that assigned the jobs.
    pub placement: String,
    /// Device index per job, in job order.
    pub assignment: Vec<usize>,
    /// Sum of device total throughputs (inferences/s).
    pub total_throughput: f64,
    /// Sum of device total goodputs (SLO-met inferences/s).
    pub total_goodput: f64,
    /// Dynamics telemetry (churn, migration, autoscaling, billing).
    /// `None` on the static path — the snapshot for a dynamics-free run
    /// stays byte-identical to what it was before dynamics existed.
    pub dynamics: Option<DynamicsOutcome>,
    /// Per-class goodput/shed accounting, merged across every device's
    /// [`FleetOutcome::slo`] report. `None` unless some job carries an
    /// [`SloClass`] — unclassed runs keep their snapshot bytes.
    pub slo: Option<SloReport>,
}

/// Merge the per-device SLO class reports into one cluster-wide report
/// (`None` when no device hosts a classed member). Shared by the static
/// and dynamic runners so both outcomes satisfy the same audit.
pub(crate) fn merge_slo_reports(devices: &[DeviceOutcome]) -> Option<SloReport> {
    let mut merged: Option<SloReport> = None;
    for dev in devices {
        if let Some(r) = &dev.fleet.slo {
            match merged.as_mut() {
                Some(acc) => acc.merge(r),
                None => merged = Some(r.clone()),
            }
        }
    }
    merged
}

/// A conservation invariant the finished outcome violates. These are
/// accounting identities, not tuning judgements: every arrived request
/// must be served, dropped, shed, or still in flight; spatial SM grants
/// must never exceed the whole device; peak memory must respect the
/// capacity the run claimed to enforce.
#[derive(Debug, Clone, PartialEq)]
pub enum AuditError {
    /// A job finished more requests than ever arrived:
    /// `served + dropped + shed + failed > arrived`.
    Conservation { job: usize, arrived: u64, served: u64, dropped: u64, shed: u64, failed: u64 },
    /// A window granted more than the whole device's SMs.
    OverSubscribed { device: usize, window: usize, granted: f64 },
    /// Peak combined memory demand exceeded the device's capacity.
    MemoryOverCeiling { device: usize, peak_mem_mb: f64, capacity_mb: f64 },
    /// The outcome's per-class SLO report disagrees with the accounting
    /// re-derived from the per-member outcomes: every classed member's
    /// goodput and shed count must land in exactly its own class bucket.
    ClassAccounting { class: &'static str, field: &'static str, reported: f64, recomputed: f64 },
}

impl fmt::Display for AuditError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AuditError::Conservation { job, arrived, served, dropped, shed, failed } => write!(
                f,
                "job {job}: served {served} + dropped {dropped} + shed {shed} \
                 + failed {failed} exceeds arrived {arrived}"
            ),
            AuditError::OverSubscribed { device, window, granted } => write!(
                f,
                "device {device}, window {window}: granted SM fraction {granted:.4} > 1"
            ),
            AuditError::MemoryOverCeiling { device, peak_mem_mb, capacity_mb } => write!(
                f,
                "device {device}: peak memory {peak_mem_mb:.1} MB over \
                 capacity {capacity_mb:.1} MB"
            ),
            AuditError::ClassAccounting { class, field, reported, recomputed } => write!(
                f,
                "class {class}: reported {field} {reported} disagrees with \
                 per-member recount {recomputed}"
            ),
        }
    }
}

impl std::error::Error for AuditError {}

impl ClusterOutcome {
    /// Check the conservation invariants every finished run must satisfy.
    ///
    /// Returns the first violation found; `run()` debug-asserts this in
    /// test builds, and callers that assemble outcomes by hand (or
    /// deserialize them) can audit explicitly. Requests still in flight
    /// when the run ends are legitimate, so request conservation is an
    /// inequality: `served + dropped + shed <= arrived`.
    pub fn audit(&self) -> Result<(), AuditError> {
        for (d, dev) in self.devices.iter().enumerate() {
            for (j, m) in dev.fleet.members.iter().enumerate() {
                if m.arrived == 0 {
                    continue; // closed-loop member: no arrival process to conserve
                }
                let served: u64 =
                    m.latencies.iter().map(|&(_, w)| w).sum::<f64>().round() as u64;
                if served + m.drops + m.dropped_deadline + m.dropped_failure > m.arrived {
                    return Err(AuditError::Conservation {
                        job: dev.jobs.get(j).copied().unwrap_or(j),
                        arrived: m.arrived,
                        served,
                        dropped: m.drops,
                        shed: m.dropped_deadline,
                        failed: m.dropped_failure,
                    });
                }
            }
            for (w, grants) in dev.fleet.grant_trace.iter().enumerate() {
                let granted: f64 = grants.iter().sum();
                if granted > 1.0 + 1e-9 {
                    return Err(AuditError::OverSubscribed { device: d, window: w, granted });
                }
            }
            if dev.fleet.peak_mem_mb > dev.fleet.mem_capacity_mb + 1e-6 {
                return Err(AuditError::MemoryOverCeiling {
                    device: d,
                    peak_mem_mb: dev.fleet.peak_mem_mb,
                    capacity_mb: dev.fleet.mem_capacity_mb,
                });
            }
        }
        // Per-class conservation: the merged SLO report must equal the
        // accounting re-derived member by member — a class can neither
        // gain nor lose goodput/shed relative to the jobs inside it. An
        // all-zero report and an absent one are equivalent here.
        let reported = self.slo.clone().unwrap_or_default();
        let recomputed = SloReport::from_members(
            self.devices
                .iter()
                .flat_map(|d| d.fleet.members.iter())
                .map(|m| (m.slo_class, m.goodput, m.dropped_deadline)),
        )
        .unwrap_or_default();
        for c in SloClass::ALL {
            let a = reported.class(c);
            let b = recomputed.class(c);
            let mismatch = if a.members != b.members {
                Some(("members", a.members as f64, b.members as f64))
            } else if a.shed != b.shed {
                Some(("shed", a.shed as f64, b.shed as f64))
            } else if (a.goodput - b.goodput).abs() > 1e-6 {
                Some(("goodput", a.goodput, b.goodput))
            } else {
                None
            };
            if let Some((field, reported, recomputed)) = mismatch {
                return Err(AuditError::ClassAccounting {
                    class: c.name(),
                    field,
                    reported,
                    recomputed,
                });
            }
        }
        Ok(())
    }
}

impl<'a> Cluster<'a> {
    pub fn builder() -> ClusterBuilder<'a> {
        ClusterBuilder::new()
    }

    /// The placement decided at build time (device index per job).
    pub fn assignment(&self) -> &Assignment {
        &self.assignment
    }

    /// The devices jobs were placed onto.
    pub fn devices(&self) -> &[DeviceDesc] {
        &self.devices
    }

    /// Serve every job to completion on its assigned device. With
    /// `threads(1)` (the default) all devices interleave in one serial
    /// virtual-time loop; with more threads the device list is sharded
    /// across scoped workers, byte-identically (see `docs/perf.md`).
    pub fn run(self) -> Result<ClusterOutcome, DeviceError> {
        let Cluster { cfg, seed, devices, jobs, placement, assignment, dynamics, threads } = self;
        if let Some(dc) = dynamics {
            // Churn / migration / autoscaling requested: the dynamic
            // runner owns the whole window loop.
            return super::dynamics::run_dynamic(
                &cfg, seed, devices, jobs, placement, assignment, dc, threads,
            );
        }
        let open = !jobs.iter().all(|m| m.arrivals.is_closed());
        // Group global job indices per device, preserving job order.
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); devices.len()];
        for (j, &d) in assignment.device_of.iter().enumerate() {
            groups[d].push(j);
        }
        // Job j's simulator/arrival seeds derive from its GLOBAL index,
        // exactly as fleet member j's would — a single-device cluster is
        // bit-identical to the fleet, and re-placing jobs never changes
        // the load they offer.
        let mut cfgs: Vec<Option<MemberCfg<'a>>> = jobs.into_iter().map(Some).collect();

        let outcomes: Vec<DeviceOutcome> = if open {
            let mut devs: Vec<OpenDevice<'_>> = Vec::with_capacity(devices.len());
            for (desc, group) in devices.iter().zip(&groups) {
                let mut members = Vec::with_capacity(group.len());
                for &j in group {
                    let m = cfgs[j].take().expect("job placed once");
                    members.push(new_open_member(
                        m,
                        &cfg,
                        seed + j as u64,
                        arrival_seed(seed, j),
                    )?);
                }
                devs.push(OpenDevice::new(timeshare_ctx(desc, group.len(), &cfg), members));
            }
            fleet::run_open_devices_parallel(&cfg, &mut devs, threads).map_err(|f| f.error)?;
            fold_device_outcomes(&devices, &groups, devs, |dev| {
                (dev.ctx, dev.members.into_iter().map(fleet::open_member_outcome).collect())
            })
        } else {
            let mut devs: Vec<ClosedDevice<'_>> = Vec::with_capacity(devices.len());
            for (desc, group) in devices.iter().zip(&groups) {
                let mut members = Vec::with_capacity(group.len());
                for &j in group {
                    let m = cfgs[j].take().expect("job placed once");
                    members.push(new_closed_member(m, &cfg, seed + j as u64)?);
                }
                devs.push(ClosedDevice {
                    ctx: timeshare_ctx(desc, group.len(), &cfg),
                    members,
                });
            }
            fleet::run_closed_devices_parallel(&cfg, &mut devs, threads).map_err(|f| f.error)?;
            fold_device_outcomes(&devices, &groups, devs, |dev| {
                (dev.ctx, dev.members.into_iter().map(fleet::closed_member_outcome).collect())
            })
        };
        let total_throughput = outcomes.iter().map(|d| d.fleet.total_throughput).sum();
        let total_goodput = outcomes.iter().map(|d| d.fleet.total_goodput).sum();
        let slo = merge_slo_reports(&outcomes);
        let out = ClusterOutcome {
            devices: outcomes,
            placement,
            assignment: assignment.device_of,
            total_throughput,
            total_goodput,
            dynamics: None,
            slo,
        };
        debug_assert!(out.audit().is_ok(), "conservation audit failed: {:?}", out.audit());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;
    use crate::gpusim::{TESLA_P4, TESLA_T4};

    fn pj(dnn: &'static str, burstiness: f64, mem: f64, demand: f64) -> PlacementJob {
        let mut spec = *paper_job(1).unwrap();
        spec.dnn = dnn;
        PlacementJob {
            spec,
            mem_floor_mb: mem,
            sm_demand: demand,
            mean_rate: 50.0,
            burstiness,
        }
    }

    fn whole(mem_mb: f64) -> DeviceDesc {
        DeviceDesc {
            name: "test".into(),
            spec: TESLA_P40,
            perf_fraction: 1.0,
            mem_mb,
            physical: 0,
            slice: None,
            price_per_hour: 1.20,
        }
    }

    #[test]
    fn device_spec_parsing() {
        let d = DeviceSpec::parse("p40").unwrap();
        assert_eq!(d.gpu.name, "Tesla P40");
        assert_eq!(d.mig, None);
        let d = DeviceSpec::parse(" t4:mig2 ").unwrap();
        assert_eq!(d.gpu.name, "Tesla T4");
        assert_eq!(d.mig, Some(2));
        assert!(DeviceSpec::parse("p40:mig0").is_none());
        assert!(DeviceSpec::parse("h100").is_none());
        assert!(DeviceSpec::parse("p40:nvlink").is_none());
        let list = DeviceSpec::parse_list("p40,p4,t4:mig2").unwrap();
        assert_eq!(list.len(), 3);
        assert!(matches!(
            DeviceSpec::parse_list("p40,bogus").unwrap_err(),
            ConfigError::BadDeviceSpec { spec } if spec == "bogus"
        ));
    }

    #[test]
    fn mig_device_splits_sm_and_memory() {
        let b = Cluster::builder().mig_device(TESLA_P40, 4).device(TESLA_P4);
        assert_eq!(b.devices.len(), 5);
        for k in 0..4 {
            let d = &b.devices[k];
            assert_eq!(d.physical, 0);
            assert_eq!(d.slice, Some((k as u32 + 1, 4)));
            assert!((d.perf_fraction - 0.25).abs() < 1e-9, "{}", d.perf_fraction);
            assert!((d.mem_mb - TESLA_P40.mem_mb / 4.0).abs() < 1e-6);
        }
        let p4 = &b.devices[4];
        assert_eq!(p4.physical, 1);
        assert_eq!(p4.slice, None);
        assert!((p4.perf_fraction - 5.5 / 11.76).abs() < 1e-9);
        assert_eq!(p4.mem_mb, TESLA_P4.mem_mb);
        assert!(p4.name.starts_with("p4#1"), "{}", p4.name);
    }

    #[test]
    fn builder_rejects_missing_parts_and_bad_lists() {
        let job = paper_job(1).unwrap();
        assert_eq!(
            Cluster::builder().job(job, PolicySpec::Clipper).build().err(),
            Some(ConfigError::NoClusterDevices)
        );
        assert_eq!(
            Cluster::builder().device(TESLA_P40).build().err(),
            Some(ConfigError::NoFleetMembers)
        );
        assert_eq!(
            Cluster::builder().queue_capacity(4).device(TESLA_P40).build().err(),
            Some(ConfigError::MemberKnobBeforeJob { knob: "queue_capacity" })
        );
        // The PR 5 bugfix check, cluster side: a rate list longer than
        // the job count is typed, not truncated.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job(job, PolicySpec::Clipper)
                .poisson_rates(&[10.0, 20.0, 30.0])
                .build()
                .err(),
            Some(ConfigError::ListCountMismatch {
                knob: "poisson_rates",
                got: 3,
                members: 1
            })
        );
        // Rates must still be valid arrival rates.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job(job, PolicySpec::Clipper)
                .poisson_rates(&[0.0])
                .build()
                .err(),
            Some(ConfigError::BadArrivalRate { rate: 0.0 })
        );
        // Queueing knobs still require open-loop arrivals.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job(job, PolicySpec::Clipper)
                .shed_deadline(true)
                .build()
                .err(),
            Some(ConfigError::ShedRequiresOpenLoop)
        );
        // A MIG split of a small card whose slices undercut MIN_GRANT
        // is named truthfully (not blamed on a reservation nobody set).
        assert!(matches!(
            Cluster::builder()
                .mig_device(TESLA_P4, 32)
                .job(job, PolicySpec::Clipper)
                .build()
                .err(),
            Some(ConfigError::SliceTooSmall { slices: 32, .. })
        ));
        // The rate list refuses to silently overwrite a job's own
        // open-loop arrival process.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job_with_arrivals(
                    job,
                    PolicySpec::Clipper,
                    ArrivalPattern::bursty(20.0, 2.0, 4.0, 1.0)
                )
                .poisson_rates(&[10.0])
                .build()
                .err(),
            Some(ConfigError::ListOverridesMemberKnob {
                list: "poisson_rates",
                knob: "job_with_arrivals"
            })
        );
    }

    #[test]
    fn builder_rejects_misplaced_class_knobs() {
        let job = paper_job(1).unwrap();
        // A class knob before any job is the same typed error every
        // other per-job knob gets.
        assert_eq!(
            Cluster::builder().slo_class(SloClass::Gold).device(TESLA_P40).build().err(),
            Some(ConfigError::MemberKnobBeforeJob { knob: "slo_class" })
        );
        // Classes act at shed/admission time: closed-loop jobs have
        // neither, so the knob is refused rather than silently inert.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job(job, PolicySpec::Clipper)
                .slo_class(SloClass::Silver)
                .build()
                .err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "slo_class" })
        );
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job(job, PolicySpec::Clipper)
                .deadline_ms(40.0)
                .build()
                .err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "deadline_ms" })
        );
        // The class list expands exactly like every other list knob.
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(20.0))
                .slo_classes(&[SloClass::Gold, SloClass::BestEffort])
                .build()
                .err(),
            Some(ConfigError::ListCountMismatch { knob: "slo_classes", got: 2, members: 1 })
        );
        assert_eq!(
            Cluster::builder()
                .device(TESLA_P40)
                .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(20.0))
                .slo_class(SloClass::Gold)
                .slo_classes(&[SloClass::Silver])
                .build()
                .err(),
            Some(ConfigError::ListOverridesMemberKnob {
                list: "slo_classes",
                knob: "slo_class"
            })
        );
    }

    #[test]
    fn classed_cluster_merges_per_class_reports_and_audits() {
        let run = |classed: bool| {
            let mut b = Cluster::builder()
                .device(TESLA_P40)
                .device(TESLA_T4)
                .windows(6)
                .rounds_per_window(10)
                .seed(11);
            for id in [1, 5, 4] {
                b = b
                    .job_with_arrivals(
                        paper_job(id).unwrap(),
                        PolicySpec::Static { bs: 1, mtl: 1 },
                        ArrivalPattern::poisson(30.0),
                    )
                    .shed_deadline(true);
            }
            if classed {
                b = b.slo_classes(&[SloClass::Gold, SloClass::Silver, SloClass::BestEffort]);
            }
            b.build().unwrap().run().unwrap()
        };
        // Unclassed: no report, and the audit's class leg is vacuous.
        let plain = run(false);
        assert!(plain.slo.is_none());
        assert_eq!(plain.audit(), Ok(()));
        // Classed: the report merges across devices — one member per
        // class regardless of which device each job landed on — and the
        // per-class totals re-derive from the member outcomes.
        let mut out = run(true);
        let report = out.slo.clone().expect("classed run must carry a report");
        for c in SloClass::ALL {
            assert_eq!(report.class(c).members, 1, "{}", c.name());
        }
        let gold_goodput: f64 = out
            .devices
            .iter()
            .flat_map(|d| d.fleet.members.iter())
            .filter(|m| m.slo_class == Some(SloClass::Gold))
            .map(|m| m.goodput)
            .sum();
        assert!((report.class(SloClass::Gold).goodput - gold_goodput).abs() < 1e-9);
        assert_eq!(out.audit(), Ok(()));
        // Forge class accounting three ways: inflated goodput, a shed
        // count from nowhere, and a dropped report — each is caught.
        let mut forged = out.clone();
        forged.slo.as_mut().unwrap().per_class[0].goodput += 1.0;
        assert!(
            matches!(
                forged.audit(),
                Err(AuditError::ClassAccounting { class: "gold", field: "goodput", .. })
            ),
            "got {:?}",
            forged.audit()
        );
        let mut forged = out.clone();
        forged.slo.as_mut().unwrap().per_class[2].shed += 1;
        assert!(
            matches!(
                forged.audit(),
                Err(AuditError::ClassAccounting { class: "best-effort", field: "shed", .. })
            ),
            "got {:?}",
            forged.audit()
        );
        out.slo = None;
        assert!(
            matches!(
                out.audit(),
                Err(AuditError::ClassAccounting { field: "members", .. })
            ),
            "got {:?}",
            out.audit()
        );
    }

    #[test]
    fn round_robin_spreads_and_reports_infeasibility() {
        let jobs = vec![pj("inc-v1", 1.0, 700.0, 0.4); 5];
        let devices = vec![whole(24_000.0), whole(24_000.0)];
        let mut rr = RoundRobin::new();
        let a = rr.place(&jobs, &devices).unwrap();
        assert_eq!(a.device_of, vec![0, 1, 0, 1, 0]);
        // Memory-blind modulo placement must still refuse infeasible
        // outcomes with a typed error.
        let tight = vec![whole(1_000.0), whole(24_000.0)];
        assert!(matches!(
            rr.place(&jobs, &tight).unwrap_err(),
            PlacementError::MemoryOverCommit { device: 0, .. }
        ));
        assert_eq!(rr.place(&jobs, &[]).unwrap_err(), PlacementError::NoDevices);
    }

    #[test]
    fn bestfit_packs_by_memory() {
        // A 2 GB model must land on the big-memory card; the small
        // device keeps the small models.
        let jobs = vec![
            pj("mobv1-025", 1.0, 400.0, 0.1),
            pj("nas-large", 1.0, 2022.0, 0.9),
            pj("mobv1-05", 1.0, 450.0, 0.2),
        ];
        let devices = vec![whole(1_000.0), whole(24_000.0)];
        let a = BestFit::new().place(&jobs, &devices).unwrap();
        assert_eq!(a.device_of[1], 1, "big model must go to the big device");
        a.validate(&jobs, &devices).unwrap();
        // Nothing fits a cluster of tiny devices: typed error.
        let tiny = vec![whole(100.0)];
        assert!(matches!(
            BestFit::new().place(&jobs, &tiny).unwrap_err(),
            PlacementError::NoDeviceFits { .. }
        ));
    }

    #[test]
    fn interference_aware_separates_bursty_hogs() {
        // Two bursty SM hogs + two quiet small jobs, ordered so round
        // robin would co-locate the hogs on device 0.
        let jobs = vec![
            pj("inc-v4", 4.0, 1418.0, 0.95),
            pj("mobv1-025", 1.0, 400.0, 0.08),
            pj("inc-v4", 4.0, 1418.0, 0.95),
            pj("mobv1-025", 1.0, 400.0, 0.08),
        ];
        let devices = vec![whole(24_000.0), whole(24_000.0)];
        let rr = RoundRobin::new().place(&jobs, &devices).unwrap();
        assert_eq!(rr.device_of[0], rr.device_of[2], "RR co-locates the hogs");
        let ia = InterferenceAware::new().place(&jobs, &devices).unwrap();
        assert_ne!(
            ia.device_of[0], ia.device_of[2],
            "interference-aware placement must separate the bursty hogs: {:?}",
            ia.device_of
        );
        ia.validate(&jobs, &devices).unwrap();
    }

    #[test]
    fn assignment_validation_catches_bad_placers() {
        let jobs = vec![pj("inc-v1", 1.0, 700.0, 0.4); 2];
        let devices = vec![whole(24_000.0)];
        let short = Assignment { device_of: vec![0] };
        assert!(matches!(
            short.validate(&jobs, &devices).unwrap_err(),
            PlacementError::WrongLength { got: 1, jobs: 2 }
        ));
        let oob = Assignment { device_of: vec![0, 3] };
        assert!(matches!(
            oob.validate(&jobs, &devices).unwrap_err(),
            PlacementError::DeviceOutOfRange { job: 1, device: 3, devices: 1 }
        ));
        let ok = Assignment { device_of: vec![0, 0] };
        ok.validate(&jobs, &devices).unwrap();
    }

    #[test]
    fn placement_errors_name_the_problem() {
        assert!(PlacementError::NoDevices.to_string().contains("no devices"));
        assert!(PlacementError::WrongLength { got: 1, jobs: 3 }.to_string().contains("3"));
        assert!(PlacementError::DeviceOutOfRange { job: 0, device: 9, devices: 2 }
            .to_string()
            .contains("9"));
        assert!(PlacementError::NoDeviceFits { job: 2, need_mb: 2022.0 }
            .to_string()
            .contains("2022"));
        assert!(PlacementError::MemoryOverCommit {
            device: 1,
            demand_mb: 9000.0,
            capacity_mb: 8192.0
        }
        .to_string()
        .contains("8192"));
    }

    #[test]
    fn heterogeneous_cluster_serves_on_every_device() {
        // 1 whole T4 + a P40 in two MIG halves, three open-loop jobs:
        // every device with members must serve, and per-job load must
        // be identical however the totals split.
        let out = Cluster::builder()
            .device(TESLA_T4)
            .mig_device(TESLA_P40, 2)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(40.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(30.0),
            )
            .job_with_arrivals(
                paper_job(4).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(20.0),
            )
            .placement(BestFit::new())
            .windows(8)
            .rounds_per_window(10)
            .seed(5)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.devices.len(), 3);
        assert_eq!(out.assignment.len(), 3);
        let served: usize = out.devices.iter().map(|d| d.jobs.len()).sum();
        assert_eq!(served, 3, "every job served exactly once");
        assert!(out.total_throughput > 0.0);
        for dev in &out.devices {
            assert_eq!(dev.fleet.members.len(), dev.jobs.len());
            for m in &dev.fleet.members {
                assert!(m.throughput > 0.0, "{} on {}: zero throughput", m.dnn, dev.device.name);
            }
            // A device's admission capacity is its OWN ceiling (a MIG
            // half exposes half the card).
            assert_eq!(dev.fleet.mem_capacity_mb, dev.device.mem_mb);
            assert!(dev.fleet.peak_mem_mb <= dev.fleet.mem_capacity_mb + 1e-9);
        }
    }

    #[test]
    fn slice_devices_serve_slower_than_whole_devices() {
        // The same job at the same static point and offered load: a
        // half-card MIG slice must deliver a worse (or equal) sojourn
        // tail than a whole card, never a better one — slice-as-device
        // really executes inside the grant.
        let run = |mig: bool| {
            let b = Cluster::builder();
            let b = if mig { b.mig_device(TESLA_P40, 2) } else { b.device(TESLA_P40) };
            b.job_with_arrivals(
                paper_job(3).unwrap(),
                PolicySpec::Static { bs: 8, mtl: 1 },
                ArrivalPattern::poisson(60.0),
            )
            .windows(8)
            .rounds_per_window(12)
            .seed(9)
            .build()
            .unwrap()
            .run()
            .unwrap()
        };
        let whole = run(false);
        let sliced = run(true);
        let wj = &whole.devices[0].fleet.members[0];
        let sj = sliced
            .devices
            .iter()
            .find(|d| !d.fleet.members.is_empty())
            .map(|d| &d.fleet.members[0])
            .unwrap();
        assert!(
            sj.p95_ms >= wj.p95_ms,
            "half-card p95 {:.2} ms beat whole-card {:.2} ms",
            sj.p95_ms,
            wj.p95_ms
        );
        assert!(whole.total_throughput > 0.0 && sliced.total_throughput > 0.0);
    }

    #[test]
    fn audit_passes_on_real_runs_and_catches_mock_violations() {
        let mut out = Cluster::builder()
            .device(TESLA_T4)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(40.0),
            )
            .windows(4)
            .rounds_per_window(10)
            .seed(3)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.audit(), Ok(()));

        // Forge more served work than ever arrived: conservation breaks.
        let mut forged = out.clone();
        forged.devices[0].fleet.members[0].latencies.push((5.0, 1e9));
        assert!(
            matches!(forged.audit(), Err(AuditError::Conservation { job: 0, .. })),
            "got {:?}",
            forged.audit()
        );

        // Forge a window granting more SMs than the whole device has.
        let mut forged = out.clone();
        forged.devices[0].fleet.grant_trace.push(vec![0.7, 0.7]);
        assert!(
            matches!(
                forged.audit(),
                Err(AuditError::OverSubscribed { device: 0, window: 0, .. })
            ),
            "got {:?}",
            forged.audit()
        );

        // Forge a peak memory demand above the advertised capacity.
        out.devices[0].fleet.peak_mem_mb = out.devices[0].fleet.mem_capacity_mb + 1.0;
        assert!(
            matches!(out.audit(), Err(AuditError::MemoryOverCeiling { device: 0, .. })),
            "got {:?}",
            out.audit()
        );
    }

    #[test]
    fn audit_runs_on_the_merged_outcome_through_the_parallel_path() {
        // The conservation audit must see the MERGED ClusterOutcome a
        // parallel run folds from its shards — per-shard state alone
        // cannot check cross-device invariants. Run a multi-device
        // cluster with more shards than workers could hide behind, then
        // forge violations into the merged outcome exactly as the
        // serial audit test does.
        let mut b = Cluster::builder()
            .windows(4)
            .rounds_per_window(10)
            .seed(9)
            .threads(8)
            .placement(RoundRobin::new());
        for _ in 0..4 {
            b = b.device(TESLA_T4);
        }
        for _ in 0..8 {
            b = b.job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(40.0),
            );
        }
        let out = b.build().unwrap().run().unwrap();
        assert_eq!(out.devices.len(), 4);
        assert_eq!(out.audit(), Ok(()));

        // A violation forged into ANY device of the merged outcome is
        // caught, including devices served by later shards.
        for d in 0..4 {
            let mut forged = out.clone();
            forged.devices[d].fleet.members[0].latencies.push((5.0, 1e9));
            assert!(
                matches!(forged.audit(), Err(AuditError::Conservation { .. })),
                "device {d}: got {:?}",
                forged.audit()
            );
            let mut forged = out.clone();
            forged.devices[d].fleet.grant_trace.push(vec![0.7, 0.7]);
            assert!(
                matches!(forged.audit(), Err(AuditError::OverSubscribed { device, .. }) if device == d),
                "device {d}: got {:?}",
                forged.audit()
            );
            let mut forged = out.clone();
            forged.devices[d].fleet.peak_mem_mb = forged.devices[d].fleet.mem_capacity_mb + 1.0;
            assert!(
                matches!(forged.audit(), Err(AuditError::MemoryOverCeiling { device, .. }) if device == d),
                "device {d}: got {:?}",
                forged.audit()
            );
        }
    }
}
