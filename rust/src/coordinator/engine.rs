//! The shared virtual-time open-loop serving engine.
//!
//! PR 1 buried the open-loop event loop inside `ServingSession::run`,
//! which meant `Fleet` could only serve closed-loop lockstep windows.
//! This module extracts that loop into a reusable per-member core so
//! *every* serving entry point drives the same machinery:
//!
//! * [`OpenLoop`] owns one member's arrival stream ([`Feed`] over an
//!   `ArrivalGenerator`), its (optionally bounded) [`RequestQueue`], the
//!   batch-formation timeout, and the member's virtual clock;
//! * [`OpenLoop::serve_round`] forms and executes ONE batch — dispatched
//!   as soon as `bs * mtl` requests are waiting (size trigger) or once
//!   the oldest waiting request has waited `batch_timeout_ms` (timeout
//!   trigger) — charges every request its full sojourn (queueing delay +
//!   service, optionally inflated by a fleet SM-contention factor), and
//!   advances the member clock by the observed batch latency;
//! * [`WindowAccum`] snapshots the member counters at a window boundary
//!   and folds the rounds served since into the `WindowRecord` /
//!   `WindowObservation` pair every policy consumes.
//!
//! `ServingSession` runs one `OpenLoop`; `Fleet` runs one per member and
//! interleaves their rounds by next-event time through the O(log M)
//! [`super::calendar::EventCalendar`], which is what makes per-member
//! arrival processes, trace replay, and cross-job burst interference
//! expressible at all.
//!
//! `serve_round` mutates ONLY the popped member's state (its `OpenLoop`,
//! simulator, and window accumulator); every cross-member coupling —
//! admission, contention shares, slice clamps, rebalancing — happens
//! per device at window boundaries. That structural fact is what lets
//! the cluster shard whole-device event loops across worker threads
//! (PR 7) while staying byte-identical to serial execution.
//!
//! ## Allocation discipline (see `docs/perf.md`)
//!
//! The steady-state per-request/per-batch path performs **zero** heap
//! allocations (asserted by the allocation-counter test below):
//!
//! * arrivals are synthesized in chunks into a recycled [`Feed`] buffer
//!   (`workload::ARRIVAL_CHUNK` per refill, one generator call per chunk
//!   instead of one per request);
//! * batches drain into a per-member scratch `Vec<Request>` owned by
//!   [`OpenLoop`] (`RequestQueue::take_batch_into`), never into a fresh
//!   allocation;
//! * [`WindowAccum`] is constructed once per member and *recycled*:
//!   [`WindowAccum::begin`] clears (but keeps) the latency buffer and
//!   the percentile scratch, so windows after the first reuse storage.
//!
//! Two modeling notes shared by every driver:
//!
//! * A partial batch still executes at the configured `mtl` (all
//!   co-located instances stay resident; the device bills full
//!   co-location contention and power), so light-load MT latency is the
//!   conservative upper bound, not the idle-instances optimum.
//! * With deadline shedding enabled, expiry is checked at dispatch time:
//!   a request whose queueing delay alone already exceeds the SLO is
//!   dropped (counted in `dropped_deadline`) instead of wasting a batch
//!   slot it can no longer use.

use crate::device::{Device, DeviceError};
use crate::workload::{ArrivalGenerator, ArrivalPattern, Request, RequestQueue, ARRIVAL_CHUNK};

use super::policy::WindowObservation;
use super::session::WindowRecord;

/// How a member's window shares the GPU's SMs — the two regimes the
/// fleet's `PartitionMode` selects between.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum SmShare {
    /// Time-sharing: execute on the whole device and inflate the observed
    /// latency by the fleet's combined-contention factor (1.0 solo).
    Inflate(f64),
    /// Spatial partition: execute inside an SM capacity grant (MPS
    /// fraction / MIG slice bundle); no cross-member inflation at all.
    Grant(f64),
    /// Slice-as-device (`coordinator::cluster`): execute inside the
    /// virtual device's SM grant *and* inflate by the time-sharing
    /// factor of the members co-located on that same slice. `grant = 1,
    /// factor = f` is byte-identical to `Inflate(f)` (a full grant
    /// consumes the device model and its noise stream identically).
    GrantInflate { grant: f64, factor: f64 },
}

/// Peekable arrival stream over an [`ArrivalGenerator`], prefetching
/// [`ARRIVAL_CHUNK`] timestamps at a time into a recycled buffer. The
/// emitted sequence is identical to calling the generator per request —
/// chunking only amortizes the call overhead (and for traces replaces
/// per-item copies with slice copies).
pub(crate) struct Feed {
    gen: ArrivalGenerator,
    /// Prefetched arrivals; `buf[pos..]` are not yet handed out.
    buf: Vec<f64>,
    pos: usize,
    /// The generator returned no further arrivals (closed pattern or an
    /// exhausted trace): `peek` is `INFINITY` forever.
    exhausted: bool,
    count: u64,
}

impl Feed {
    pub(crate) fn new(gen: ArrivalGenerator) -> Self {
        let mut feed = Feed {
            gen,
            buf: Vec::with_capacity(ARRIVAL_CHUNK),
            pos: 0,
            exhausted: false,
            count: 0,
        };
        feed.refill();
        feed
    }

    fn refill(&mut self) {
        self.buf.clear();
        self.pos = 0;
        if self.gen.fill_next(&mut self.buf, ARRIVAL_CHUNK) == 0 {
            self.exhausted = true;
        }
    }

    #[inline]
    pub(crate) fn peek(&self) -> f64 {
        match self.buf.get(self.pos) {
            Some(&t) => t,
            None => f64::INFINITY,
        }
    }

    /// Consume the next arrival. Only callable when [`Feed::peek`] is
    /// finite (the serving loop never pops an exhausted stream).
    pub(crate) fn pop(&mut self) -> f64 {
        debug_assert!(self.pos < self.buf.len(), "pop on an exhausted feed");
        let t = self.buf[self.pos];
        self.pos += 1;
        self.count += 1;
        if self.pos == self.buf.len() && !self.exhausted {
            self.refill();
        }
        t
    }
}

/// One member's open-loop serving state: arrival feed, request queue,
/// batch-formation timeout, shedding switch, batch scratch, and virtual
/// clock.
pub(crate) struct OpenLoop {
    feed: Feed,
    queue: RequestQueue,
    timeout_s: f64,
    shed_deadline: bool,
    /// Explicit shedding deadline (ms) overriding the window's SLO target
    /// when set (`FleetBuilder::deadline_ms`). The SLO schedule still
    /// drives `WindowRecord.slo_ms` and attainment; only `shed_expired`
    /// sees this.
    deadline_ms: Option<f64>,
    /// SLO-class deadline multiplier applied to the effective shedding
    /// deadline (gold 1.0 / silver 0.75 / best-effort 0.5). Exactly 1.0
    /// when unclassed, which is bit-identical to no multiplier at all.
    shed_scale: f64,
    /// Reused batch scratch: `serve_round` drains each batch here, so the
    /// steady-state path never allocates a per-batch `Vec`.
    batch: Vec<Request>,
    /// Member-local virtual time (seconds).
    pub(crate) now_s: f64,
}

impl OpenLoop {
    /// `start_s` seeds the clock (profiling consumed virtual time before
    /// serving began, so arrivals during it start the serve as backlog).
    pub(crate) fn new(
        pattern: ArrivalPattern,
        seed: u64,
        queue_capacity: Option<usize>,
        batch_timeout_ms: f64,
        shed_deadline: bool,
        start_s: f64,
    ) -> Self {
        OpenLoop {
            feed: Feed::new(ArrivalGenerator::new(pattern, seed)),
            queue: match queue_capacity {
                Some(cap) => RequestQueue::bounded(cap),
                None => RequestQueue::new(),
            },
            timeout_s: batch_timeout_ms / 1000.0,
            shed_deadline,
            deadline_ms: None,
            shed_scale: 1.0,
            batch: Vec::new(),
            now_s: start_s,
        }
    }

    /// Set the explicit shedding deadline and/or the SLO-class deadline
    /// multiplier (see the field docs). `(None, 1.0)` — the construction
    /// default — sheds at the raw SLO target exactly as before.
    pub(crate) fn set_shed_deadline(&mut self, deadline_ms: Option<f64>, shed_scale: f64) {
        self.deadline_ms = deadline_ms;
        self.shed_scale = shed_scale;
    }

    /// Requests pulled off the arrival stream so far.
    pub(crate) fn arrived(&self) -> u64 {
        self.feed.count
    }

    /// Requests dropped at admission (bounded-queue overflow).
    pub(crate) fn dropped(&self) -> u64 {
        self.queue.dropped
    }

    /// Requests shed because their queueing delay blew the deadline.
    pub(crate) fn dropped_deadline(&self) -> u64 {
        self.queue.dropped_deadline
    }

    /// Requests lost to device crashes (see [`OpenLoop::fail_queue`]).
    pub(crate) fn dropped_failure(&self) -> u64 {
        self.queue.dropped_failure
    }

    /// The member's device crashed at a window barrier: its queued
    /// (in-flight) work is lost. Drains the queue, accounts the losses
    /// (conservation stays closed — the requests already counted as
    /// arrived), and returns how many were lost. The arrival feed and
    /// virtual clock are untouched: a failed-over member resumes
    /// serving fresh arrivals on its new device.
    pub(crate) fn fail_queue(&mut self) -> u64 {
        self.queue.fail_all()
    }

    /// Current queue depth (the window-boundary backpressure signal).
    pub(crate) fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Queue high-water mark over the whole run.
    pub(crate) fn max_depth(&self) -> usize {
        self.queue.max_depth
    }

    /// Advance this member's virtual clock by a stall — a model (re)load
    /// on launch or live migration. Open-loop arrivals keep flowing on
    /// the wall clock, so every request that lands during the stall
    /// queues up as backlog and the stall is charged to the sojourn
    /// latencies of the member's next served batches (the same backlog
    /// mechanism `start_s` uses for profiling overhead).
    pub(crate) fn stall_ms(&mut self, ms: f64) {
        self.now_s += ms / 1000.0;
    }

    /// Form and execute one batch at `(bs, mtl)` under `share` — either
    /// time-sharing (observed latency inflated by the fleet's contention
    /// factor; `SmShare::Inflate(1.0)` solo) or a spatial SM grant
    /// (executed inside the partition, no inflation). `slo_ms` is the
    /// deadline for shedding when enabled. Returns `Ok(false)` when the
    /// arrival stream is exhausted and nothing is left to serve (finite
    /// traces); the driver should stop scheduling rounds for this member.
    pub(crate) fn serve_round(
        &mut self,
        (bs, mtl): (u32, u32),
        slo_ms: f64,
        share: SmShare,
        device: &mut dyn Device,
        win: &mut WindowAccum,
    ) -> Result<bool, DeviceError> {
        let target = (bs as usize) * (mtl as usize);
        // Batch formation: size- or timeout-triggered.
        loop {
            while self.feed.peek() <= self.now_s {
                let t = self.feed.pop();
                let _ = self.queue.push(t);
            }
            win.queue_peak = win.queue_peak.max(self.queue.len());
            if self.queue.len() >= target {
                break;
            }
            let deadline = match self.queue.oldest_arrival() {
                Some(oldest) => oldest + self.timeout_s,
                None => f64::INFINITY,
            };
            let next = self.feed.peek();
            if next.is_infinite() && self.queue.is_empty() {
                // Trace exhausted and fully drained: no more work, ever.
                return Ok(false);
            }
            if next <= deadline {
                // Wait for the next arrival (maybe it fills the batch).
                self.now_s = next;
            } else {
                // Timeout: dispatch whatever is waiting.
                self.now_s = self.now_s.max(deadline);
                break;
            }
        }

        if self.shed_deadline {
            // Effective deadline: the explicit per-member override (or
            // the window's SLO target) scaled by the member's SLO class.
            // Unclassed members multiply by exactly 1.0 — a bit-identical
            // no-op for every finite f64 — so runs without classes or
            // deadline overrides stay byte-identical to the pre-class
            // engine.
            let deadline = self.deadline_ms.unwrap_or(slo_ms) * self.shed_scale;
            self.queue.shed_expired(self.now_s, deadline);
        }
        self.queue.take_batch_into(target, &mut self.batch);
        if self.batch.is_empty() {
            // Everything waiting had already blown its deadline; the
            // round consumed (virtual) time but dispatched nothing.
            return Ok(true);
        }
        let eff_bs = (self.batch.len().div_ceil(mtl as usize)).max(1) as u32;
        let (s, lat_ms) = match share {
            SmShare::Inflate(factor) => {
                let s = device.execute_batch(eff_bs, mtl)?;
                (s, s.latency_ms * factor)
            }
            SmShare::Grant(grant) => {
                let s = device.execute_batch_granted(eff_bs, mtl, grant)?;
                (s, s.latency_ms)
            }
            SmShare::GrantInflate { grant, factor } => {
                let s = device.execute_batch_granted(eff_bs, mtl, grant)?;
                (s, s.latency_ms * factor)
            }
        };
        self.now_s += lat_ms / 1000.0;
        let done_s = self.now_s;
        for r in &self.batch {
            win.lat.push((done_s - r.arrival_s) * 1000.0);
        }
        win.served += self.batch.len() as f64;
        win.power_acc += s.power_w;
        win.sm_acc += s.sm_util;
        win.executed += 1;
        Ok(true)
    }
}

/// Per-window accumulator: counter snapshots taken at the window start
/// plus everything [`OpenLoop::serve_round`] measured since.
///
/// Constructed ONCE per member and recycled across windows: `begin`
/// re-snapshots the counters and clears the latency buffer without
/// releasing its storage, and the percentile scratch lives here too —
/// the per-member scratch pool that keeps window accumulation off the
/// allocator. The window's latencies stay readable through
/// [`WindowAccum::latencies`] until the next `begin`.
pub(crate) struct WindowAccum {
    start_s: f64,
    arrived_before: u64,
    dropped_before: u64,
    shed_before: u64,
    /// Per-request sojourn latencies (ms) served this window. (This used
    /// to carry a `(sojourn_ms, weight)` pair with the weight always 1.0
    /// — open-loop requests are individually counted, unlike closed-loop
    /// batch records — so the dead weight was dropped and the record
    /// halved to a bare `f64`.)
    pub(crate) lat: Vec<f64>,
    served: f64,
    power_acc: f64,
    sm_acc: f64,
    /// Batches actually executed this window — the divisor for the
    /// power/SM means. Equal to `rounds_per_window` on an infinite
    /// arrival stream; smaller once a finite trace drains mid-window.
    executed: usize,
    queue_peak: usize,
    /// Reused percentile scratch (one quickselect per control decision,
    /// no per-window alloc + sort).
    scratch: Vec<f64>,
}

impl WindowAccum {
    /// Fresh accumulator; call [`WindowAccum::begin`] at every window
    /// boundary (including before the first window).
    pub(crate) fn new() -> Self {
        WindowAccum {
            start_s: 0.0,
            arrived_before: 0,
            dropped_before: 0,
            shed_before: 0,
            lat: Vec::new(),
            served: 0.0,
            power_acc: 0.0,
            sm_acc: 0.0,
            executed: 0,
            queue_peak: 0,
            scratch: Vec::new(),
        }
    }

    /// Snapshot the member counters at a window boundary, recycling the
    /// latency buffer (cleared, storage kept).
    pub(crate) fn begin(&mut self, lp: &OpenLoop) {
        self.start_s = lp.now_s;
        self.arrived_before = lp.arrived();
        self.dropped_before = lp.dropped();
        self.shed_before = lp.dropped_deadline();
        self.lat.clear();
        self.served = 0.0;
        self.power_acc = 0.0;
        self.sm_acc = 0.0;
        self.executed = 0;
        self.queue_peak = 0;
    }

    /// This window's per-request sojourn latencies (ms), valid until the
    /// next [`WindowAccum::begin`]. Every open-loop request counts with
    /// weight 1 in SLO attainment and CDFs.
    pub(crate) fn latencies(&self) -> &[f64] {
        &self.lat
    }

    /// Fold the window into its trace record + policy observation.
    pub(crate) fn finish(
        &mut self,
        window: usize,
        slo_ms: f64,
        (bs, mtl): (u32, u32),
        lp: &OpenLoop,
    ) -> (WindowRecord, WindowObservation) {
        let duration_s = (lp.now_s - self.start_s).max(1e-9);
        let n = self.lat.len();
        let (p95, mean) = if n == 0 {
            // A window can be empty once a finite trace has drained.
            (0.0, 0.0)
        } else {
            self.scratch.clear();
            self.scratch.extend_from_slice(&self.lat);
            let rank = ((0.95 * n as f64).ceil() as usize).clamp(1, n);
            // total_cmp: a NaN sample (device bug) must degrade to a NaN
            // percentile, never panic the comparator mid-run.
            let (_, p95, _) =
                self.scratch.select_nth_unstable_by(rank - 1, |a, b| a.total_cmp(b));
            (*p95, self.lat.iter().sum::<f64>() / n as f64)
        };
        let throughput = self.served / duration_s;
        // Means over batches actually executed (a drained finite trace
        // can end a window early; an idle window honestly reports 0).
        let power_w = self.power_acc / self.executed.max(1) as f64;
        let arrival_rate = (lp.arrived() - self.arrived_before) as f64 / duration_s;
        let drops = lp.dropped() - self.dropped_before;
        let drops_deadline = lp.dropped_deadline() - self.shed_before;

        let record = WindowRecord {
            window,
            bs,
            mtl,
            slo_ms,
            p95_ms: p95,
            mean_ms: mean,
            throughput,
            duration_s,
            power_w,
            queue_peak: self.queue_peak,
            arrival_rate,
            drops,
            drops_deadline,
        };
        let obs = WindowObservation {
            window,
            slo_ms,
            p95_ms: p95,
            mean_ms: mean,
            throughput,
            power_w,
            sm_util: self.sm_acc / self.executed.max(1) as f64,
            queue_depth: lp.queue_len(),
            arrival_rate,
            drops,
            drops_deadline,
        };
        (record, obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calendar::{EventCalendar, LinearScan, NextEventQueue};
    use crate::device::ExecSample;
    use crate::gpusim::{Dataset, GpuSim};

    /// Drive a 3-member open-loop "fleet" with the given scheduler and
    /// record the global dispatch order plus every member's sojourn
    /// latencies and final clock.
    fn drive(mut sched: impl NextEventQueue) -> (Vec<usize>, Vec<Vec<f64>>, Vec<f64>) {
        // Members 0 and 1 replay the IDENTICAL trace (their next-event
        // times tie exactly, starting at clock 0.0 for all three); member
        // 2's one-arrival trace exhausts in the first window.
        let traces: [Vec<f64>; 3] = [
            vec![0.0, 0.010, 0.010, 0.020, 0.100, 0.400],
            vec![0.0, 0.010, 0.010, 0.020, 0.100, 0.400],
            vec![0.005],
        ];
        let mut lps: Vec<OpenLoop> = traces
            .iter()
            .map(|t| OpenLoop::new(ArrivalPattern::Trace(t.clone()), 1, None, 5.0, false, 0.0))
            .collect();
        let mut sims: Vec<GpuSim> = (0..3)
            .map(|i| GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 10 + i).unwrap())
            .collect();
        let mut wins: Vec<WindowAccum> = (0..3).map(|_| WindowAccum::new()).collect();
        let mut order: Vec<usize> = Vec::new();
        let mut lat: Vec<Vec<f64>> = vec![Vec::new(); 3];
        for w in 0..3 {
            for i in 0..3 {
                wins[i].begin(&lps[i]);
            }
            let mut remaining = [4usize; 3];
            sched.clear();
            for i in 0..3 {
                sched.push(i, lps[i].now_s);
            }
            while let Some(k) = sched.pop() {
                remaining[k] -= 1;
                order.push(k);
                let more = lps[k]
                    .serve_round((2, 1), 50.0, SmShare::Inflate(1.0), &mut sims[k], &mut wins[k])
                    .unwrap();
                if more && remaining[k] > 0 {
                    sched.push(k, lps[k].now_s);
                }
            }
            for i in 0..3 {
                let (_record, _obs) = wins[i].finish(w, 50.0, (2, 1), &lps[i]);
                lat[i].extend_from_slice(wins[i].latencies());
            }
        }
        (order, lat, lps.iter().map(|l| l.now_s).collect())
    }

    #[test]
    fn calendar_serves_in_exactly_the_linear_scan_order() {
        // The O(log M) event calendar must reproduce the pre-refactor
        // linear scan bit for bit on a scenario with exact next-event
        // ties and a member whose finite trace exhausts mid-run: same
        // global dispatch order, same latencies, same final clocks.
        let (order_cal, lat_cal, clocks_cal) = drive(EventCalendar::new());
        let (order_lin, lat_lin, clocks_lin) = drive(LinearScan::new());
        assert_eq!(order_cal, order_lin, "global dispatch order changed");
        assert_eq!(lat_cal, lat_lin, "per-member sojourn latencies changed");
        assert_eq!(clocks_cal, clocks_lin, "member clocks diverged");
        // Sanity: the tie at t=0 was really exercised (member 0 before 1)
        // and the exhausted member 2 stopped being scheduled.
        assert_eq!(&order_cal[..2], &[0, 1]);
        let last_windows = &order_cal[order_cal.len() - 8..];
        assert!(!last_windows.contains(&2), "exhausted member kept being served");
    }

    /// Device returning NaN latencies (a misbehaving backend): the
    /// percentile scratch must never panic on the comparator.
    struct NanDevice;

    impl Device for NanDevice {
        fn model(&self) -> &str {
            "nan-device"
        }
        fn execute_batch(&mut self, bs: u32, mtl: u32) -> Result<ExecSample, DeviceError> {
            Ok(ExecSample { latency_ms: f64::NAN, batch_size: bs, mtl, power_w: 0.0, sm_util: 0.0 })
        }
    }

    #[test]
    fn nan_latency_samples_cannot_panic_window_accumulation() {
        let mut lp = OpenLoop::new(ArrivalPattern::uniform(1000.0), 3, None, 1.0, false, 0.0);
        let mut dev = NanDevice;
        let mut win = WindowAccum::new();
        win.begin(&lp);
        for _ in 0..8 {
            lp.serve_round((2, 1), 50.0, SmShare::Inflate(1.0), &mut dev, &mut win).unwrap();
        }
        let (record, obs) = win.finish(0, 50.0, (2, 1), &lp);
        // The NaN propagates into the percentile instead of panicking.
        assert!(record.p95_ms.is_nan());
        assert!(obs.p95_ms.is_nan());
    }

    #[test]
    fn steady_state_serving_path_does_not_allocate() {
        // The acceptance criterion of the zero-allocation refactor: once
        // every recycled buffer has reached its steady capacity, a full
        // window of serve_round + window accumulation performs ZERO heap
        // allocations on this thread. Overload a bounded queue so the
        // ring, the batch scratch, the arrival chunk buffer, and the
        // latency/percentile buffers all hit their high-water marks
        // during warm-up. Shedding stays OFF so every round dispatches a
        // full batch and each window's latency count is identical —
        // deterministic buffer demand, no flaky capacity edge. (The shed
        // path itself is branch-and-counter arithmetic on the ring; it
        // has no allocation to hide.)
        let mut sim = GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 9).unwrap();
        let mut lp = OpenLoop::new(ArrivalPattern::uniform(2000.0), 9, Some(64), 2.0, false, 0.0);
        let mut win = WindowAccum::new();
        for w in 0..5 {
            win.begin(&lp);
            for _ in 0..100 {
                lp.serve_round((4, 1), 50.0, SmShare::Inflate(1.0), &mut sim, &mut win).unwrap();
            }
            let _ = win.finish(w, 50.0, (4, 1), &lp);
        }
        let before = crate::alloc_probe::thread_allocs();
        win.begin(&lp);
        for _ in 0..100 {
            lp.serve_round((4, 1), 50.0, SmShare::Inflate(1.0), &mut sim, &mut win).unwrap();
        }
        let (record, _obs) = win.finish(5, 50.0, (4, 1), &lp);
        let allocs = crate::alloc_probe::thread_allocs() - before;
        assert!(record.throughput > 0.0);
        assert_eq!(allocs, 0, "steady-state serving path allocated {allocs} times");
    }

    #[test]
    fn explicit_deadline_and_class_scale_tighten_shedding() {
        // 32 simultaneous arrivals against a 2-wide batch: everything
        // past the first batch ages while earlier batches execute. The
        // effective shed deadline is `deadline_ms.unwrap_or(slo) *
        // shed_scale`; tightening either knob can only shed more, and
        // the construction default (None, 1.0) is the raw SLO behavior.
        let trace: Vec<f64> = vec![0.0; 32];
        let serve = |deadline: Option<f64>, scale: f64| {
            let mut lp =
                OpenLoop::new(ArrivalPattern::Trace(trace.clone()), 1, None, 5.0, true, 0.0);
            lp.set_shed_deadline(deadline, scale);
            let mut sim = GpuSim::for_paper_dnn("inc-v1", Dataset::ImageNet, 7).unwrap();
            let mut win = WindowAccum::new();
            win.begin(&lp);
            for _ in 0..64 {
                if !lp
                    .serve_round((2, 1), 1000.0, SmShare::Inflate(1.0), &mut sim, &mut win)
                    .unwrap()
                {
                    break;
                }
            }
            lp.dropped_deadline()
        };
        let baseline = serve(None, 1.0);
        let tight = serve(Some(0.01), 1.0); // 10 µs: only the first batch survives
        assert!(tight > baseline, "tight {tight} must shed more than baseline {baseline}");
        assert!(serve(Some(40.0), 0.5) >= serve(Some(40.0), 1.0), "scale must tighten");
        assert_eq!(serve(None, 1.0), baseline, "shed accounting must be deterministic");
    }

    #[test]
    fn feed_chunking_preserves_the_arrival_stream() {
        // The Feed must hand out exactly the generator's sequence across
        // chunk refills (ARRIVAL_CHUNK boundaries included).
        let pattern = ArrivalPattern::poisson(500.0);
        let mut feed = Feed::new(ArrivalGenerator::new(pattern.clone(), 42));
        let mut gen = ArrivalGenerator::new(pattern, 42);
        for i in 0..(3 * ARRIVAL_CHUNK + 7) {
            assert!(feed.peek().is_finite());
            assert_eq!(feed.pop(), gen.next_arrival(), "arrival #{i} diverged");
            assert_eq!(feed.count, i as u64 + 1);
        }
    }

    #[test]
    fn feed_reports_exhaustion_as_infinity() {
        let mut feed =
            Feed::new(ArrivalGenerator::new(ArrivalPattern::trace(vec![0.25, 0.5]).unwrap(), 1));
        assert_eq!(feed.pop(), 0.25);
        assert_eq!(feed.pop(), 0.5);
        assert_eq!(feed.peek(), f64::INFINITY);
        assert_eq!(feed.count, 2);
        let closed = Feed::new(ArrivalGenerator::new(ArrivalPattern::Closed, 1));
        assert_eq!(closed.peek(), f64::INFINITY);
    }
}
