//! The shared virtual-time open-loop serving engine.
//!
//! PR 1 buried the open-loop event loop inside `ServingSession::run`,
//! which meant `Fleet` could only serve closed-loop lockstep windows.
//! This module extracts that loop into a reusable per-member core so
//! *every* serving entry point drives the same machinery:
//!
//! * [`OpenLoop`] owns one member's arrival stream ([`Feed`] over an
//!   `ArrivalGenerator`), its (optionally bounded) [`RequestQueue`], the
//!   batch-formation timeout, and the member's virtual clock;
//! * [`OpenLoop::serve_round`] forms and executes ONE batch — dispatched
//!   as soon as `bs * mtl` requests are waiting (size trigger) or once
//!   the oldest waiting request has waited `batch_timeout_ms` (timeout
//!   trigger) — charges every request its full sojourn (queueing delay +
//!   service, optionally inflated by a fleet SM-contention factor), and
//!   advances the member clock by the observed batch latency;
//! * [`WindowAccum`] snapshots the member counters at a window boundary
//!   and folds the rounds served since into the `WindowRecord` /
//!   `WindowObservation` pair every policy consumes.
//!
//! `ServingSession` runs one `OpenLoop`; `Fleet` runs one per member and
//! interleaves their rounds by next-event time (smallest member clock
//! first), which is what makes per-member arrival processes, trace
//! replay, and cross-job burst interference expressible at all.
//!
//! Two modeling notes shared by every driver:
//!
//! * A partial batch still executes at the configured `mtl` (all
//!   co-located instances stay resident; the device bills full
//!   co-location contention and power), so light-load MT latency is the
//!   conservative upper bound, not the idle-instances optimum.
//! * With deadline shedding enabled, expiry is checked at dispatch time:
//!   a request whose queueing delay alone already exceeds the SLO is
//!   dropped (counted in `dropped_deadline`) instead of wasting a batch
//!   slot it can no longer use.

use crate::device::{Device, DeviceError};
use crate::workload::{ArrivalGenerator, ArrivalPattern, RequestQueue};

use super::policy::WindowObservation;
use super::session::WindowRecord;

/// How a member's window shares the GPU's SMs — the two regimes the
/// fleet's `PartitionMode` selects between.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum SmShare {
    /// Time-sharing: execute on the whole device and inflate the observed
    /// latency by the fleet's combined-contention factor (1.0 solo).
    Inflate(f64),
    /// Spatial partition: execute inside an SM capacity grant (MPS
    /// fraction / MIG slice bundle); no cross-member inflation at all.
    Grant(f64),
}

/// Peekable arrival stream over an [`ArrivalGenerator`].
pub(crate) struct Feed {
    gen: ArrivalGenerator,
    next: f64,
    count: u64,
}

impl Feed {
    pub(crate) fn new(mut gen: ArrivalGenerator) -> Self {
        let next = gen.next_arrival();
        Feed { gen, next, count: 0 }
    }

    pub(crate) fn peek(&self) -> f64 {
        self.next
    }

    pub(crate) fn pop(&mut self) -> f64 {
        let t = self.next;
        self.next = self.gen.next_arrival();
        self.count += 1;
        t
    }
}

/// One member's open-loop serving state: arrival feed, request queue,
/// batch-formation timeout, shedding switch, and virtual clock.
pub(crate) struct OpenLoop {
    feed: Feed,
    queue: RequestQueue,
    timeout_s: f64,
    shed_deadline: bool,
    /// Member-local virtual time (seconds).
    pub(crate) now_s: f64,
}

impl OpenLoop {
    /// `start_s` seeds the clock (profiling consumed virtual time before
    /// serving began, so arrivals during it start the serve as backlog).
    pub(crate) fn new(
        pattern: ArrivalPattern,
        seed: u64,
        queue_capacity: Option<usize>,
        batch_timeout_ms: f64,
        shed_deadline: bool,
        start_s: f64,
    ) -> Self {
        OpenLoop {
            feed: Feed::new(ArrivalGenerator::new(pattern, seed)),
            queue: match queue_capacity {
                Some(cap) => RequestQueue::bounded(cap),
                None => RequestQueue::new(),
            },
            timeout_s: batch_timeout_ms / 1000.0,
            shed_deadline,
            now_s: start_s,
        }
    }

    /// Requests pulled off the arrival stream so far.
    pub(crate) fn arrived(&self) -> u64 {
        self.feed.count
    }

    /// Requests dropped at admission (bounded-queue overflow).
    pub(crate) fn dropped(&self) -> u64 {
        self.queue.dropped
    }

    /// Requests shed because their queueing delay blew the deadline.
    pub(crate) fn dropped_deadline(&self) -> u64 {
        self.queue.dropped_deadline
    }

    /// Current queue depth (the window-boundary backpressure signal).
    pub(crate) fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Queue high-water mark over the whole run.
    pub(crate) fn max_depth(&self) -> usize {
        self.queue.max_depth
    }

    /// Form and execute one batch at `(bs, mtl)` under `share` — either
    /// time-sharing (observed latency inflated by the fleet's contention
    /// factor; `SmShare::Inflate(1.0)` solo) or a spatial SM grant
    /// (executed inside the partition, no inflation). `slo_ms` is the
    /// deadline for shedding when enabled. Returns `Ok(false)` when the
    /// arrival stream is exhausted and nothing is left to serve (finite
    /// traces); the driver should stop scheduling rounds for this member.
    pub(crate) fn serve_round(
        &mut self,
        (bs, mtl): (u32, u32),
        slo_ms: f64,
        share: SmShare,
        device: &mut dyn Device,
        win: &mut WindowAccum,
    ) -> Result<bool, DeviceError> {
        let target = (bs as usize) * (mtl as usize);
        // Batch formation: size- or timeout-triggered.
        loop {
            while self.feed.peek() <= self.now_s {
                let t = self.feed.pop();
                let _ = self.queue.push(t);
            }
            win.queue_peak = win.queue_peak.max(self.queue.len());
            if self.queue.len() >= target {
                break;
            }
            let deadline = match self.queue.oldest_arrival() {
                Some(oldest) => oldest + self.timeout_s,
                None => f64::INFINITY,
            };
            let next = self.feed.peek();
            if next.is_infinite() && self.queue.is_empty() {
                // Trace exhausted and fully drained: no more work, ever.
                return Ok(false);
            }
            if next <= deadline {
                // Wait for the next arrival (maybe it fills the batch).
                self.now_s = next;
            } else {
                // Timeout: dispatch whatever is waiting.
                self.now_s = self.now_s.max(deadline);
                break;
            }
        }

        if self.shed_deadline {
            self.queue.shed_expired(self.now_s, slo_ms);
        }
        let batch = self.queue.take_batch(target);
        if batch.is_empty() {
            // Everything waiting had already blown its deadline; the
            // round consumed (virtual) time but dispatched nothing.
            return Ok(true);
        }
        let eff_bs = (batch.len().div_ceil(mtl as usize)).max(1) as u32;
        let (s, lat_ms) = match share {
            SmShare::Inflate(factor) => {
                let s = device.execute_batch(eff_bs, mtl)?;
                (s, s.latency_ms * factor)
            }
            SmShare::Grant(grant) => {
                let s = device.execute_batch_granted(eff_bs, mtl, grant)?;
                (s, s.latency_ms)
            }
        };
        self.now_s += lat_ms / 1000.0;
        for r in &batch {
            let sojourn_ms = (self.now_s - r.arrival_s) * 1000.0;
            win.lat.push((sojourn_ms, 1.0));
        }
        win.served += batch.len() as f64;
        win.power_acc += s.power_w;
        win.sm_acc += s.sm_util;
        win.executed += 1;
        Ok(true)
    }
}

/// Per-window accumulator: counter snapshots taken at the window start
/// plus everything [`OpenLoop::serve_round`] measured since.
pub(crate) struct WindowAccum {
    start_s: f64,
    arrived_before: u64,
    dropped_before: u64,
    shed_before: u64,
    /// Per-request `(sojourn_ms, weight)` pairs served this window.
    pub(crate) lat: Vec<(f64, f64)>,
    served: f64,
    power_acc: f64,
    sm_acc: f64,
    /// Batches actually executed this window — the divisor for the
    /// power/SM means. Equal to `rounds_per_window` on an infinite
    /// arrival stream; smaller once a finite trace drains mid-window.
    executed: usize,
    queue_peak: usize,
}

impl WindowAccum {
    /// Snapshot the member counters at a window boundary.
    pub(crate) fn begin(lp: &OpenLoop) -> Self {
        WindowAccum {
            start_s: lp.now_s,
            arrived_before: lp.arrived(),
            dropped_before: lp.dropped(),
            shed_before: lp.dropped_deadline(),
            lat: Vec::new(),
            served: 0.0,
            power_acc: 0.0,
            sm_acc: 0.0,
            executed: 0,
            queue_peak: 0,
        }
    }

    /// Fold the window into its trace record + policy observation.
    /// `scratch` is reused percentile space (one quickselect per control
    /// decision, no per-window alloc + sort). Also returns the window's
    /// `(latency, weight)` pairs for SLO-attainment accounting.
    pub(crate) fn finish(
        self,
        window: usize,
        slo_ms: f64,
        (bs, mtl): (u32, u32),
        lp: &OpenLoop,
        scratch: &mut Vec<f64>,
    ) -> (WindowRecord, WindowObservation, Vec<(f64, f64)>) {
        let WindowAccum {
            start_s,
            arrived_before,
            dropped_before,
            shed_before,
            lat,
            served,
            power_acc,
            sm_acc,
            executed,
            queue_peak,
        } = self;
        let duration_s = (lp.now_s - start_s).max(1e-9);
        let n = lat.len();
        let (p95, mean) = if n == 0 {
            // A window can be empty once a finite trace has drained.
            (0.0, 0.0)
        } else {
            scratch.clear();
            scratch.extend(lat.iter().map(|(l, _)| *l));
            let rank = ((0.95 * n as f64).ceil() as usize).clamp(1, n);
            let (_, p95, _) =
                scratch.select_nth_unstable_by(rank - 1, |a, b| a.partial_cmp(b).unwrap());
            (*p95, lat.iter().map(|(l, _)| *l).sum::<f64>() / n as f64)
        };
        let throughput = served / duration_s;
        // Means over batches actually executed (a drained finite trace
        // can end a window early; an idle window honestly reports 0).
        let power_w = power_acc / executed.max(1) as f64;
        let arrival_rate = (lp.arrived() - arrived_before) as f64 / duration_s;
        let drops = lp.dropped() - dropped_before;
        let drops_deadline = lp.dropped_deadline() - shed_before;

        let record = WindowRecord {
            window,
            bs,
            mtl,
            slo_ms,
            p95_ms: p95,
            mean_ms: mean,
            throughput,
            duration_s,
            power_w,
            queue_peak,
            arrival_rate,
            drops,
            drops_deadline,
        };
        let obs = WindowObservation {
            window,
            slo_ms,
            p95_ms: p95,
            mean_ms: mean,
            throughput,
            power_w,
            sm_util: sm_acc / executed.max(1) as f64,
            queue_depth: lp.queue_len(),
            arrival_rate,
            drops,
            drops_deadline,
        };
        (record, obs, lat)
    }
}
