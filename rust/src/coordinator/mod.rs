//! The DNNScaler coordinator — the paper's system contribution.
//!
//! * [`profiler`] — run-time probe deciding Batching vs Multi-Tenancy
//!   (Eqs. 3-5 / Algorithm 1 lines 1-9);
//! * [`scaler_batching`] — pseudo-binary-search dynamic batch sizing with
//!   the `alpha = 0.85` hysteresis band (Algorithm 1 lines 10-29);
//! * [`scaler_mt`] — matrix-completion-seeded AIMD instance scaling
//!   (Algorithm 1 lines 30-41);
//! * [`matcomp`] — the soft-impute matrix-completion estimator over a
//!   library of latency-vs-MTL curves;
//! * [`clipper`] — the Clipper baseline (AIMD batching only, Crankshaw et
//!   al. NSDI'17) the paper compares against;
//! * [`latency`] — windowed tail-latency (p95) monitor;
//! * [`job`] — the 30-job workload of Table 4;
//! * [`runner`] — the serving loop tying device + controller + metrics.

pub mod clipper;
pub mod controller;
pub mod job;
pub mod latency;
pub mod matcomp;
pub mod profiler;
pub mod runner;
pub mod scaler_batching;
pub mod scaler_mt;

pub use controller::{Controller, Decision, Method};
pub use profiler::{ProfileOutcome, Profiler};

/// Hysteresis coefficient from the paper (§3.3.1): the Scaler holds the
/// knob while `alpha * SLO <= p95 <= SLO`.
pub const ALPHA: f64 = 0.85;

/// Upper bound on batch size (paper §3.3.1, fitted to GPU memory).
pub const MAX_BS: u32 = 128;

/// Upper bound on co-located instances (paper §3.3.2).
pub const MAX_MTL: u32 = 10;
