//! The DNNScaler coordinator — the paper's system contribution, grown
//! into an event-driven serving core.
//!
//! ## Serving entry points
//!
//! * [`session`] — **`ServingSession`**, the primary API: one job, one
//!   device, one [`policy::Policy`], served either closed-loop (the
//!   paper's setup, `ArrivalPattern::Closed`) or open-loop (virtual-time
//!   event loop over `workload` arrivals: timeout/size-triggered batch
//!   formation, queueing delay charged into every latency, drop
//!   accounting under bounded queues);
//! * [`fleet`] — **`Fleet`**, multiple jobs co-located on one simulated
//!   GPU with shared memory (admission control) and shared SMs
//!   (contention-inflated latencies);
//! * [`runner`] — the deprecated closed-loop `JobRunner` shim over
//!   `ServingSession`, kept for legacy call sites.
//!
//! ## Control algorithms (all [`policy::Policy`] implementations)
//!
//! * [`profiler`] — run-time probe deciding Batching vs Multi-Tenancy
//!   (Eqs. 3-5 / Algorithm 1 lines 1-9);
//! * [`scaler_batching`] — pseudo-binary-search dynamic batch sizing with
//!   the `alpha = 0.85` hysteresis band (Algorithm 1 lines 10-29);
//! * [`scaler_mt`] — matrix-completion-seeded AIMD instance scaling
//!   (Algorithm 1 lines 30-41);
//! * [`clipper`] — the Clipper baseline (AIMD batching only, Crankshaw et
//!   al. NSDI'17) the paper compares against;
//! * [`policy`] — the `Policy`/`WindowObservation`/`Action` interface
//!   plus the static-knob baseline and the legacy-`Controller` adapter.
//!
//! ## Substrate
//!
//! * [`controller`] — the legacy p95-only `Controller` trait;
//! * [`matcomp`] — the soft-impute matrix-completion estimator over a
//!   library of latency-vs-MTL curves;
//! * [`latency`] — windowed tail-latency (p95) monitor;
//! * [`job`] — the 30-job workload of Table 4.

pub mod clipper;
pub mod controller;
pub mod fleet;
pub mod job;
pub mod latency;
pub mod matcomp;
pub mod policy;
pub mod profiler;
pub mod runner;
pub mod scaler_batching;
pub mod scaler_mt;
pub mod session;

pub use controller::{Controller, Decision, Method};
pub use fleet::{Fleet, FleetBuilder, FleetOutcome};
pub use policy::{Action, AsPolicy, Policy, StaticPolicy, WindowObservation};
pub use profiler::{ProfileOutcome, Profiler};
pub use session::{
    ConfigError, JobOutcome, PolicySpec, RunConfig, ServingSession, SessionBuilder, WindowRecord,
};

/// Hysteresis coefficient from the paper (§3.3.1): the Scaler holds the
/// knob while `alpha * SLO <= p95 <= SLO`.
pub const ALPHA: f64 = 0.85;

/// Upper bound on batch size (paper §3.3.1, fitted to GPU memory).
pub const MAX_BS: u32 = 128;

/// Upper bound on co-located instances (paper §3.3.2).
pub const MAX_MTL: u32 = 10;
