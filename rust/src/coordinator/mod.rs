//! The DNNScaler coordinator — the paper's system contribution, grown
//! into an event-driven serving core.
//!
//! ## Architecture: engine / session / fleet
//!
//! The open-loop serving machinery lives in ONE place, [`engine`]: a
//! virtual-time event loop (arrival generation, timestamped queueing,
//! size/timeout batch formation, sojourn-latency charging, bounded-queue
//! drop accounting, SLO deadline shedding) packaged as a per-member
//! `OpenLoop` core. The entry points are thin drivers over it:
//!
//! * [`session`] — **`ServingSession`**, the single-job API: one job, one
//!   device, one [`policy::Policy`], served either closed-loop (the
//!   paper's setup, `ArrivalPattern::Closed`) or open-loop over one
//!   engine core (Poisson/uniform/bursty arrivals or recorded-trace
//!   replay via `ArrivalPattern::Trace`);
//! * [`fleet`] — **`Fleet`**, multiple jobs co-located on one simulated
//!   GPU with shared memory (admission control) and shared SMs
//!   (contention-inflated latencies). Members added with
//!   `FleetBuilder::job` serve closed-loop in lockstep windows exactly as
//!   before; members added with `FleetBuilder::job_with_arrivals` each
//!   get their own arrival process, bounded queue, batch timeout, and
//!   shedding switch, and one global event loop interleaves their batch
//!   rounds by next-event time — the "No DNN Left Behind" cross-job
//!   burst-interference setting;
//! * [`cluster`] — **`Cluster`**, the scheduling layer above one device:
//!   a heterogeneous pool of GPUs and MIG slices (each slice a virtual
//!   device with its own SM grant and memory ceiling), a pluggable
//!   [`cluster::Placement`] deciding which device each job lands on
//!   (round-robin, memory best-fit, interference-aware), and per-device
//!   serving through the very same fleet engine — a single-device
//!   cluster reproduces `Fleet` byte for byte (see `docs/cluster.md`);
//! * [`dynamics`] — warehouse-scale dynamics driving the cluster at
//!   window boundaries: job churn ([`dynamics::ChurnSchedule`]), live
//!   migration ([`dynamics::PlacementPolicy`]), and price-aware
//!   autoscaling ([`dynamics::Autoscaler`] billing $/device-hour into
//!   cost-per-goodput). Inactive dynamics leave the static path
//!   byte-identical (see `docs/dynamics.md`);
//! * [`faults`] — seeded fault injection over the same window-boundary
//!   loop: device crashes (queued work accounted to `dropped_failure`,
//!   residents failed over or retried with capped backoff), temporary
//!   performance degradation, repair, and a byte-reproducible
//!   MTBF/MTTR stochastic mode (see `docs/faults.md`).
//!
//! Open-loop fleets and clusters schedule their members through the
//! O(log M) [`calendar::EventCalendar`] (a binary heap keyed by
//! next-event time; ties break toward the lower member index, exactly
//! like the linear scan it replaced — see `docs/perf.md` and the
//! `fleet_scale` bench). A cluster additionally serves data-parallel
//! (`ClusterBuilder::threads`, PR 7): the device list shards into
//! contiguous chunks, each chunk's event loop running on its own scoped
//! worker thread, with snapshots byte-identical to the serial engine at
//! every thread count. The legacy closed-loop `JobRunner` shim was
//! removed in PR 5; [`session::ServingSession`] is the single-job entry
//! point.
//!
//! ## Control algorithms (all [`policy::Policy`] implementations)
//!
//! * [`profiler`] — run-time probe deciding Batching vs Multi-Tenancy
//!   (Eqs. 3-5 / Algorithm 1 lines 1-9);
//! * [`scaler_batching`] — pseudo-binary-search dynamic batch sizing with
//!   the `alpha = 0.85` hysteresis band (Algorithm 1 lines 10-29);
//! * [`scaler_mt`] — matrix-completion-seeded AIMD instance scaling
//!   (Algorithm 1 lines 30-41);
//! * [`clipper`] — the Clipper baseline (AIMD batching only, Crankshaw et
//!   al. NSDI'17) the paper compares against;
//! * [`policy`] — the `Policy`/`WindowObservation`/`Action` interface,
//!   the static-knob baseline, the queue-aware proactive scaler
//!   (`QueuePolicy`, D-STACK-style demand estimation), and the
//!   legacy-`Controller` adapter;
//! * [`slo`] — per-member service classes (gold / silver / best-effort)
//!   with class-weighted deadline shedding and overload admission, and
//!   the paper's combined Batching + Multi-Tenancy search
//!   (`CombinedPolicy`, §4.6) extended with a class-weighted partition
//!   share knob (`ClassPartition`). See `docs/slo.md`.
//!
//! ## Substrate
//!
//! * [`controller`] — the legacy p95-only `Controller` trait;
//! * [`matcomp`] — the soft-impute matrix-completion estimator over a
//!   library of latency-vs-MTL curves;
//! * [`latency`] — windowed tail-latency (p95) monitor;
//! * [`job`] — the 30-job workload of Table 4.

pub mod calendar;
pub mod clipper;
pub mod cluster;
pub mod controller;
pub mod dynamics;
pub(crate) mod engine;
pub mod faults;
pub mod fleet;
pub mod job;
pub mod latency;
pub mod matcomp;
pub mod policy;
pub mod profiler;
pub mod scaler_batching;
pub mod scaler_mt;
pub mod session;
pub mod slo;
pub mod snapshot;
pub mod testkit;

pub use cluster::{
    Assignment, AuditError, BestFit, Cluster, ClusterBuilder, ClusterOutcome, DeviceDesc,
    DeviceOutcome, DeviceSpec, InterferenceAware, Placement, PlacementError, PlacementJob,
    RoundRobin,
};
pub use controller::{Controller, Decision, Method};
pub use dynamics::{
    Autoscaler, ChurnSchedule, DynamicsOutcome, JobEvent, PeriodicReplace, PlacementPolicy,
    PoolObservation, ScaleAction, ThresholdAutoscaler,
};
pub use faults::{FaultEvent, FaultSchedule, FaultsOutcome};
pub use fleet::{Fleet, FleetBuilder, FleetOutcome};
pub use policy::{
    Action, AsPolicy, DemandPartition, PartitionPolicy, Policy, QueuePolicy, StaticPolicy,
    WindowObservation,
};
pub use profiler::{ProfileOutcome, Profiler};
pub use session::{
    ConfigError, JobOutcome, PolicySpec, RunConfig, ServingSession, SessionBuilder, WindowRecord,
};
pub use slo::{ClassPartition, ClassStat, CombinedPolicy, ParseSloClassError, SloClass, SloReport};

/// Hysteresis coefficient from the paper (§3.3.1): the Scaler holds the
/// knob while `alpha * SLO <= p95 <= SLO`.
pub const ALPHA: f64 = 0.85;

/// Upper bound on batch size (paper §3.3.1, fitted to GPU memory).
pub const MAX_BS: u32 = 128;

/// Upper bound on co-located instances (paper §3.3.2).
pub const MAX_MTL: u32 = 10;
